"""Campaign engine coverage: scan-over-rounds + vmap-over-runs.

The load-bearing property: **lane k of a vmapped campaign reproduces the
single-run ``Swarm`` for the same (scenario, seed)** — same agg_norm
history, same caught sets, same minted contributions — across scenario
regimes including verification, compression, churn, and heterogeneous
capacity.  Plus: the ``derailment.sweep`` phase-diagram API (one compiled
program, baseline sharing, equivalence with ``simulate_derailment``) and
traced aggregator kwargs / multi-aggregator rounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.core.derailment import simulate_derailment, sweep
from repro.core.scenarios import (
    SweepGrid,
    get_scenario,
    get_sweep_grid,
    list_sweep_grids,
    scenario_campaign,
)
from repro.core.swarm import (
    NodeSpec,
    SwarmConfig,
    history_from_records,
    lane_for_nodes,
    ledger_from_run,
    make_swarm,
    run_campaign,
    stack_lanes,
)
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem

ROUNDS = 15
SEEDS = (0, 1, 2)


def _lane_slice(tree, k):
    return jax.tree.map(lambda x: x[k], tree)


# --------------------- lane k == single-run Swarm ------------------------------
# >= 3 scenarios, including verification (audit_heavy), a lossy wire
# (compressed_wire), churn (high_churn_elastic), and speed-weighted minting
# (heterogeneous_speed).
@pytest.mark.parametrize("scenario", [
    "sign_flip_minority",
    "audit_heavy",
    "compressed_wire",
    "high_churn_elastic",
    "heterogeneous_speed",
])
def test_campaign_lane_matches_single_run_swarm(scenario):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    state, recs, _, node_ids, cfg = scenario_campaign(
        scenario, loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
        n_nodes=8, seeds=SEEDS, rounds=ROUNDS)

    for k, seed in enumerate(SEEDS):
        # the reference: a fresh Swarm stepped round by round on the host
        swarm = get_scenario(scenario).build_swarm(
            loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
            n_nodes=8, seed=seed)
        for r in range(ROUNDS):
            swarm.step(r)

        hist = history_from_records(_lane_slice(recs, k), node_ids)
        assert [h["n_active"] for h in hist] == \
            [h["n_active"] for h in swarm.history]
        assert [h["n_byzantine"] for h in hist] == \
            [h["n_byzantine"] for h in swarm.history]
        assert [h["caught"] for h in hist] == \
            [h["caught"] for h in swarm.history]
        np.testing.assert_allclose(
            [h["agg_norm"] for h in hist],
            [h["agg_norm"] for h in swarm.history],
            rtol=2e-3, atol=1e-5, err_msg=f"{scenario} seed {seed}")

        led = ledger_from_run(_lane_slice(state, k), node_ids,
                              verification=cfg.verification)
        assert led.balances == pytest.approx(swarm.ledger.balances)
        assert led.burned_stake == pytest.approx(swarm.ledger.burned_stake)


def test_campaign_slashes_on_device():
    """Slashing is part of the device carry: once caught, a node stays out
    for the rest of the scanned run and its contribution counter freezes."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    state, recs, _, node_ids, cfg = scenario_campaign(
        "audit_heavy", loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
        n_nodes=8, seeds=(0,), rounds=20)
    slashed = np.asarray(state.slashed[0])
    caught = np.asarray(recs.caught[0])               # (T, N)
    assert slashed.any()
    for i in np.flatnonzero(slashed):
        t_caught = int(np.flatnonzero(caught[:, i])[0])
        keep = np.asarray(recs.keep[0][:, i])
        assert not keep[t_caught:].any()              # never kept again
        assert np.asarray(state.contrib[0][i]) == keep[:t_caught].sum()


# ----------------------------- sweep API ---------------------------------------
def _quad_sweep(grid, **kw):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                 eval_fn, grid, **kw), (loss_fn, params0, data_fn, eval_fn)


def test_sweep_smoke_grid_phase_diagram():
    """The registered smoke grid: one program, mean derails past its 0
    breakdown point while CenteredClip resists the same minority."""
    res, _ = _quad_sweep(get_sweep_grid("no_off_smoke"))
    assert res.n_programs == 1
    assert len(res.results) == res.grid.n_points == 4
    assert res.n_runs == 4 + 1                        # + 1 baseline seed
    by = {(r.regime, r.n_attackers): r for r in res.results}
    assert by[("mean", 2)].derailed                   # 2/8 kills mean
    assert not by[("centered_clip", 2)].derailed      # CC holds at 25%
    assert by[("centered_clip", 6)].derailed          # 6/12 = breakdown
    assert all(np.isfinite(r.baseline_loss) for r in res.results)
    table = res.phase_table()
    assert "mean" in table and "centered_clip" in table


def test_sweep_lane_equals_simulate_derailment():
    """Any sweep cell must reproduce the single-point path bit-for-bit
    (same fold_in schedule, same masked-aggregation algebra)."""
    grid = SweepGrid(
        name="tiny", description="", n_honest=6, attacker_counts=(1, 3),
        seeds=(0, 2), rounds=10,
        regimes=get_sweep_grid("no_off_smoke").regimes)
    res, (loss_fn, params0, data_fn, eval_fn) = _quad_sweep(grid)
    for r in res.results:
        single = simulate_derailment(
            loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, eval_fn,
            n_honest=6, n_attack=r.n_attackers, rounds=10,
            aggregator=r.aggregator, seed=r.seed,
            baseline_loss=r.baseline_loss)
        np.testing.assert_allclose(r.final_loss, single.final_loss,
                                   rtol=2e-3, err_msg=str(r))
        assert r.derailed == single.derailed


def test_sweep_verified_regime_slashes_attackers():
    """p_check rides as a traced lane: verified lanes slash every attacker
    while the unverified regime in the same program slashes none."""
    from repro.core.scenarios import Regime
    from repro.core.verification import VerificationConfig
    grid = SweepGrid(
        name="v", description="", n_honest=6, attacker_counts=(2,),
        seeds=(0,), rounds=10, attack="zero",
        regimes=(Regime("mean", "mean"),
                 Regime("mean+verified", "mean",
                        verification=VerificationConfig(
                            p_check=1.0, stake=5.0, tolerance=1e-3))))
    res, _ = _quad_sweep(grid)
    assert res.n_programs == 1
    by = {r.regime: r for r in res.results}
    assert by["mean+verified"].attackers_slashed == 2
    assert not by["mean+verified"].derailed
    assert by["mean"].attackers_slashed == 0


def test_sweep_fast_compile_matches_default():
    grid = get_sweep_grid("no_off_smoke")
    fast, _ = _quad_sweep(grid, fast_compile=True)
    full, _ = _quad_sweep(grid, fast_compile=False)
    np.testing.assert_allclose(
        [r.final_loss for r in fast.results],
        [r.final_loss for r in full.results], rtol=1e-6)


def test_sweep_grid_registry():
    assert {"no_off_quick", "no_off_phase", "no_off_smoke"} <= \
        set(list_sweep_grids())
    assert get_sweep_grid("no_off_quick").n_points == 24
    with pytest.raises(KeyError, match="registered"):
        get_sweep_grid("nope")


# ---------------- traced aggregator kwargs / multi-aggregator ------------------
def test_masked_aggregators_accept_traced_kwargs():
    """One compiled program sweeps krum's f / trimmed_mean's trim /
    centered_clip's clip_tau as traced per-run values."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10, 7)).astype(np.float32))
    mask = jnp.asarray(rng.random(10) < 0.8).at[0].set(True)

    for name, kw_name, values in [
        ("krum", "f", jnp.asarray([1, 2, 3])),
        ("multi_krum", "m", jnp.asarray([2, 3, 4])),
        ("trimmed_mean", "trim", jnp.asarray([1, 2, 3])),
        ("centered_clip", "clip_tau", jnp.asarray([0.5, 1.0, 2.0])),
    ]:
        fn = aggregation.get_masked_aggregator(name)
        batched = jax.jit(jax.vmap(lambda v: fn(x, mask, **{kw_name: v})))(values)
        for i, v in enumerate(np.asarray(values)):
            one = fn(x, mask, **{kw_name: v.item()})
            np.testing.assert_allclose(np.asarray(batched[i]),
                                       np.asarray(one), rtol=1e-5, atol=1e-6,
                                       err_msg=f"{name}({kw_name}={v})")


def test_multi_aggregator_round_selects_per_lane():
    """A fused round evaluates the whole aggregator set and lane.agg_id
    picks the result — each lane equals its single-aggregator campaign."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(6)] + \
        [NodeSpec("adv", byzantine="sign_flip", byzantine_scale=20.0)]
    opt = SGD(lr=0.1, momentum=0.0)
    aggs = [("mean", {}), ("centered_clip", {})]
    lanes = []
    for aid in (0, 1):
        lane = lane_for_nodes(nodes, SwarmConfig(seed=0))
        lanes.append(lane._replace(agg_id=jnp.asarray(aid, jnp.int32)))
    _, recs, _ = run_campaign(loss_fn, params0, opt, data_fn,
                              stack_lanes(lanes), rounds=10, aggregator=aggs)
    for aid, name in [(0, "mean"), (1, "centered_clip")]:
        _, recs1, _ = run_campaign(
            loss_fn, params0, opt, data_fn,
            stack_lanes([lane_for_nodes(nodes, SwarmConfig(seed=0))]),
            rounds=10, aggregator=name)
        np.testing.assert_allclose(np.asarray(recs.agg_norm[aid]),
                                   np.asarray(recs1.agg_norm[0]),
                                   rtol=1e-5, err_msg=name)


def test_routed_static_kwargs_beat_traced_lane_kwargs():
    """Regression: in a fused round, a regime pinned to a static krum f must
    not pick up the per-lane traced f meant for the auto-f krum regime
    (call-time kwargs would silently win over the partial-baked ones)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(5)] + \
        [NodeSpec("adv", byzantine="sign_flip", byzantine_scale=30.0)]
    opt = SGD(lr=0.1, momentum=0.0)
    lane = lane_for_nodes(nodes, SwarmConfig(seed=0),
                          agg_kwargs={"f": 3})      # traced f for auto-krum
    aggs = [("krum", {"f": 1}), ("krum", {})]       # pinned f=1 | auto f
    _, recs, _ = run_campaign(
        loss_fn, params0, opt, data_fn,
        stack_lanes([lane._replace(agg_id=jnp.asarray(0, jnp.int32)),
                     lane._replace(agg_id=jnp.asarray(1, jnp.int32))]),
        rounds=8, aggregator=aggs)
    for aid, static_kw in [(0, {"f": 1}), (1, {"f": 3})]:
        _, recs1, _ = run_campaign(
            loss_fn, params0, opt, data_fn,
            stack_lanes([lane_for_nodes(nodes, SwarmConfig(seed=0))]),
            rounds=8, aggregator="krum", agg_kwargs=static_kw)
        np.testing.assert_allclose(np.asarray(recs.agg_norm[aid]),
                                   np.asarray(recs1.agg_norm[0]),
                                   rtol=1e-5, err_msg=f"agg_id={aid}")


def test_run_campaign_rejects_agg_kwargs_with_aggregator_set():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    lanes = stack_lanes([lane_for_nodes([NodeSpec("h0"), NodeSpec("h1")],
                                        SwarmConfig(seed=0))])
    with pytest.raises(ValueError, match="static kwargs"):
        run_campaign(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                     lanes, rounds=2, aggregator=[("mean", {}), ("krum", {})],
                     agg_kwargs={"f": 1})
