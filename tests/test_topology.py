"""Topology engine coverage: the graph layer (registry, mixing matrices,
spectral gaps, gossip invariants) and the decentralized swarm round built
on it.

The load-bearing equivalence: **a fully-connected decentralized swarm
reproduces the centralized ``Swarm`` exactly** — same history (agg_norm,
caught sets), same minted balances — because a complete graph makes every
neighborhood global and every replica identical.  Plus the §5.5 topology
axis: a (topology × attacker fraction × seed) sweep compiles to ONE device
program via ``run_campaign``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip, topology
from repro.core.derailment import sweep
from repro.core.scenarios import Regime, SweepGrid, get_scenario
from repro.core.swarm import (
    NodeSpec,
    SwarmConfig,
    lane_for_nodes,
    make_swarm,
    run_campaign,
    stack_lanes,
)
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem


# ----------------------------- graph layer -------------------------------------
@pytest.mark.parametrize("name", sorted(topology.TOPOLOGIES))
def test_mixing_matrices_doubly_stochastic(name):
    """Every registered topology yields a symmetric, nonnegative,
    doubly-stochastic Metropolis matrix with a positive spectral gap."""
    w = topology.mixing_matrix(name, 16, seed=0)
    assert w.shape == (16, 16)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    assert (w >= -1e-12).all()
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9)
    gap = topology.spectral_gap(w)
    assert 0.0 < gap <= 1.0 + 1e-9, name


def test_ring_gap_matches_closed_form():
    """Metropolis ring: W = 1/3 on the cycle, so λ₂ = 1/3 + 2/3·cos(2π/n)."""
    n = 12
    gap = topology.spectral_gap(topology.mixing_matrix("ring", n))
    expected = 1.0 - (1 / 3 + 2 / 3 * np.cos(2 * np.pi / n))
    np.testing.assert_allclose(gap, expected, rtol=1e-9)


def test_fully_connected_gap_is_one():
    # W = J/n: one gossip round is the exact mean
    assert topology.spectral_gap(
        topology.mixing_matrix("fully_connected", 8)) == pytest.approx(1.0)


def test_clustered_gap_below_ring_gap():
    ring = topology.spectral_gap(topology.mixing_matrix("ring", 16))
    clustered = topology.spectral_gap(topology.mixing_matrix("clustered", 16))
    assert 0.0 < clustered < ring


def test_torus_degree_and_connectivity():
    adj = topology.torus_adjacency(16)                 # 4x4
    assert (adj.sum(1) == 4).all()
    assert topology.is_connected(adj)
    assert topology.is_connected(topology.torus_adjacency(13))  # prime -> ring


def test_random_regular_connected_across_seeds():
    """Regression: duplicate ring-perm edges used to silently yield
    disconnected or under-degree graphs; now every draw is validated and
    redrawn."""
    for seed in range(12):
        adj = topology.random_regular_adjacency(24, 4, seed=seed)
        assert topology.is_connected(adj), seed
        assert not adj.diagonal().any()
        np.testing.assert_array_equal(adj, adj.T)
        assert adj.sum(1).min() >= 2 and adj.sum(1).max() <= 4


def test_consensus_decays_at_spectral_gap_rate():
    """Gossip contracts the mean-orthogonal component by exactly (1-gap)
    per round (Frobenius norm) — the geometric-decay invariant."""
    for name in ("ring", "torus", "random_regular"):
        w = topology.mixing_matrix(name, 16, seed=1)
        gap = topology.spectral_gap(w)
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        dev0 = np.linalg.norm(np.asarray(x) - np.asarray(x).mean(0))
        for rounds in (5, 15):
            out = np.asarray(gossip.gossip_average(x, jnp.asarray(w), rounds))
            dev = np.linalg.norm(out - out.mean(0))
            assert dev <= dev0 * (1 - gap) ** rounds * 1.01 + 1e-7, name


def test_rounds_for_tolerance_clamped_nonnegative():
    """Regression: tol >= 1 returned *negative* round counts (-3 for tol=2
    on an 8-ring); round 0 already satisfies it."""
    w = topology.mixing_matrix("ring", 8)
    assert gossip.rounds_for_tolerance(w, 2.0) == 0
    assert gossip.rounds_for_tolerance(w, 1.0) == 0
    assert gossip.rounds_for_tolerance(w, 1e-3) > 0


def test_rounds_for_tolerance_disconnected_raises():
    """Regression: a zero-gap (disconnected) graph returned a silent 10**9
    sentinel; consensus is impossible, so that is now a ValueError."""
    a = np.zeros((8, 8), bool)
    a[:4, :4] = topology.ring_adjacency(4)             # two disjoint rings
    a[4:, 4:] = topology.ring_adjacency(4)
    w = topology.metropolis_weights(a)
    with pytest.raises(ValueError, match="spectral gap"):
        gossip.rounds_for_tolerance(w, 1e-3)


def test_time_varying_mixing_every_slice_valid():
    stack = topology.time_varying_mixing("random_regular", 12, 5, seed=3)
    assert stack.shape == (5, 12, 12)
    for t in range(5):
        np.testing.assert_allclose(stack[t].sum(1), 1.0, atol=1e-9)
        np.testing.assert_allclose(stack[t], stack[t].T, atol=1e-12)
    # fresh draws: not all rounds share one graph
    assert any(not np.allclose(stack[0], stack[t]) for t in range(1, 5))


def test_churn_coupled_mixing_isolates_inactive_nodes():
    w = topology.mixing_matrix("ring", 6)
    joins = np.array([0, 0, 0, 0, 2, 0])
    leaves = np.array([10, 10, 1, 10, 10, 10])
    stack = topology.churn_coupled_mixing(w, joins, leaves, rounds=3)
    for t in range(3):
        np.testing.assert_allclose(stack[t].sum(1), 1.0, atol=1e-12)
        np.testing.assert_allclose(stack[t], stack[t].T, atol=1e-12)
    # node 4 inactive until round 2, node 2 gone after round 0
    np.testing.assert_allclose(stack[0][4], np.eye(6)[4])
    np.testing.assert_allclose(stack[1][2], np.eye(6)[2])
    assert stack[2][4].max() < 1.0                     # mixing once joined
    assert stack[0][2].max() < 1.0                     # mixed before leaving


def test_unknown_topology_names_registered_ones():
    with pytest.raises(KeyError, match="registered"):
        topology.get_topology("moebius")


# ------------------- decentralized round == centralized (K_n) ------------------
@pytest.mark.parametrize("scenario", [
    "sign_flip_minority",
    "audit_heavy",
    "high_churn_elastic",
    "heterogeneous_speed",
])
def test_fully_connected_decentralized_matches_centralized(scenario):
    """On a complete graph every neighborhood is global and every replica
    identical, so the decentralized round must reproduce the centralized
    engine: same history, same caught sets, same minted balances."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes, cfg = get_scenario(scenario).build(n_nodes=8, seed=0)
    dcfg = dataclasses.replace(cfg, topology="fully_connected")
    opt = lambda: SGD(lr=0.1, momentum=0.0)
    cen = make_swarm(loss_fn, params0, opt(), nodes, cfg, data_fn)
    dec = make_swarm(loss_fn, params0, opt(), nodes, dcfg, data_fn)
    for r in range(12):
        cen.step(r)
        dec.step(r)
    assert [h["n_active"] for h in dec.history] == \
        [h["n_active"] for h in cen.history]
    assert [h["caught"] for h in dec.history] == \
        [h["caught"] for h in cen.history]
    np.testing.assert_allclose(
        [h["agg_norm"] for h in dec.history],
        [h["agg_norm"] for h in cen.history], rtol=2e-3, atol=1e-5,
        err_msg=scenario)
    assert all(h["consensus_error"] < 1e-4 for h in dec.history)
    assert dec.ledger.balances == pytest.approx(cen.ledger.balances)
    assert dec.ledger.burned_stake == pytest.approx(cen.ledger.burned_stake)


def test_decentralized_ring_disagrees_then_converges():
    """A sparse graph shows real replica disagreement (consensus_error > 0)
    that gossip drives down; the consensus params still learn."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarm = get_scenario("gossip_ring_honest").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    losses = swarm.run(40, eval_fn=eval_fn)
    errs = [h["consensus_error"] for h in swarm.history]
    assert max(errs) > 1e-4                            # genuine disagreement
    assert errs[-1] < max(errs)                        # gossip contracts it
    assert losses[-1] < 0.1 * losses[0]                # consensus learns


def test_decentralized_scanned_run_matches_step_loop():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    mk = lambda: get_scenario("byzantine_neighborhood").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=8)
    scanned, stepped = mk(), mk()
    scanned.run(10)
    for r in range(10):
        stepped.step(r)
    np.testing.assert_allclose(
        [h["agg_norm"] for h in scanned.history],
        [h["agg_norm"] for h in stepped.history], rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(
        [h["consensus_error"] for h in scanned.history],
        [h["consensus_error"] for h in stepped.history], rtol=1e-4, atol=1e-7)


def test_churn_coupled_engine_freezes_leaver_replica():
    """SwarmConfig.churn_coupled couples the mixing graph to the roster's
    join/leave schedule: a departed node's replica freezes (isolated
    self-loop) instead of relaying forever, and consensus_error — which
    only counts active replicas — stays clean."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(5)] + \
        [NodeSpec("leaver", leave_round=3)]
    cfg = SwarmConfig(aggregator="mean", topology="ring", churn_coupled=True)
    swarm = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                       nodes, cfg, data_fn)
    snap = None
    for r in range(8):
        swarm.step(r)
        if r == 3:
            snap = np.asarray(swarm.params["w"][5]).copy()
    frozen = np.asarray(swarm.params["w"][5])
    np.testing.assert_array_equal(frozen, snap)        # replica froze at leave
    moving = np.asarray(swarm.params["w"][0])
    assert np.abs(moving - frozen).max() > 1e-6        # active kept training
    assert all(np.isfinite(h["consensus_error"]) for h in swarm.history)

    # default (static mixing): the departed replica keeps mixing and moves
    loose = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                       SwarmConfig(aggregator="mean", topology="ring"),
                       data_fn)
    for r in range(8):
        loose.step(r)
    assert np.abs(np.asarray(loose.params["w"][5]) - frozen).max() > 1e-6


def test_sequential_engine_rejects_topology():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    with pytest.raises(ValueError, match="centralized-only"):
        make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                   [NodeSpec("h0"), NodeSpec("h1")],
                   SwarmConfig(aggregator="mean", topology="ring"), data_fn,
                   engine="sequential")


# ------------------------- the §5.5 topology axis ------------------------------
def test_topology_axis_sweep_is_one_program():
    """Acceptance: >= 2 topologies x >= 3 attacker fractions x >= 2 seeds
    compile to ONE device program via run_campaign, with per-topology
    baselines and a rendered phase table."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = SweepGrid(
        name="topo", description="", n_honest=6,
        attacker_counts=(1, 2, 4), seeds=(0, 1), rounds=8,
        regimes=(Regime("mean", "mean"),
                 Regime("centered_clip", "centered_clip")),
        topologies=("ring", "fully_connected"))
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)
    assert res.n_programs == 1
    assert len(res.results) == grid.n_points == 24
    assert res.n_runs == 24 + 2 * 2                    # + (topo, seed) baselines
    assert {r.topology for r in res.results} == {"ring", "fully_connected"}
    assert all(np.isfinite(r.final_loss) for r in res.results)
    assert all(np.isfinite(r.baseline_loss) for r in res.results)
    table = res.phase_table()
    assert "mean@ring" in table and "centered_clip@fully_connected" in table
    # K_n decentralized == centralized algebra: mean derails, CC holds at 25%
    by = {(r.regime, r.topology, r.n_attackers): r for r in res.results}
    assert by[("mean", "fully_connected", 2)].derailed
    assert not by[("centered_clip", "fully_connected", 2)].derailed


def test_sweep_max_count_cell_matches_simulate_derailment():
    """At count == max(attacker_counts) the sweep lane's graph and the
    single-point path's graph coincide (same size, same topology_seed=0
    draw), so the decentralized cell must reproduce
    simulate_derailment(topology=...) — including the same-size-graph
    baseline (attacker slots as never-joining relays)."""
    from repro.core.derailment import simulate_derailment
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = SweepGrid(
        name="parity", description="", n_honest=6, attacker_counts=(3,),
        seeds=(0,), rounds=8,
        regimes=(Regime("centered_clip", "centered_clip"),),
        topologies=("ring",))
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)
    (cell,) = res.results
    single = simulate_derailment(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, eval_fn,
        n_honest=6, n_attack=3, rounds=8, aggregator="centered_clip",
        topology="ring", seed=0)
    np.testing.assert_allclose(cell.final_loss, single.final_loss, rtol=2e-3)
    np.testing.assert_allclose(cell.baseline_loss, single.baseline_loss,
                               rtol=2e-3)
    assert cell.derailed == single.derailed


def test_time_varying_mixing_lane_runs_in_campaign():
    """A (T, N, N) churn-coupled mixing stack rides through the scanned
    round (indexed by round % T) without retracing."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(6)]
    lane = lane_for_nodes(nodes, SwarmConfig(aggregator="mean", seed=0))
    stack = topology.time_varying_mixing("random_regular", 6, 4, seed=0)
    lane = lane._replace(mixing=jnp.asarray(stack, jnp.float32))
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    state, recs, final = run_campaign(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
        stack_lanes([lane]), rounds=10, aggregator="mean", eval_fn=eval_fn)
    assert np.isfinite(np.asarray(final)).all()
    assert np.asarray(recs.consensus_err).shape == (1, 10)
    assert np.isfinite(np.asarray(recs.consensus_err)).all()
