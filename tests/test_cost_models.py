"""Dedicated unit tests for the launch-layer cost models on tiny programs
with hand-computable numbers.

``test_launch.py`` exercises ``analyze_hlo`` end-to-end on real XLA-lowered
programs; here the HLO text is *synthetic* so every expected FLOP/byte count
is exact by construction — parser regressions show up as precise numeric
diffs, not tolerance drift.  The roofline half pins the ring wire-byte
model, the three roofline terms, and the small formatting/model-FLOP
helpers used by the dry-run reports and ``bench_round_fused``.
"""
import pytest

from repro.launch.hlo_cost import (
    HloCost, analyze_hlo, parse_module, _multiplicities, _wire_bytes)
from repro.launch import roofline
from repro.launch.roofline import (
    CollectiveOp, Roofline, collective_summary, fmt_seconds, model_flops,
    parse_collectives)


# ------------------------------ synthetic HLO ----------------------------------
DOT_HLO = """\
HloModule tiny_dot

ENTRY %main (a: f32[4,8], b: f32[8,16]) -> f32[4,16] {
  %a = f32[4,8]{1,0} parameter(0)
  %b = f32[8,16]{1,0} parameter(1)
  ROOT %dot = f32[4,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

LOOP_HLO = """\
HloModule tiny_loop

%body (p: (s32[], f32[4,8], f32[8,8])) -> (s32[], f32[4,8], f32[8,8]) {
  %p = (s32[], f32[4,8], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} get-tuple-element(%p), index=2
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %y = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,8], f32[8,8]) tuple(%ip, %y, %w)
}

%cond (p: (s32[], f32[4,8], f32[8,8])) -> pred[] {
  %p = (s32[], f32[4,8], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,8], w: f32[8,8]) -> (s32[], f32[4,8], f32[8,8]) {
  %x = f32[4,8]{1,0} parameter(0)
  %w = f32[8,8]{1,0} parameter(1)
  %z = s32[] constant(0)
  %init = (s32[], f32[4,8], f32[8,8]) tuple(%z, %x, %w)
  ROOT %wh = (s32[], f32[4,8], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""

COLL_HLO = """\
HloModule tiny_coll

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[256]) -> f32[256] {
  %x = f32[256]{0} parameter(0)
  ROOT %ar = f32[256]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_parse_module_symbols_and_entry():
    comps, entry = parse_module(DOT_HLO)
    assert entry == "main"
    main = comps["main"]
    assert main.symbols["a"] == [("f32", (4, 8))]
    assert main.symbols["dot"] == [("f32", (4, 16))]
    kinds = {op.kind for op in main.ops}
    assert kinds == {"parameter", "dot"}
    dot = next(op for op in main.ops if op.kind == "dot")
    assert dot.operands == ["a", "b"]


def test_dot_program_exact_flops_and_bytes():
    cost = analyze_hlo(DOT_HLO, total_devices=1)
    # 2 * numel(result) * contracting dim
    assert cost.flops == 2 * (4 * 16) * 8
    # dot is the only materializing op: operands + result
    assert cost.bytes_accessed == (4 * 8 + 8 * 16 + 4 * 16) * 4
    assert cost.dots == 1
    assert cost.wire_bytes == 0.0


def test_loop_multiplicities_and_trip_scaled_flops():
    comps, entry = parse_module(LOOP_HLO)
    mult = _multiplicities(comps, entry)
    assert mult["main"] == 1.0
    assert mult["body"] == 7.0
    assert mult["cond"] == 7.0
    cost = analyze_hlo(LOOP_HLO, total_devices=1)
    assert cost.flops == 7 * 2 * (4 * 8) * 8


def test_collective_program_ring_wire_bytes():
    cost = analyze_hlo(COLL_HLO, total_devices=1)
    # replica_groups={{0,1,2,3}} overrides total_devices: n = 4
    result_bytes = 256 * 4
    assert cost.wire_bytes == pytest.approx(2 * result_bytes * 3 / 4)
    (key, agg), = cost.collectives.items()
    assert key == "all-reduce@g4"
    assert agg["count"] == 1.0
    assert agg["wire_bytes"] == pytest.approx(1536.0)
    # the reduction lambda is inlined — its add contributes no bytes
    assert "add" not in {k.split("@")[0] for k in cost.collectives}


def test_collective_without_groups_uses_total_devices():
    hlo = """\
ENTRY %main (x: f32[100]) -> f32[100] {
  %x = f32[100]{0} parameter(0)
  ROOT %cp = f32[100]{0} collective-permute(%x), source_target_pairs={{0,1}}
}
"""
    cost = analyze_hlo(hlo, total_devices=8)
    assert cost.wire_bytes == 400.0     # permute moves the full buffer


def test_no_entry_is_noted_not_crashed():
    cost = analyze_hlo("HloModule empty\n", total_devices=4)
    assert cost.flops == 0.0
    assert cost.notes == ["no ENTRY computation found"]


def test_wire_byte_model_all_kinds():
    b, n = 1000, 4
    assert _wire_bytes("all-gather", b, n) == pytest.approx(750.0)
    assert _wire_bytes("all-reduce", b, n) == pytest.approx(1500.0)
    assert _wire_bytes("reduce-scatter", b, n) == pytest.approx(3000.0)
    assert _wire_bytes("all-to-all", b, n) == pytest.approx(750.0)
    assert _wire_bytes("collective-permute", b, n) == 1000.0
    for kind in ("all-gather", "all-reduce", "reduce-scatter",
                 "all-to-all", "collective-permute"):
        assert _wire_bytes(kind, b, 1) == 0.0


def test_hlo_cost_to_dict_round_trips_fields():
    d = analyze_hlo(DOT_HLO, total_devices=1).to_dict()
    assert set(d) == {"flops", "bytes_accessed", "wire_bytes",
                      "collectives", "dots"}
    assert d["flops"] == 1024.0


# ------------------------------ roofline ---------------------------------------
def test_parse_collectives_explicit_and_iota_groups():
    hlo = """\
  %ag = f32[128,256]{1,0} all-gather-start(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1}}, to_apply=%add
"""
    ops = parse_collectives(hlo, total_devices=16)
    assert [(o.op, o.group_size) for o in ops] == [
        ("all-gather", 4), ("all-reduce", 2)]
    assert ops[0].result_bytes == 128 * 256 * 4
    assert ops[0].wire_bytes == pytest.approx(128 * 256 * 4 * 3 / 4)
    assert ops[1].wire_bytes == pytest.approx(2 * 64 * 4 * 1 / 2)


def test_collective_summary_aggregates_by_kind():
    ops = [CollectiveOp("all-reduce", 1000, 4),
           CollectiveOp("all-reduce", 1000, 4),
           CollectiveOp("all-gather", 400, 2)]
    s = collective_summary(ops)
    assert s["all-reduce"]["count"] == 2
    assert s["all-reduce"]["wire_bytes"] == pytest.approx(3000.0)
    assert s["all-gather"]["result_bytes"] == 400


def test_roofline_terms_and_dominance():
    r = Roofline(flops_per_device=roofline.PEAK_FLOPS,       # 1 s compute
                 bytes_per_device=0.5 * roofline.HBM_BW,     # 0.5 s memory
                 wire_bytes_per_device=2.0 * roofline.ICI_BW,  # 2 s wire
                 model_flops_global=roofline.PEAK_FLOPS / 2,
                 num_chips=1)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(2.0)
    assert r.dominant == "collective"
    assert r.bound_s == pytest.approx(2.0)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    d = r.to_dict()
    assert d["dominant"] == "collective"
    assert d["bytes_per_device"] == pytest.approx(0.5 * roofline.HBM_BW)


def test_roofline_zero_flops_ratio_guard():
    r = Roofline(flops_per_device=0.0, bytes_per_device=1.0,
                 wire_bytes_per_device=0.0, model_flops_global=1e12)
    assert r.useful_flops_ratio == 0.0


def test_model_flops_per_shape_kind():
    class Cfg:
        def active_param_count(self):
            return 100
        def param_count(self):
            return 400

    class Shape:
        def __init__(self, kind):
            self.kind = kind
            self.global_batch = 8
            self.seq_len = 32

    cfg = Cfg()
    assert model_flops(cfg, Shape("train")) == 6.0 * 100 * 8 * 32
    assert model_flops(cfg, Shape("prefill")) == 2.0 * 100 * 8 * 32
    assert model_flops(cfg, Shape("decode")) == 2.0 * 100 * 8
    assert model_flops(cfg, Shape("train"), active=False) == 6.0 * 400 * 8 * 32


def test_fmt_seconds_units():
    assert fmt_seconds(2.5).strip() == "2.50s"
    assert fmt_seconds(3.2e-3).strip() == "3.20ms"
    assert fmt_seconds(4.5e-6).strip() == "4.50us"
