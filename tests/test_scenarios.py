"""Batched engine + scenario registry coverage.

Three layers:
1. masked aggregators == dense aggregators on the kept subset (the algebra
   the batched engine's fixed-shape round rests on);
2. the batched engine is *equivalent* to the sequential reference: same seed
   -> same agg_norm history (fp32 tolerance), same slash decisions, same
   active counts — honest, byzantine, churn, compressed, and audited runs;
3. every registered scenario builds and runs on the batched engine.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.core.scenarios import (
    SCENARIOS,
    batched_data_fn_for,
    get_scenario,
    list_scenarios,
)
from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem


# ------------------------- masked aggregator algebra ---------------------------
AGG_CASES = [
    ("mean", {}),
    ("median", {}),
    ("trimmed_mean", {"trim": 2}),
    ("krum", {"f": 1}),
    ("multi_krum", {"f": 1}),
    ("centered_clip", {"iters": 3}),
    ("centered_clip", {"clip_tau": 1.0, "iters": 3}),
]


@pytest.mark.parametrize("name,kwargs", AGG_CASES)
def test_masked_aggregator_matches_dense_subset(name, kwargs):
    rng = np.random.default_rng(0)
    for trial in range(4):
        x = jnp.asarray(rng.normal(size=(12, 17)).astype(np.float32))
        mask = rng.random(12) < 0.7
        mask[0] = True                               # never fully empty
        dense = aggregation.get_aggregator(name, **kwargs)(x[mask])
        masked = jax.jit(
            lambda x, m: aggregation.get_masked_aggregator(name, **kwargs)(x, m)
        )(x, jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(masked), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{name} trial {trial}")


def test_masked_krum_single_survivor_never_picks_masked_row():
    """Regression: with one kept node every krum score is +inf, and argmin
    must still land on the kept row, not a slashed byzantine one."""
    x = jnp.asarray([[100.0] * 3, [1.0] * 3, [2.0] * 3])
    mask = jnp.asarray([False, True, False])
    out = aggregation.masked_krum(x, mask, f=1)
    np.testing.assert_allclose(np.asarray(out), [1.0, 1.0, 1.0])


def test_masked_multi_krum_clamps_static_m_to_kept_count():
    """Regression: m larger than the kept count must not average the
    masked-out rows (real corrupted updates) into the aggregate."""
    x = jnp.asarray([[100.0] * 3, [1.0] * 3, [3.0] * 3])
    mask = jnp.asarray([False, True, True])
    out = aggregation.masked_multi_krum(x, mask, f=0, m=3)
    np.testing.assert_allclose(np.asarray(out), [2.0, 2.0, 2.0])


# ------------------------- engine equivalence ----------------------------------
def _run_both(nodes, cfg, rounds=15):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarms = {}
    for engine in ("sequential", "batched"):
        s = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                       nodes, cfg, data_fn, engine=engine)
        s.run(rounds)
        swarms[engine] = s
    return swarms["sequential"], swarms["batched"]


def _assert_equivalent(seq, bat):
    assert [r["n_active"] for r in seq.history] == \
        [r["n_active"] for r in bat.history]
    assert [r["caught"] for r in seq.history] == \
        [r["caught"] for r in bat.history]
    assert seq.slashed == bat.slashed
    a_seq = np.array([r["agg_norm"] for r in seq.history])
    a_bat = np.array([r["agg_norm"] for r in bat.history])
    np.testing.assert_allclose(a_bat, a_seq, rtol=2e-3, atol=1e-5)
    # balances mint identically (speed-weighted verified work)
    assert seq.ledger.balances == pytest.approx(bat.ledger.balances)


def test_batched_matches_sequential_honest():
    nodes = [NodeSpec(f"h{i}") for i in range(8)]
    _assert_equivalent(*_run_both(nodes, SwarmConfig(aggregator="mean")))


@pytest.mark.parametrize("aggregator,kwargs", [
    ("centered_clip", {"clip_tau": 1.0, "iters": 3}),
    ("centered_clip", {}),
    ("median", {}),
    ("trimmed_mean", {"trim": 2}),
    ("krum", {"f": 2}),
    ("multi_krum", {"f": 2}),
])
def test_batched_matches_sequential_byzantine(aggregator, kwargs):
    nodes = [NodeSpec(f"h{i}") for i in range(6)] + [
        NodeSpec("adv0", byzantine="sign_flip", byzantine_scale=20.0),
        NodeSpec("adv1", byzantine="inner_product", byzantine_scale=10.0),
    ]
    cfg = SwarmConfig(aggregator=aggregator, agg_kwargs=kwargs)
    _assert_equivalent(*_run_both(nodes, cfg))


def test_batched_matches_sequential_noise_attack():
    """'noise' draws randomness — the shared fold_in key schedule makes the
    realization identical across engines."""
    nodes = [NodeSpec(f"h{i}") for i in range(7)] + \
        [NodeSpec("nz", byzantine="noise", byzantine_scale=5.0)]
    _assert_equivalent(*_run_both(nodes, SwarmConfig(aggregator="centered_clip")))


@pytest.mark.parametrize("compression,kwargs", [
    ("qsgd", {"levels": 64}),
    ("topk", {"k_frac": 0.25}),
])
def test_batched_matches_sequential_compressed_wire(compression, kwargs):
    nodes = [NodeSpec(f"h{i}") for i in range(6)]
    cfg = SwarmConfig(aggregator="mean", compression=compression,
                      compression_kwargs=kwargs)
    _assert_equivalent(*_run_both(nodes, cfg))


def test_batched_matches_sequential_verification():
    vcfg = VerificationConfig(p_check=0.4, stake=5.0, tolerance=1e-3)
    nodes = [NodeSpec(f"h{i}") for i in range(5)] + \
        [NodeSpec("cheat", byzantine="zero")]
    cfg = SwarmConfig(aggregator="mean", verification=vcfg)
    seq, bat = _run_both(nodes, cfg, rounds=20)
    _assert_equivalent(seq, bat)
    assert bat.slashed == {"cheat"}


# ------------------------- active-mask / churn ---------------------------------
def test_active_mask_tracks_join_leave():
    nodes = [NodeSpec("h0"), NodeSpec("h1"),
             NodeSpec("late", join_round=5),
             NodeSpec("early", leave_round=8),
             NodeSpec("window", join_round=3, leave_round=12)]
    cfg = SwarmConfig(aggregator="mean")
    seq, bat = _run_both(nodes, cfg, rounds=15)
    _assert_equivalent(seq, bat)
    expected = [sum(1 for n in nodes if n.active(r)) for r in range(15)]
    assert [r["n_active"] for r in bat.history] == expected
    # members outside their window never mint shares for those rounds
    assert bat.ledger.balances["late"] == pytest.approx(10.0)     # rounds 5..14
    assert bat.ledger.balances["early"] == pytest.approx(8.0)     # rounds 0..7


def test_batched_round_compiles_once_despite_churn():
    """The fixed-shape claim: join/leave/slash only flips mask bits — the
    jitted per-round path must not retrace."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"c{i}") for i in range(3)] + \
        [NodeSpec(f"w{i}", join_round=2 + i, leave_round=6 + 2 * i)
         for i in range(5)]
    swarm = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                       SwarmConfig(aggregator="centered_clip"), data_fn)
    for r in range(20):
        swarm.step(r)
    if not hasattr(swarm._round_fn, "_cache_size"):
        pytest.skip("this jax exposes no jit cache-size introspection — "
                    "the no-recompile claim is unverifiable here")
    assert swarm._round_fn._cache_size() == 1


def test_scanned_run_is_one_program_and_matches_step_loop():
    """run() with no eval_fn dispatches the scanned core: one compiled
    program for the whole run, identical history/ledger to the step loop."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(5)] + [
        NodeSpec("adv", byzantine="sign_flip", byzantine_scale=20.0),
        NodeSpec("late", join_round=4),
    ]
    cfg = SwarmConfig(aggregator="centered_clip")
    mk = lambda: make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                            nodes, cfg, data_fn)
    scanned, stepped = mk(), mk()
    scanned.run(15)
    for r in range(15):
        stepped.step(r)
    assert [r["n_active"] for r in scanned.history] == \
        [r["n_active"] for r in stepped.history]
    np.testing.assert_allclose(
        [r["agg_norm"] for r in scanned.history],
        [r["agg_norm"] for r in stepped.history], rtol=1e-5, atol=1e-7)
    assert scanned.ledger.balances == pytest.approx(stepped.ledger.balances)
    if hasattr(scanned._round_fn, "_cache_size"):
        assert scanned._round_fn._cache_size() == 0     # never used per-round
        (scan_fn,) = scanned._scan_cache.values()
        assert scan_fn._cache_size() == 1


def test_make_swarm_rejects_batched_data_fn_on_sequential():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    with pytest.raises(ValueError, match="batched_data_fn"):
        make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                   [NodeSpec("h0")], SwarmConfig(aggregator="mean"), data_fn,
                   engine="sequential",
                   batched_data_fn=batched_data_fn_for(data_fn, 1))


def test_no_active_nodes_raises():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec("late", join_round=5)]
    swarm = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                       SwarmConfig(aggregator="mean"), data_fn)
    with pytest.raises(RuntimeError, match="no active nodes"):
        swarm.step(0)


def test_batched_data_fn_matches_stacking():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(6)]
    cfg = SwarmConfig(aggregator="mean")
    plain = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                       nodes, cfg, data_fn)
    fused = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                       nodes, cfg, data_fn,
                       batched_data_fn=batched_data_fn_for(data_fn, len(nodes)))
    plain.run(10)
    fused.run(10)
    np.testing.assert_allclose(
        [r["agg_norm"] for r in fused.history],
        [r["agg_norm"] for r in plain.history], rtol=1e-6)


# ------------------------- scenario registry -----------------------------------
def test_registry_has_the_documented_scenarios():
    assert set(list_scenarios()) == {
        "honest_baseline", "sign_flip_minority", "inner_product_collusion",
        "high_churn_elastic", "heterogeneous_speed", "compressed_wire",
        "audit_heavy", "derailment_stress",
        "gossip_ring_honest", "byzantine_neighborhood", "partitioned_swarm",
        "straggler_majority", "stale_poisoning", "async_churn",
        "custody_leech", "custody_churn_collapse",
        "economy_rational", "economy_sybil_adaptive",
    }


def test_get_scenario_unknown_name():
    with pytest.raises(KeyError, match="registered"):
        get_scenario("nope")


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_builds_and_runs(name):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    scn = get_scenario(name)
    nodes, cfg = scn.build(n_nodes=8, seed=0)
    assert len(nodes) == 8
    assert len({n.node_id for n in nodes}) == 8          # ids unique
    assert any(n.active(0) and not n.byzantine for n in nodes)
    swarm = scn.build_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                            data_fn, n_nodes=8)
    swarm.run(12)
    assert len(swarm.history) == 12
    assert all(np.isfinite(r["agg_norm"]) for r in swarm.history)
    assert all(r["n_active"] >= 1 for r in swarm.history)


def test_honest_baseline_converges():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarm = get_scenario("honest_baseline").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    losses = swarm.run(40, eval_fn=eval_fn)
    assert losses[-1] < 0.05 * losses[0]


def test_audit_heavy_slashes_freeloaders():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarm = get_scenario("audit_heavy").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=8)
    swarm.run(25)
    byz = {n.node_id for n in swarm.nodes if n.byzantine}
    assert swarm.slashed == byz                       # all freeloaders caught
    assert swarm.ledger.burned_stake > 0


def test_scenarios_scale_and_reproduce():
    scn = get_scenario("sign_flip_minority")
    for n in (4, 16, 33):
        nodes, _ = scn.build(n_nodes=n)
        assert len(nodes) == n
        assert sum(1 for x in nodes if x.byzantine) == max(1, n // 4)
    a, _ = scn.build(n_nodes=9, seed=3)
    b, _ = scn.build(n_nodes=9, seed=3)
    assert a == b


def test_scenario_config_is_immutable():
    cfg = get_scenario("audit_heavy").build(n_nodes=8)[1]
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.aggregator = "mean"
