"""Economy engine coverage (paper §4): stake markets, Sybil pressure, and
adaptive adversaries as campaign axes.

The load-bearing pins:

- the batched economy round reproduces the :class:`SequentialEconomy` host
  oracle (admission counts, caught sets, stake/balance trajectories, params)
  for both fixed-behaviour and best-response coalitions;
- an economy sweep cell reproduces the single-run engine for the same knobs
  (lane == run, the campaign-engine contract extended to the econ axes);
- the conservation identity holds on device, at init and through rounds,
  and projects onto a conserved host :class:`Ledger` (``ledger_view``);
- the adaptive-vs-fixed gap is *measurable*: on the registered smoke grid
  the best-response coalition derails the weakly-defended (mean) regime
  that the fixed scale-2 attack cannot touch, and robust aggregation
  closes the gap (ISSUE acceptance).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, economy
from repro.core.economy import (
    ADAPTIVE_SCALES,
    EconomyConfig,
    SequentialEconomy,
    admitted_mask,
    classify_outcome,
    conservation_gap,
    econ_round_update,
    init_econ_state,
    ledger_view,
    payoff,
)
from repro.core.derailment import sweep
from repro.core.scenarios import Regime, SweepGrid, get_scenario, get_sweep_grid
from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem


# ============================ pure device economy ==============================
def test_sybil_funding_formula():
    """init_econ_state resolves the Sybil knob in-program: the budget buys
    floor(budget / (identity_cost + min_stake)) identities (capped by the
    coalition size), leftover capital tops funded stakes up equally, and
    unfunded slots are born dead."""
    coal = np.array([False] * 4 + [True] * 4)
    # rich budget: all 4 funded, leftover 50 - 4*6 = 26 splits 6.5 each
    st0 = init_econ_state(
        EconomyConfig(identity_cost=1.0, budget=50.0, min_stake=5.0)
        .params_for(coal), 8)
    np.testing.assert_allclose(np.asarray(st0.stake)[:4], 5.0)
    np.testing.assert_allclose(np.asarray(st0.stake)[4:], 11.5)
    assert np.asarray(st0.alive).all()
    assert float(conservation_gap(st0)) < 1e-4

    # tight budget: identities cost 9 apiece, 10 buys exactly one (+1 top-up)
    ep = EconomyConfig(identity_cost=4.0, budget=10.0,
                       min_stake=5.0).params_for(coal)
    st1 = init_econ_state(ep, 8)
    stake, alive = np.asarray(st1.stake), np.asarray(st1.alive)
    np.testing.assert_allclose(stake[4], 6.0)
    assert (stake[5:] == 0.0).all()
    assert alive[:5].all() and not alive[5:].any()
    assert not np.asarray(admitted_mask(ep, st1))[5:].any()
    assert float(conservation_gap(st1)) < 1e-4


def test_round_update_jackpot_pool_capped_and_conserved():
    """A catch slashes the stake into the pool and the jackpot is paid FROM
    that pool, capped by it — a validator can never earn more than the
    cheater forfeited — and the whole flow conserves."""
    coal = np.array([False, False, True])
    ep = EconomyConfig(identity_cost=1.0, budget=6.0, min_stake=5.0,
                       fee_income=1.0, reward_rate=0.1, op_cost=0.0,
                       jackpot=9.0).params_for(coal)
    st0 = init_econ_state(ep, 3)
    active = jnp.ones(3, bool)
    caught = jnp.array([False, False, True])
    st1 = econ_round_update(ep, st0, active=active, keep=active & ~caught,
                            caught=caught, speeds=jnp.ones(3))
    assert float(st1.validator_income) == pytest.approx(5.0)   # not 9
    assert float(st1.slash_pool) == pytest.approx(0.0)
    assert float(np.asarray(st1.stake)[2]) == 0.0
    assert float(conservation_gap(st1)) < 1e-4


def test_death_spiral_is_absorbing():
    """A node that cannot cover its operating cost exits for good: alive
    drops, admission never readmits it, and the books stay balanced."""
    ep = EconomyConfig(identity_cost=0.0, budget=0.0, min_stake=5.0,
                       fee_income=0.0, reward_rate=0.0, op_cost=10.0,
                       honest_reserve=1.0).params_for(np.zeros(2, bool))
    st = init_econ_state(ep, 2)
    active = admitted_mask(ep, st)
    assert np.asarray(active).all()
    st = econ_round_update(ep, st, active=active, keep=active,
                           caught=jnp.zeros(2, bool), speeds=jnp.ones(2))
    assert not np.asarray(st.alive).any()          # cost 10 > afford 6
    active = admitted_mask(ep, st)
    assert not np.asarray(active).any()            # never readmitted
    st = econ_round_update(ep, st, active=active, keep=active,
                           caught=jnp.zeros(2, bool), speeds=jnp.ones(2))
    assert not np.asarray(st.alive).any()
    assert float(conservation_gap(st)) < 1e-4
    # everyone under water: sunk bonds were drained by op costs
    assert (np.asarray(payoff(st)) < 0).all()


def test_classify_outcome_priority():
    """captured > death_spiral > sustained, on the documented conditions."""
    base = dict(honest_active_first=8, honest_active_last=8,
                coalition_stake_last=0.0, honest_payoff_mean=1.0)
    assert classify_outcome(**base) == "sustained"
    assert classify_outcome(**{**base, "coalition_stake_last": 0.6}) \
        == "captured"
    assert classify_outcome(**{**base, "honest_active_last": 3}) \
        == "death_spiral"
    assert classify_outcome(**{**base, "honest_payoff_mean": -0.1}) \
        == "death_spiral"
    # capture trumps collapse
    assert classify_outcome(**{**base, "coalition_stake_last": 0.9,
                               "honest_active_last": 0}) == "captured"
    assert set(economy.OUTCOMES) == {"captured", "death_spiral", "sustained"}


def test_best_response_maximizes_damage_vs_mean():
    """Against an undefended mean the menu is monotone in scale — the
    best response is the largest scale; a tiny coalition against
    centered_clip picks SOME menu entry (the argmax is total)."""
    hm = jnp.ones(8)
    gf = jnp.broadcast_to(hm, (4, 8))
    coal = jnp.array([False, False, True, True])
    mask = jnp.ones(4, bool)
    s = float(economy.best_response_scale(
        aggregation.get_masked_aggregator("mean"), gf, hm, coal, mask))
    assert s == max(ADAPTIVE_SCALES)
    s2 = float(economy.best_response_scale(
        aggregation.get_masked_aggregator("centered_clip"), gf, hm, coal,
        mask))
    assert s2 in ADAPTIVE_SCALES


# ====================== batched engine vs host oracle ==========================
def _econ_roster():
    return ([NodeSpec(f"h{i}", speed=s)
             for i, s in enumerate((1.0, 1.0, 0.5, 2.0))]
            + [NodeSpec(f"adv{i}", byzantine="inner_product",
                        byzantine_scale=2.0) for i in range(2)])


@pytest.mark.parametrize("adaptive,aggregator",
                         [(False, "centered_clip"), (True, "mean")])
def test_batched_economy_matches_sequential_oracle(adaptive, aggregator):
    """The tentpole pin: the scanned batched economy round ≡ the readable
    per-node SequentialEconomy oracle — admission counts, caught sets,
    coalition stake share, aggregate norms, final params, and every field
    of the economic state — for fixed AND best-response coalitions."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = _econ_roster()
    cfg = SwarmConfig(
        aggregator=aggregator,
        verification=VerificationConfig(p_check=0.5, stake=5.0,
                                        tolerance=1e-3, jackpot=5.0),
        economy=EconomyConfig(identity_cost=0.5, budget=12.0, min_stake=5.0,
                              fee_income=1.0, reward_rate=0.1, op_cost=0.05,
                              jackpot=5.0, honest_reserve=1.0,
                              adaptive=adaptive),
        seed=0)
    opt = SGD(lr=0.1, momentum=0.0)
    sw = make_swarm(loss_fn, params0, opt, nodes, cfg, data_fn)
    sw.run(6)                                       # the scanned path
    oracle = SequentialEconomy(loss_fn, params0, opt, nodes, cfg, data_fn)
    oracle.run(6)

    for key in ("n_active", "caught"):
        assert [h[key] for h in sw.history] == \
            [h[key] for h in oracle.history], key
    np.testing.assert_allclose(
        [h["coalition_stake"] for h in sw.history],
        [h["coalition_stake"] for h in oracle.history], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        [h["agg_norm"] for h in sw.history],
        [h["agg_norm"] for h in oracle.history], rtol=1e-4, atol=1e-6)

    eb, es = sw._econ_state, oracle.econ
    for name in ("stake", "balance", "pending", "capital_in"):
        np.testing.assert_allclose(np.asarray(getattr(eb, name)),
                                   np.asarray(getattr(es, name)),
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    np.testing.assert_array_equal(np.asarray(eb.alive), np.asarray(es.alive))
    for name in ("minted", "fees_in", "burned", "slash_pool",
                 "validator_income"):
        assert float(getattr(eb, name)) == pytest.approx(
            float(getattr(es, name)), rel=1e-4, abs=1e-5), name
    np.testing.assert_allclose(np.asarray(sw.params["w"]),
                               np.asarray(oracle.params["w"]),
                               rtol=1e-5, atol=1e-6)
    # the device books balance and project onto a conserved host Ledger
    assert float(conservation_gap(eb)) < 1e-3
    assert ledger_view(eb, [n.node_id for n in nodes]).check_conservation()


def test_sequential_economy_rejects_unsupported_configs():
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    opt = SGD(lr=0.1, momentum=0.0)
    with pytest.raises(ValueError, match="economy"):
        SequentialEconomy(loss_fn, params0, opt, _econ_roster(),
                          SwarmConfig(aggregator="mean"), data_fn)
    with pytest.raises(ValueError, match="centralized"):
        SequentialEconomy(
            loss_fn, params0, opt, _econ_roster(),
            SwarmConfig(aggregator="mean", topology="ring",
                        economy=EconomyConfig()), data_fn)


def test_economy_scenarios_run_on_both_engines():
    """The registered §4 scenarios build and agree across engines on the
    admission trajectory (the economy-aware n_active)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    opt = SGD(lr=0.1, momentum=0.0)
    for name in ("economy_rational", "economy_sybil_adaptive"):
        sc = get_scenario(name)
        nodes, cfg = sc.make_nodes(6), sc.make_config(0)
        sw = make_swarm(loss_fn, params0, opt, nodes, cfg, data_fn)
        sw.run(4)
        oracle = SequentialEconomy(loss_fn, params0, opt, nodes, cfg, data_fn)
        oracle.run(4)
        assert [h["n_active"] for h in sw.history] == \
            [h["n_active"] for h in oracle.history], name


# ============================= sweep integration ===============================
def _quad_sweep(grid, **kw):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                 eval_fn, grid, **kw), (loss_fn, params0, data_fn, eval_fn)


def test_sweep_economy_cell_matches_single_run():
    """Lane == run, extended to the economy axes: each cell of an economy
    sweep reproduces the single-run batched engine (itself pinned to the
    oracle above) for the same knobs — final loss, admission, coalition
    stake share, and honest payoff."""
    audit = VerificationConfig(p_check=0.25, stake=10.0, tolerance=1e-3,
                               jackpot=5.0)
    grid = SweepGrid(
        name="econ-tiny", description="", n_honest=5, attacker_counts=(2,),
        seeds=(0,), scales=(2.0,), rounds=6,
        regimes=(Regime("mean+audit", "mean", verification=audit),),
        identity_costs=(0.5,), fees=(1.0,), reward_schedules=((0.1, 5.0),),
        adaptive=(False, True))
    (res, (loss_fn, params0, data_fn, eval_fn)) = _quad_sweep(grid)
    assert res.n_programs == 1
    assert len(res.results) == len(res.econ_results) == 2
    assert {r.adaptive for r in res.econ_results} == {False, True}

    for dres, eres in zip(res.results, res.econ_results):
        nodes = ([NodeSpec(f"h{i}") for i in range(5)]
                 + [NodeSpec(f"adv{i}", byzantine="inner_product",
                             byzantine_scale=2.0) for i in range(2)])
        cfg = SwarmConfig(
            aggregator="mean", verification=audit,
            economy=EconomyConfig(identity_cost=0.5, budget=grid.econ_budget,
                                  min_stake=grid.econ_min_stake,
                                  fee_income=1.0, reward_rate=0.1,
                                  op_cost=grid.econ_op_cost, jackpot=5.0,
                                  honest_reserve=grid.econ_reserve,
                                  adaptive=eres.adaptive),
            seed=0)
        sw = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                        cfg, data_fn)
        sw.run(6)
        np.testing.assert_allclose(dres.final_loss,
                                   float(eval_fn(sw.params)), rtol=2e-3)
        assert eres.n_admitted_last == sw.history[-1]["n_active"]
        np.testing.assert_allclose(eres.coalition_stake_share,
                                   sw.history[-1]["coalition_stake"],
                                   rtol=1e-4, atol=1e-5)
        hp = float(np.asarray(payoff(sw._econ_state))[:5].mean())
        assert eres.honest_payoff == pytest.approx(hp, rel=1e-3, abs=1e-3)


def test_smoke_grid_adaptive_gap_is_measurable():
    """ISSUE acceptance: on the registered smoke grid the best-response
    coalition derails the mean+audit regime the fixed scale-2 attack
    cannot (median adaptive/fixed loss ratio >> 1), robust aggregation
    closes the gap, and the phase table renders both sustained and
    death-spiral cells."""
    res, _ = _quad_sweep(get_sweep_grid("no_off_economy_smoke"))
    assert res.n_programs == 1
    assert len(res.econ_results) == res.grid.n_points == 16

    gap = res.economy_adaptive_gap()
    assert gap["cells"] == 8
    assert gap["loss_ratio"] > 5.0          # adaptive recalibration wins
    # the gap concentrates in the weakly-defended regime...
    mean_gap = economy.adaptive_gap(
        [r for r in res.econ_results if r.regime == "mean+audit"])
    assert mean_gap["loss_ratio"] > 5.0
    # ...and robust aggregation closes it
    cc_gap = economy.adaptive_gap(
        [r for r in res.econ_results if r.regime == "centered_clip+audit"])
    assert cc_gap["loss_ratio"] < 2.0

    outcomes = {r.outcome for r in res.econ_results}
    assert "sustained" in outcomes          # cheap identities + fees hold
    assert "death_spiral" in outcomes       # cost 4.0 sinks honest payoff
    table = res.economy_phase_table("mean+audit")
    assert "cost\\fee" in table and "0.5" in table and "4" in table


def test_full_economy_grid_counts():
    """The full no_off_economy grid compiles its lane plan as registered:
    every economy knob multiplies the point count (one program's worth of
    lanes — running it is the benchmark's job, not CI's)."""
    grid = get_sweep_grid("no_off_economy")
    assert grid.has_economy
    assert grid.n_points == 2 * 3 * 3 * 2 * 2 * 2   # reg·cost·fee·sched·adp·seed
    from repro.core.derailment import build_sweep_lanes
    spec = build_sweep_lanes(grid)
    assert len(spec.lanes) == grid.n_points + 2      # + baseline per seed


# ====================== satellite: speed-derived delays ========================
def test_delay_defaults_derive_from_speed():
    """NodeSpec.delay=None derives the staleness cap from speed
    (ceil(1/speed) - 1); an explicit delay — including 0 — overrides."""
    assert NodeSpec("a").effective_delay == 0
    assert NodeSpec("a", speed=2.0).effective_delay == 0
    assert NodeSpec("a", speed=0.5).effective_delay == 1
    assert NodeSpec("a", speed=0.25).effective_delay == 3
    assert NodeSpec("a", speed=0.25, delay=5).effective_delay == 5
    assert NodeSpec("a", speed=0.25, delay=0).effective_delay == 0


def test_async_speed_derived_delays_match_explicit_twin():
    """A slow node with no explicit delay runs EXACTLY like its
    explicitly-delayed twin — same realized staleness, same params — so
    speed heterogeneity and asynchrony are one axis, not two."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    mk = lambda nodes: make_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
        SwarmConfig(aggregator="mean", staleness_bound=2, seed=3), data_fn)
    derived = mk([NodeSpec("h0"), NodeSpec("h1", speed=0.5),
                  NodeSpec("h2", speed=0.25)])
    explicit = mk([NodeSpec("h0", delay=0), NodeSpec("h1", delay=1),
                   NodeSpec("h2", delay=3)])
    derived.run(6)
    explicit.run(6)
    assert [h["staleness"] for h in derived.history] == \
        [h["staleness"] for h in explicit.history]
    np.testing.assert_array_equal(np.asarray(derived.params["w"]),
                                  np.asarray(explicit.params["w"]))
