"""Bounded-staleness async rounds: oracle contracts, determinism, sweeps.

The async engine's correctness story has four pins:

1. ``staleness_bound=0`` routes through the LITERAL synchronous code path,
   so it is bit-exact with the pre-async engine by construction — pinned
   here anyway (params and every RoundRecord counter, through churn +
   audits + corruption), the same way FC-decentralized pins centralized.
2. K > 0: the batched ring-buffer engine equals the ``SequentialSwarm``
   oracle (a plain dict of the last K+1 snapshots, host-side delay draws
   from the identical key schedule) — counters and realized staleness
   exactly, aggregates to vmap-reduction tolerance.
3. Histories are a pure function of ``(seed, delay schedule)``, and a
   campaign lane reproduces the single-run ``Swarm`` (lane stacking is
   invariant).  The hypothesis twin lives in ``test_properties.py``.
4. The staleness axis of ``derailment.sweep`` reproduces single-point
   ``simulate_derailment(staleness_bound=K)`` runs, and audits recompute
   against the claimed stale snapshot — staleness alone never slashes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.derailment import simulate_derailment, sweep
from repro.core.scenarios import (
    SweepGrid,
    get_scenario,
    get_sweep_grid,
    scenario_campaign,
)
from repro.core.swarm import (
    NodeSpec,
    SwarmConfig,
    history_from_records,
    make_swarm,
)
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem

_VERIF = VerificationConfig(p_check=0.5, stake=5.0, tolerance=1e-3,
                            jackpot=5.0)


def _roster(delays=(0, 2, 3, 3, 1)):
    """Churn + audits + corruption + heterogeneous speed in 5 nodes — every
    code path the round serves, with per-node staleness caps."""
    return [
        NodeSpec("h0", delay=delays[0]),
        NodeSpec("h1", delay=delays[1]),
        NodeSpec("h2", speed=2.0, delay=delays[2]),
        NodeSpec("adv0", byzantine="sign_flip", byzantine_scale=5.0,
                 delay=delays[3]),
        NodeSpec("ch0", join_round=2, leave_round=9, delay=delays[4]),
    ]


def _build(cfg, engine="batched", delays=(0, 2, 3, 3, 1)):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem()
    opt = SGD(lr=0.1, momentum=0.0)
    return make_swarm(loss_fn, params0, opt, _roster(delays), cfg, data_fn,
                      engine=engine)


def _flat(params):
    return np.concatenate([np.asarray(l).reshape(-1)
                           for l in jax.tree.leaves(params)])


# ------------------- pin 1: K=0 == the synchronous engine ----------------------
def test_staleness_zero_bit_exact_vs_sync():
    """staleness_bound=0 IS the synchronous engine: node delay fields are
    not read, the ring is not traced, params and every record counter are
    bit-identical through churn, audits and corruption."""
    a = _build(SwarmConfig(aggregator="centered_clip", verification=_VERIF,
                           staleness_bound=0, seed=0))
    b = _build(SwarmConfig(aggregator="centered_clip", verification=_VERIF,
                           seed=0), delays=(0, 0, 0, 0, 0))
    a.run(10)
    b.run(10)
    assert a.history == b.history           # bit-exact, staleness included
    assert all(h["staleness"] == 0.0 for h in a.history)
    np.testing.assert_array_equal(_flat(a.params), _flat(b.params))


# ------------------- pin 2: batched ring == sequential oracle ------------------
def test_async_batched_equals_sequential_oracle():
    K = 3
    cfg = SwarmConfig(aggregator="centered_clip", verification=_VERIF,
                      staleness_bound=K, seed=0)
    bat = _build(cfg)
    seq = _build(cfg, engine="sequential")
    for rnd in range(12):
        rb, rs = bat.step(rnd), seq.step(rnd)
        for k in ("n_active", "n_byzantine", "caught"):
            assert rb[k] == rs[k], (rnd, k, rb[k], rs[k])
        # realized delays come from the SAME (seed, _DELAY, round, node)
        # schedule on both engines — the mean matches exactly in f32
        assert rb["staleness"] == rs["staleness"], rnd
        np.testing.assert_allclose(rb["agg_norm"], rs["agg_norm"],
                                   rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(_flat(bat.params), _flat(seq.params),
                               rtol=1e-4, atol=1e-5)
    assert sorted(bat.slashed) == sorted(seq.slashed)


def test_async_audit_recomputes_against_claimed_snapshot():
    """§4.2 soundness under staleness: the validator recomputes from the
    SAME delayed snapshot the contributor used (the delay is part of the
    claim), so honest-but-stale nodes are never slashed — only the
    corrupting attacker is."""
    K = 3
    cfg = SwarmConfig(aggregator="centered_clip",
                      verification=VerificationConfig(
                          p_check=1.0, stake=5.0, tolerance=1e-3,
                          jackpot=5.0),
                      staleness_bound=K, seed=0)
    for engine in ("batched", "sequential"):
        sw = _build(cfg, engine=engine)
        sw.run(10)
        assert any(h["staleness"] > 0 for h in sw.history), engine
        # p_check=1 audits every node every round: with the stale-snapshot
        # recompute, only the sign-flipper can be caught
        assert set(sw.slashed) <= {"adv0"}, engine
        assert "adv0" in sw.slashed, engine


# ------------------- pin 3: determinism + lane stacking ------------------------
def test_async_history_deterministic_in_seed_and_delays():
    K = 3
    cfg = SwarmConfig(aggregator="centered_clip", staleness_bound=K, seed=0)
    a, b = _build(cfg), _build(cfg)
    a.run(8)
    b.run(8)
    assert a.history == b.history           # same (seed, delays): identical
    np.testing.assert_array_equal(_flat(a.params), _flat(b.params))
    # a different delay schedule (same seed) realizes different staleness
    c = _build(cfg, delays=(3, 3, 3, 3, 3))
    c.run(8)
    assert [h["staleness"] for h in c.history] != \
        [h["staleness"] for h in a.history]
    # a different seed redraws the delays too
    d = _build(SwarmConfig(aggregator="centered_clip", staleness_bound=K,
                           seed=1))
    d.run(8)
    assert [h["staleness"] for h in d.history] != \
        [h["staleness"] for h in a.history]


def test_async_scan_equals_step_loop():
    """The scanned async run (ring donated through lax.scan) is bit-exact
    with the eager step loop."""
    K = 2
    cfg = SwarmConfig(aggregator="centered_clip", verification=_VERIF,
                      staleness_bound=K, seed=0)
    scanned, stepped = _build(cfg), _build(cfg)
    scanned.run(10)
    for rnd in range(10):
        stepped.step(rnd)
    assert scanned.history == stepped.history
    np.testing.assert_array_equal(_flat(scanned.params),
                                  _flat(stepped.params))


def test_async_staleness_records_bounded():
    K = 2
    sw = _build(SwarmConfig(aggregator="mean", staleness_bound=K, seed=0),
                delays=(2, 2, 2, 2, 2))
    sw.run(10)
    stale = [h["staleness"] for h in sw.history]
    assert stale[0] == 0.0                  # round 0 has no older snapshot
    assert all(0.0 <= s <= K for s in stale)
    assert any(s > 0 for s in stale)


@pytest.mark.parametrize("scenario", [
    "straggler_majority",
    "stale_poisoning",
    "async_churn",
])
def test_async_campaign_lane_matches_single_run_swarm(scenario):
    """Lane-stacking invariance: each lane of an async scenario campaign
    reproduces the single-run Swarm for the same (scenario, seed) — the
    test_campaign.py contract extended to the staleness axis."""
    rounds, seeds = 10, (0, 1)
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    state, recs, _, node_ids, cfg = scenario_campaign(
        scenario, loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
        n_nodes=8, seeds=seeds, rounds=rounds)
    for k, seed in enumerate(seeds):
        swarm = get_scenario(scenario).build_swarm(
            loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
            n_nodes=8, seed=seed)
        for r in range(rounds):
            swarm.step(r)
        hist = history_from_records(
            jax.tree.map(lambda x: x[k], recs), node_ids)
        for key in ("n_active", "n_byzantine", "caught", "staleness"):
            assert [h[key] for h in hist] == \
                [h[key] for h in swarm.history], (scenario, seed, key)
        np.testing.assert_allclose(
            [h["agg_norm"] for h in hist],
            [h["agg_norm"] for h in swarm.history],
            rtol=2e-3, atol=1e-5, err_msg=f"{scenario} seed {seed}")


# ------------------- pin 4: the staleness sweep axis ---------------------------
def _quad_sweep(grid, **kw):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return (sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                  eval_fn, grid, **kw),
            (loss_fn, params0, data_fn, eval_fn))


def test_async_sweep_smoke_grid():
    """The registered staleness-axis smoke grid runs as ONE program with
    per-bound baselines, and the phase table grows s=K rows."""
    grid = get_sweep_grid("no_off_async_smoke")
    res, _ = _quad_sweep(grid)
    assert res.n_programs == 1
    assert len(res.results) == grid.n_points == 4
    assert res.n_runs == grid.n_lanes == 6      # 4 cells + 2 baselines
    assert {r.staleness_bound for r in res.results} == {0, 2}
    assert all(np.isfinite(r.final_loss) and np.isfinite(r.baseline_loss)
               for r in res.results)
    table = res.phase_table()
    assert "s=0" in table and "s=2" in table


def test_async_sweep_lane_equals_simulate_derailment():
    """A staleness-axis sweep cell reproduces the single-point
    ``simulate_derailment(staleness_bound=K)`` run (same key schedule,
    same ring semantics — the single run's ring has the same K because the
    grid carries one bound)."""
    grid = SweepGrid(
        name="tiny_async", description="", n_honest=6,
        attacker_counts=(1, 3), seeds=(0,), rounds=10,
        staleness_bounds=(2,),
        regimes=get_sweep_grid("no_off_smoke").regimes)
    res, (loss_fn, params0, data_fn, eval_fn) = _quad_sweep(grid)
    for r in res.results:
        single = simulate_derailment(
            loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, eval_fn,
            n_honest=6, n_attack=r.n_attackers, rounds=10,
            aggregator=r.aggregator, seed=r.seed, staleness_bound=2,
            baseline_loss=r.baseline_loss)
        np.testing.assert_allclose(r.final_loss, single.final_loss,
                                   rtol=2e-3, err_msg=str(r))
        assert r.derailed == single.derailed
