"""Golden tests for the protolint static-analysis suite (repro.analysis).

Every rule code gets a minimal *firing* snippet and a minimal *quiet*
snippet, so the rule catalog can neither rot (a rule that stops firing
fails here first) nor creep (a rule that starts over-firing fails the
quiet twin).  The integration test at the bottom is the gate itself: the
six engine programs and seven kernels must audit clean at HEAD.

Run standalone with ``pytest -m analysis``; included in tier-1.
"""
import textwrap

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_audit, pallas_check, tracer_lint
from repro.analysis.jaxpr_audit import fingerprint
from repro.analysis.programs import DonationUnit, TracedProgram, TracedUnit
from repro.analysis.report import RULES, Report, Violation, load_baseline

pytestmark = pytest.mark.analysis


def _codes(violations):
    return {v.code for v in violations}


def _audit(fn, *args, declared_axes=frozenset(), **make_jaxpr_kwargs):
    closed = jax.make_jaxpr(fn, **make_jaxpr_kwargs)(*args)
    unit = TracedUnit("t", closed, declared_axes=declared_axes)
    return jaxpr_audit._audit_unit("prog", unit)


# =============================== JX rules =====================================
def test_jx001_fires_on_f64():
    from jax.experimental import enable_x64
    with enable_x64():
        vs = _audit(lambda x: x.astype("float64") * 2.0,
                    jnp.zeros(4, jnp.float32))
    assert "JX001" in _codes(vs)


def test_jx001_quiet_on_f32():
    vs = _audit(lambda x: x * 2.0, jnp.zeros(4, jnp.float32))
    assert "JX001" not in _codes(vs)


def test_jx002_fires_on_weak_constant_buffer():
    # the exact bug class fixed in aggregation.py: nanmedian's internal
    # weak 0.5 quantile materializes a weak-typed buffer
    vs = _audit(lambda x: jnp.nanmedian(x, axis=0), jnp.zeros((4, 4)))
    assert "JX002" in _codes(vs)


def test_jx002_quiet_on_dtype_matched_quantile():
    vs = _audit(lambda x: jnp.nanquantile(x, jnp.asarray(0.5, x.dtype),
                                          axis=0, method="midpoint"),
                jnp.zeros((4, 4)))
    assert "JX002" not in _codes(vs)


def test_jx003_fires_on_debug_print():
    def f(x):
        jax.debug.print("x={}", x)
        return x + 1
    vs = _audit(f, jnp.zeros(3))
    assert "JX003" in _codes(vs)


def test_jx003_quiet_without_callbacks():
    vs = _audit(lambda x: x + 1, jnp.zeros(3))
    assert "JX003" not in _codes(vs)


def test_jx004_fires_on_dynamic_shape():
    jax.config.update("jax_dynamic_shapes", True)
    try:
        closed = jax.make_jaxpr(lambda x: x + x,
                                abstracted_axes=("n",))(jnp.arange(4.0))
    finally:
        jax.config.update("jax_dynamic_shapes", False)
    vs = jaxpr_audit._audit_unit("prog", TracedUnit("t", closed))
    assert "JX004" in _codes(vs)


def test_jx004_quiet_on_static_shapes():
    vs = _audit(lambda x: x + x, jnp.arange(4.0))
    assert "JX004" not in _codes(vs)


def test_jx005_fires_on_undeclared_axis():
    vs = _audit(lambda x: jax.lax.psum(x, "lanes"), jnp.zeros(3),
                axis_env=[("lanes", 4)])
    assert "JX005" in _codes(vs)


def test_jx005_quiet_on_declared_axis():
    vs = _audit(lambda x: jax.lax.psum(x, "lanes"), jnp.zeros(3),
                declared_axes=frozenset({"lanes"}),
                axis_env=[("lanes", 4)])
    assert "JX005" not in _codes(vs)


def test_jx006_fires_when_donation_missing():
    text = jax.jit(lambda x: x + 1).lower(jnp.zeros(128)).as_text()
    vs = jaxpr_audit._audit_donation("prog", DonationUnit("t", text, 1))
    assert _codes(vs) == {"JX006"}


def test_jx006_quiet_when_donation_honored():
    text = jax.jit(lambda x: x + 1,
                   donate_argnums=0).lower(jnp.zeros(128)).as_text()
    vs = jaxpr_audit._audit_donation("prog", DonationUnit("t", text, 1))
    assert vs == []


def test_jx007_fires_on_structural_drift():
    closed_a = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(4))
    closed_b = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(8))
    prog = TracedProgram("prog", [TracedUnit("a", closed_a, group="g"),
                                  TracedUnit("b", closed_b, group="g")])
    vs = jaxpr_audit._audit_fingerprints(prog)
    assert _codes(vs) == {"JX007"}


def test_jx007_quiet_on_value_variants():
    # same shapes, different values -> same trace -> same fingerprint
    closed_a = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros(4))
    closed_b = jax.make_jaxpr(lambda x: x * 2)(jnp.ones(4))
    assert fingerprint(closed_a) == fingerprint(closed_b)
    prog = TracedProgram("prog", [TracedUnit("a", closed_a, group="g"),
                                  TracedUnit("b", closed_b, group="g")])
    assert jaxpr_audit._audit_fingerprints(prog) == []


# =============================== PK rules =====================================
class _Spec:
    """Duck-typed BlockSpec: exactly the two attrs the checker reads."""

    def __init__(self, block_shape, index_map):
        self.block_shape = block_shape
        self.index_map = index_map


def _call(grid, in_specs, in_shapes, out_specs, out_shapes, scratch=0):
    return pallas_check.CapturedCall(
        kernel="golden", index=0, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        in_shapes=in_shapes, out_shapes=out_shapes,
        scratch_bytes=scratch, num_scalar_prefetch=0)


def test_pk001_fires_on_uncovered_output_tile():
    call = _call((1,), [], [], [_Spec((128,), lambda i: (i,))], [((256,), 4)])
    assert "PK001" in _codes(pallas_check._check_call(call))


def test_pk001_quiet_on_full_coverage():
    call = _call((2,), [], [], [_Spec((128,), lambda i: (i,))], [((256,), 4)])
    assert pallas_check._check_call(call) == []


def test_pk002_fires_on_out_of_bounds_tile():
    call = _call((2,), [], [], [_Spec((128,), lambda i: (i,))], [((128,), 4)])
    assert "PK002" in _codes(pallas_check._check_call(call))


def test_pk002_quiet_within_bounds():
    call = _call((1,), [], [], [_Spec((128,), lambda i: (i,))], [((128,), 4)])
    assert pallas_check._check_call(call) == []


def test_pk003_fires_over_vmem_budget():
    call = _call((4,), [_Spec((128,), lambda i: (i,))], [((512,), 4)],
                 [_Spec((128,), lambda i: (i,))], [((512,), 4)])
    vs = pallas_check._check_call(call, budget=1024)
    assert "PK003" in _codes(vs)


def test_pk003_quiet_under_budget():
    call = _call((4,), [_Spec((128,), lambda i: (i,))], [((512,), 4)],
                 [_Spec((128,), lambda i: (i,))], [((512,), 4)])
    assert pallas_check._check_call(call) == []


def test_pk004_fires_on_sub_lane_tiling():
    call = _call((8,), [_Spec((64,), lambda i: (i,))], [((512,), 4)],
                 [_Spec((64,), lambda i: (i,))], [((512,), 4)])
    assert "PK004" in _codes(pallas_check._check_call(call))


def test_pk004_quiet_on_lane_multiple_tiling():
    call = _call((4,), [_Spec((128,), lambda i: (i,))], [((512,), 4)],
                 [_Spec((128,), lambda i: (i,))], [((512,), 4)])
    assert pallas_check._check_call(call) == []


# =============================== PL rules =====================================
def _lint(src):
    return tracer_lint.lint_source(textwrap.dedent(src))


def test_pl000_fires_on_stale_baseline_entry():
    report = Report()
    report.apply_baseline({"JX001::gone::unit": "historical debt"})
    assert _codes(report.violations) == {"PL000"}
    assert not report.ok


def test_pl000_quiet_on_live_baseline_entry():
    report = Report(violations=[Violation("JX001", "prog::unit", "m")])
    report.apply_baseline({"JX001::prog::unit": "known"})
    assert report.ok and len(report.baselined) == 1


def test_pl001_fires_on_python_if_over_tracer():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.sum(x) > 0:
                return x
            return -x
    """)
    assert "PL001" in _codes(vs)


def test_pl001_quiet_on_jnp_where():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return jnp.where(jnp.sum(x) > 0, x, -x)
    """)
    assert "PL001" not in _codes(vs)


def test_pl002_fires_on_host_escape():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return float(jnp.sum(x)) * x
    """)
    assert "PL002" in _codes(vs)


def test_pl002_quiet_on_traced_arithmetic():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return jnp.sum(x) * x
    """)
    assert "PL002" not in _codes(vs)


def test_pl003_fires_on_numpy_in_traced_fn():
    vs = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
    """)
    assert "PL003" in _codes(vs)


def test_pl003_quiet_on_static_shape_math():
    vs = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return x.reshape(int(np.prod(x.shape)))
    """)
    assert "PL003" not in _codes(vs)


def test_pl004_fires_on_unordered_dict_iteration():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, kwargs):
            for k, v in kwargs.items():
                x = x + v
            return x
    """)
    assert "PL004" in _codes(vs)


def test_pl004_quiet_on_sorted_iteration():
    vs = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, kwargs):
            for k, v in sorted(kwargs.items()):
                x = x + v
            return x
    """)
    assert "PL004" not in _codes(vs)


def test_pl005_fires_on_array_taking_lru_cache():
    vs = _lint("""
        import functools
        import jax.numpy as jnp

        @functools.lru_cache(maxsize=None)
        def f(x):
            return jnp.sum(x)
    """)
    assert "PL005" in _codes(vs)


def test_pl005_quiet_on_static_arg_cache():
    vs = _lint("""
        import functools

        @functools.lru_cache(maxsize=None)
        def f(n):
            return n * 2
    """)
    assert "PL005" not in _codes(vs)


def test_noqa_suppresses_a_rule():
    vs = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)  # noqa: PL003
    """)
    assert "PL003" not in _codes(vs)


def test_rule_catalog_is_complete():
    assert set(RULES) == {
        "JX001", "JX002", "JX003", "JX004", "JX005", "JX006", "JX007",
        "PK001", "PK002", "PK003", "PK004",
        "PL000", "PL001", "PL002", "PL003", "PL004", "PL005"}


# ============================ the gate itself =================================
def test_engine_programs_and_kernels_violation_free():
    """The integration gate: the seven engine programs (22 traced
    variants), all seven kernels, and the whole source tree audit clean at
    HEAD (modulo the checked-in baseline, empty at HEAD)."""
    from repro.analysis.__main__ import build_report
    report = build_report()
    report.apply_baseline(load_baseline())
    assert set(report.summary["programs"]) == {
        "round_unfused", "round_fused", "round_async", "campaign", "sweep",
        "economy", "serve_step"}
    assert len(report.summary["kernels"]) == 7
    assert sum(report.summary["kernels"].values()) >= 7
    assert report.ok, "\n".join(
        f"{v.key}: {v.message}" for v in report.violations)
