"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real single
CPU device; multi-device paths (pipeline, dry-run) shell out with their own
flags (DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile/dry-run) tests")
    config.addinivalue_line(
        "markers", "kernels: Pallas kernel conformance suite "
        "(run standalone with `pytest -m kernels`; included in tier-1)")
    config.addinivalue_line(
        "markers", "analysis: protolint static-analysis golden tests + the "
        "engine-programs-audit-clean gate (`pytest -m analysis`; tier-1)")
    config.addinivalue_line(
        "markers", "sanitize: numeric smoke — one campaign and one serving "
        "scenario re-run under jax_debug_nans/jax_debug_infs, so a NaN/Inf "
        "produced anywhere in the hot path raises at the producing "
        "primitive instead of corrupting results downstream "
        "(`pytest -m sanitize`; tier-1)")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_quadratic_problem(n_params: int = 8):
    """A convex toy problem for swarm/optimizer tests: loss = ||Wx - y||²."""
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    target = jax.random.normal(k1, (n_params,))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["x"] @ target))

    def data_fn(node_idx: int, rnd: int):
        k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
        return {"x": jax.random.normal(k, (16, n_params))}

    params0 = {"w": jnp.zeros((n_params,))}
    return loss_fn, params0, data_fn, target
