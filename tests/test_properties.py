"""All hypothesis property tests, in one module guarded by importorskip.

The seed suite imported ``hypothesis`` at the top of test_launch.py and
test_protocol_core.py, so tier-1 died at *collection* when the package was
missing.  Property tests now live here: without hypothesis only this module
skips and every unit test still runs; CI installs hypothesis via
requirements-dev.txt, so these always run there.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import assume, given, settings
from hypothesis import strategies as st

from repro.core import aggregation, compression, verification
from repro.core import unextractable as unext
from repro.core.ledger import Ledger
from repro.core.unextractable import ShardCustody
from repro.data.pipeline import DataConfig, lm_batch


# =============================== aggregation ===================================
@settings(max_examples=20, deadline=None)
@given(st.integers(3, 12), st.integers(1, 16), st.integers(0, 5))
def test_property_agg_fixed_point(n, d, seed):
    """All aggregators return x when every node submits the same x."""
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(seed), (d,)), (n, d))
    for name in aggregation.AGGREGATORS:
        kw = {"f": 1} if "krum" in name else {}
        agg = aggregation.get_aggregator(name, **kw)(x)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(x[0]),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 10), st.integers(0, 3))
def test_property_agg_permutation_invariant(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 99), n)
    for name in ("mean", "median", "trimmed_mean", "centered_clip"):
        a = aggregation.AGGREGATORS[name](x)
        b = aggregation.AGGREGATORS[name](x[perm])
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 12), st.integers(0, 5))
def test_property_masked_agg_equals_dense_subset(n, seed):
    """The batched-engine contract: masked_agg(X, mask) == agg(X[mask])."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    mask = rng.random(n) < 0.6
    mask[rng.integers(n)] = True
    for name in aggregation.MASKED_AGGREGATORS:
        kw = {"f": 1} if "krum" in name else {}
        dense = aggregation.get_aggregator(name, **kw)(x[mask])
        masked = aggregation.get_masked_aggregator(name, **kw)(
            x, jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(masked), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


# =============================== compression ===================================
@settings(max_examples=15, deadline=None)
@given(st.integers(8, 200), st.integers(0, 5))
def test_property_qsgd_error_bounded(size, seed):
    """QSGD theory: ‖x − Q(x)‖ ≤ (√d / levels) ‖x‖ (one-sigma-ish bound)."""
    levels = 64
    x = jax.random.normal(jax.random.PRNGKey(seed), (size,))
    c = compression.qsgd_compress(jax.random.PRNGKey(seed + 1), x,
                                  levels=levels)
    y = compression.qsgd_decompress(c)
    err = float(jnp.linalg.norm(y - x))
    bound = (np.sqrt(size) / levels) * float(jnp.linalg.norm(x)) * 3 + 1e-6
    assert err <= bound


# =============================== verification ==================================
@settings(max_examples=300, deadline=None)
@given(st.floats(-10.0, 1e6, allow_nan=False, allow_infinity=False),
       st.floats(1e-12, 1e6, allow_nan=False, allow_infinity=False))
def test_property_min_p_check_makes_cheating_irrational(gain, stake):
    """The audit-rate boundary contract over arbitrary (gain, stake): the
    returned rate is in [0, 1] and — whenever any rate <= 1 can suffice —
    actually makes cheating irrational, float rounding included (the EV==0
    boundary counts as irrational; min_p_check nudges the quotient up by
    ulps until p * stake >= gain)."""
    p = verification.min_p_check(gain, stake)
    assert 0.0 <= p <= 1.0
    if gain <= 0.0:
        assert p == 0.0
    if p < 1.0:
        assert verification.cheating_irrational(
            gain, verification.VerificationConfig(p_check=p, stake=stake))


# ============================== async swarm ====================================
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16),
       st.lists(st.integers(0, 3), min_size=5, max_size=5))
def test_property_async_history_deterministic_and_stack_invariant(seed,
                                                                  delays):
    """Bounded-staleness histories are a pure function of (seed, delay
    schedule), and a lane keeps its history when stacked into a wider
    campaign (sweep lane == single run)."""
    from conftest import tiny_quadratic_problem
    from repro.core.swarm import (NodeSpec, SwarmConfig, history_from_records,
                                  lane_for_nodes, run_campaign, stack_lanes)
    from repro.optim.optimizer import SGD

    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    opt = SGD(lr=0.1, momentum=0.0)
    K = 3
    nodes = [NodeSpec(f"h{i}", delay=d) for i, d in enumerate(delays[:-1])]
    nodes.append(NodeSpec("adv", byzantine="sign_flip", byzantine_scale=5.0,
                          delay=delays[-1]))
    cfg = SwarmConfig(aggregator="centered_clip", staleness_bound=K,
                      seed=seed)
    lane = lane_for_nodes(nodes, cfg)
    ids = [n.node_id for n in nodes]

    hists = []
    for _ in range(2):      # determinism: identical inputs, identical run
        _, recs, _ = run_campaign(loss_fn, params0, opt, data_fn,
                                  stack_lanes([lane]), rounds=6,
                                  aggregator="centered_clip")
        hists.append(history_from_records(
            jax.tree.map(lambda x: x[0], recs), ids))
    assert hists[0] == hists[1]

    # stacking: the same lane next to a different-delay, different-seed
    # lane keeps counters and realized staleness exactly (floats to vmap
    # tolerance)
    other = lane_for_nodes(
        [NodeSpec(f"o{i}", delay=(i + 1) % (K + 1)) for i in range(len(ids))],
        SwarmConfig(aggregator="centered_clip", staleness_bound=K,
                    seed=seed + 1))
    _, recs, _ = run_campaign(loss_fn, params0, opt, data_fn,
                              stack_lanes([lane, other]), rounds=6,
                              aggregator="centered_clip")
    stacked = history_from_records(jax.tree.map(lambda x: x[0], recs), ids)
    for key in ("n_active", "n_byzantine", "caught", "staleness"):
        assert [h[key] for h in stacked] == [h[key] for h in hists[0]], key
    np.testing.assert_allclose([h["agg_norm"] for h in stacked],
                               [h["agg_norm"] for h in hists[0]],
                               rtol=1e-5, atol=1e-7)


# ================================= ledger ======================================
@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                          st.floats(0.0, 10.0)), min_size=1, max_size=20))
def test_property_ledger_conservation(events):
    led = Ledger()
    for node, amount in events:
        led.record_contribution(node, amount)
    assert led.check_conservation()
    total = sum(a for _, a in events)
    assert led.total_shares == pytest.approx(total)
    for n in "abc":
        contributed = sum(a for nn, a in events if nn == n)
        if total:
            assert led.ownership_fraction(n) == pytest.approx(
                contributed / total)


_LEDGER_OPS = ("mint", "stake", "transfer", "slash", "jackpot", "fee",
               "distribute")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(_LEDGER_OPS), st.integers(0, 3),
                          st.integers(0, 3),
                          st.floats(0.0, 20.0, allow_nan=False)),
                max_size=40))
def test_property_ledger_conservation_random_ops(ops):
    """Conservation survives ARBITRARY interleavings of every ledger op —
    mints, staked capital, transfers, slashes, pool-funded jackpots, fee
    charges, and fee distribution — checked after every single op.  Also
    the jackpot cap: a validator is never paid more than the slash pool
    holds, and the payout drains exactly that much from it."""
    led = Ledger()
    names = [f"n{i}" for i in range(4)]
    for op, i, j, amt in ops:
        src, dst = names[i], names[j]
        if op == "mint":
            led.record_contribution(src, amt)
        elif op == "stake":
            led.stake(src, amt)
        elif op == "transfer":
            have = led.balances.get(src, 0.0)
            if have > 0:
                led.transfer(src, dst, min(amt, have))
        elif op == "slash":
            led.slash(src)
        elif op == "jackpot":
            pool = led.slash_pool
            paid = led.pay_jackpot("validator", amt)
            assert paid <= min(amt, pool) + 1e-9
            assert led.slash_pool == pytest.approx(pool - paid)
        elif op == "fee":
            have = led.balances.get(src, 0.0)
            if have > 0:
                led.charge_fee(src, min(amt, have))
        elif op == "distribute":
            led.distribute_fees()
        assert led.check_conservation(), (op, src, amt)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**16), st.booleans())
def test_property_ledger_engine_round_trip(seed, late_joiner):
    """The ledger <-> engine round trip: both engines' host Ledgers agree
    BIT-FOR-BIT through churn + audits + slashing (speeds 0.5/1/2 are
    exactly representable, so speed-weighted mints are exact in f32 and
    f64 alike), stay conserved with an over-sized (pool-capped) jackpot,
    and keep agreeing when the same fee events settle on top."""
    from conftest import tiny_quadratic_problem
    from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
    from repro.optim.optimizer import SGD

    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec("h0", speed=1.0), NodeSpec("h1", speed=0.5),
             NodeSpec("h2", speed=2.0, join_round=2 if late_joiner else 0),
             NodeSpec("h3", speed=1.0, leave_round=5),
             NodeSpec("adv", byzantine="sign_flip", byzantine_scale=8.0)]
    cfg = SwarmConfig(
        aggregator="mean", seed=seed,
        verification=verification.VerificationConfig(
            p_check=0.5, stake=4.0, tolerance=1e-3, jackpot=6.0))
    ledgers = []
    for engine in ("batched", "sequential"):
        sw = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                        cfg, data_fn, engine=engine)
        sw.run(8)
        assert sw.ledger.check_conservation(), engine
        ledgers.append(sw.ledger)
    a, b = ledgers
    assert a.balances == b.balances
    assert a.stakes == b.stakes
    assert (a.burned, a.burned_stake, a.slash_pool, a.fee_pool) == \
        (b.burned, b.burned_stake, b.slash_pool, b.fee_pool)

    # the same serving-fee events applied to both reconstructed ledgers
    # keep them identical and conserved (Ledger.charge_fee/distribute_fees
    # iterate in identical insertion order on both)
    for led in (a, b):
        for holder in ("h0", "h1"):
            have = led.balances.get(holder, 0.0)
            if have > 0:
                led.charge_fee(holder, have / 2)
        led.distribute_fees()
        assert led.check_conservation()
    assert a.balances == b.balances
    assert a.fee_pool == b.fee_pool


# ============================ unextractability =================================
@settings(max_examples=10, deadline=None)
@given(st.integers(4, 12), st.integers(2, 3), st.integers(0, 4))
def test_property_custody_full_swarm_covers(n_nodes, redundancy, seed):
    # feasibility: total custody slots must cover shards x redundancy
    assume(n_nodes * math.ceil(0.6 * 16) >= 16 * redundancy)
    nodes = [f"n{i}" for i in range(n_nodes)]
    try:
        c = ShardCustody.assign(nodes, 16, redundancy=redundancy, seed=seed,
                                max_fraction=0.6)
    except ValueError:
        # greedy packing can strand capacity on near-tight configs —
        # that's the documented failure mode, not a coverage bug
        assume(False)
    assert c.coverage(nodes) == 1.0
    # redundancy: every shard held by `redundancy` distinct nodes
    for holders in c.assignment.values():
        assert len(set(holders)) == redundancy


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 10), st.integers(4, 20), st.integers(0, 6),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_property_custody_matrix_matches_dict_oracle(n_nodes, n_shards, seed,
                                                     density, pick):
    """The vectorized (N, S) reductions agree with plain python set math on
    *arbitrary* custody assignments (not just assign_matrix draws) for
    coverage / can_extract / tolerates_departures / missing_shards."""
    rng = np.random.default_rng(seed)
    holds_np = rng.random((n_nodes, n_shards)) < density
    mask_np = rng.random(n_nodes) < pick
    holds, mask = jnp.asarray(holds_np), jnp.asarray(mask_np)

    # the dict-based oracle: node -> set of shards, python set unions
    node_shards = {n: set(np.flatnonzero(holds_np[n]).tolist())
                   for n in range(n_nodes)}
    covered = set().union(*(node_shards[n] for n in np.flatnonzero(mask_np)))
    survives = set().union(*(node_shards[n] for n in range(n_nodes)
                             if not mask_np[n]))

    assert float(unext.coverage_frac(holds, mask)) == \
        pytest.approx(len(covered) / n_shards)
    assert bool(unext.can_extract_all(holds, mask)) == \
        (len(covered) == n_shards)
    assert bool(unext.tolerates_departures_all(holds, mask)) == \
        (len(survives) == n_shards)
    assert int(unext.missing_shards(holds, mask)) == n_shards - len(covered)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 17), st.integers(0, 5), st.integers(1, 40),
       st.integers(1, 40))
def test_property_shard_reconstruct_roundtrip_mixed_dtype(num_shards, seed,
                                                          size_a, size_b):
    """shard_params -> reconstruct_params at full coverage is an EXACT
    roundtrip for mixed fp32/bf16 pytrees, across shard counts that force
    zero-padding (bf16 -> fp32 -> bf16 is value-preserving)."""
    k = jax.random.PRNGKey(seed)
    params = {
        "w": jax.random.normal(k, (size_a,), jnp.float32),
        "b": jax.random.normal(jax.random.fold_in(k, 1), (size_b,)
                               ).astype(jnp.bfloat16),
    }
    shards, true_size = unext.shard_params(params, num_shards)
    out = unext.reconstruct_params(dict(enumerate(shards)), params,
                                   num_shards, true_size)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))
    # the traced twin agrees leaf for leaf at full coverage too
    traced = unext.masked_reconstruct(params, jnp.ones(num_shards, bool))
    for got, want in zip(jax.tree.leaves(traced), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 8), st.integers(1, 3), st.integers(0, 6))
def test_property_exact_coalition_at_most_greedy(n_nodes, redundancy, seed):
    """Greedy set cover is an UPPER bound on the minimum extraction
    coalition: the exact brute-force answer is never larger, and is itself
    a feasible cover."""
    assume(n_nodes * math.ceil(0.6 * 8) >= 8 * redundancy)
    nodes = [f"n{i}" for i in range(n_nodes)]
    try:
        c = ShardCustody.assign(nodes, 8, redundancy=redundancy, seed=seed,
                                max_fraction=0.6)
    except ValueError:
        assume(False)
    greedy = c.min_extraction_coalition()
    exact = c.min_extraction_coalition(exact=True)
    assert 0 < exact <= greedy
    holds = np.asarray(c.holds)
    import itertools as it                     # a size-`exact` cover exists
    assert any(holds[list(combo)].any(0).all()
               for combo in it.combinations(range(n_nodes), exact))


# ============================== data pipeline ==================================
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 20))
def test_property_data_sharding_partitions(num_shards, step):
    """Shards are disjoint slices whose union is the global batch."""
    dcfg = DataConfig(vocab_size=50, seq_len=16, global_batch=8)
    full = lm_batch(dcfg, step)["tokens"]
    parts = [lm_batch(dcfg, step, shard=s, num_shards=num_shards)["tokens"]
             for s in range(num_shards)]
    assert sum(p.shape[0] for p in parts) == full.shape[0]
    # shard determinism
    again = lm_batch(dcfg, step, shard=0, num_shards=num_shards)["tokens"]
    np.testing.assert_array_equal(np.asarray(parts[0]), np.asarray(again))


# ============================== serving ========================================
_SERVE_ENV: dict = {}


def _serve_env():
    """Model + engine built once — every hypothesis example reuses the same
    compiled programs (prompts/arrivals/lengths are traced arguments, so
    drawing new ones never retraces)."""
    if not _SERVE_ENV:
        from repro.configs import get_config
        from repro.core import serving
        from repro.models.model import build_model
        cfg = get_config("protocol-125m").reduced(
            num_layers=1, d_model=32, num_heads=2, head_dim=16, d_ff=64,
            vocab_size=64)
        model = build_model(cfg)
        _SERVE_ENV.update(
            serving=serving, model=model,
            params=model.init(jax.random.PRNGKey(0)),
            engine=serving.ServingEngine(
                model, serving.ServingConfig(slots=2, max_new=4, steps=64),
                jnp.zeros((5, 6), jnp.int32)))
    return _SERVE_ENV


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**20),
       st.lists(st.integers(0, 20), min_size=5, max_size=5),
       st.lists(st.integers(3, 6), min_size=5, max_size=5))
def test_property_serving_engine_matches_greedy(seed, arrivals, plens):
    """The continuous-batching engine reproduces per-request greedy_decode
    outputs bit-exactly for ANY prompts, prompt lengths, and admission
    order (queueing on 2 slots forces recycling + mixed prefill/decode)."""
    env = _serve_env()
    serving, model, params = env["serving"], env["model"], env["params"]
    engine = env["engine"]
    prompts = jax.random.randint(jax.random.PRNGKey(seed), (5, 6), 0, 64)
    lane = serving.build_lane(
        n_requests=5, prompt_lens=np.asarray(plens, np.int32), max_new=4,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        arrivals=np.asarray(arrivals, np.int32))
    res = engine.run(params, lane, prompts)
    assert res.done.all()
    for r in range(5):
        ref, _ = serving.greedy_decode(model, params,
                                       prompts[r:r + 1, :plens[r]], 4)
        np.testing.assert_array_equal(res.tokens[r], np.asarray(ref[0]))
