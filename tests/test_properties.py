"""All hypothesis property tests, in one module guarded by importorskip.

The seed suite imported ``hypothesis`` at the top of test_launch.py and
test_protocol_core.py, so tier-1 died at *collection* when the package was
missing.  Property tests now live here: without hypothesis only this module
skips and every unit test still runs; CI installs hypothesis via
requirements-dev.txt, so these always run there.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import assume, given, settings
from hypothesis import strategies as st

from repro.core import aggregation, compression
from repro.core.ledger import Ledger
from repro.core.unextractable import ShardCustody
from repro.data.pipeline import DataConfig, lm_batch


# =============================== aggregation ===================================
@settings(max_examples=20, deadline=None)
@given(st.integers(3, 12), st.integers(1, 16), st.integers(0, 5))
def test_property_agg_fixed_point(n, d, seed):
    """All aggregators return x when every node submits the same x."""
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(seed), (d,)), (n, d))
    for name in aggregation.AGGREGATORS:
        kw = {"f": 1} if "krum" in name else {}
        agg = aggregation.get_aggregator(name, **kw)(x)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(x[0]),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 10), st.integers(0, 3))
def test_property_agg_permutation_invariant(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 99), n)
    for name in ("mean", "median", "trimmed_mean", "centered_clip"):
        a = aggregation.AGGREGATORS[name](x)
        b = aggregation.AGGREGATORS[name](x[perm])
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 12), st.integers(0, 5))
def test_property_masked_agg_equals_dense_subset(n, seed):
    """The batched-engine contract: masked_agg(X, mask) == agg(X[mask])."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 6)).astype(np.float32))
    mask = rng.random(n) < 0.6
    mask[rng.integers(n)] = True
    for name in aggregation.MASKED_AGGREGATORS:
        kw = {"f": 1} if "krum" in name else {}
        dense = aggregation.get_aggregator(name, **kw)(x[mask])
        masked = aggregation.get_masked_aggregator(name, **kw)(
            x, jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(masked), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


# =============================== compression ===================================
@settings(max_examples=15, deadline=None)
@given(st.integers(8, 200), st.integers(0, 5))
def test_property_qsgd_error_bounded(size, seed):
    """QSGD theory: ‖x − Q(x)‖ ≤ (√d / levels) ‖x‖ (one-sigma-ish bound)."""
    levels = 64
    x = jax.random.normal(jax.random.PRNGKey(seed), (size,))
    c = compression.qsgd_compress(jax.random.PRNGKey(seed + 1), x,
                                  levels=levels)
    y = compression.qsgd_decompress(c)
    err = float(jnp.linalg.norm(y - x))
    bound = (np.sqrt(size) / levels) * float(jnp.linalg.norm(x)) * 3 + 1e-6
    assert err <= bound


# ================================= ledger ======================================
@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                          st.floats(0.0, 10.0)), min_size=1, max_size=20))
def test_property_ledger_conservation(events):
    led = Ledger()
    for node, amount in events:
        led.record_contribution(node, amount)
    assert led.check_conservation()
    total = sum(a for _, a in events)
    assert led.total_shares == pytest.approx(total)
    for n in "abc":
        contributed = sum(a for nn, a in events if nn == n)
        if total:
            assert led.ownership_fraction(n) == pytest.approx(
                contributed / total)


# ============================ unextractability =================================
@settings(max_examples=10, deadline=None)
@given(st.integers(4, 12), st.integers(2, 3), st.integers(0, 4))
def test_property_custody_full_swarm_covers(n_nodes, redundancy, seed):
    # feasibility: total custody slots must cover shards x redundancy
    assume(n_nodes * math.ceil(0.6 * 16) >= 16 * redundancy)
    nodes = [f"n{i}" for i in range(n_nodes)]
    try:
        c = ShardCustody.assign(nodes, 16, redundancy=redundancy, seed=seed,
                                max_fraction=0.6)
    except ValueError:
        # greedy packing can strand capacity on near-tight configs —
        # that's the documented failure mode, not a coverage bug
        assume(False)
    assert c.coverage(nodes) == 1.0
    # redundancy: every shard held by `redundancy` distinct nodes
    for holders in c.assignment.values():
        assert len(set(holders)) == redundancy


# ============================== data pipeline ==================================
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 20))
def test_property_data_sharding_partitions(num_shards, step):
    """Shards are disjoint slices whose union is the global batch."""
    dcfg = DataConfig(vocab_size=50, seq_len=16, global_batch=8)
    full = lm_batch(dcfg, step)["tokens"]
    parts = [lm_batch(dcfg, step, shard=s, num_shards=num_shards)["tokens"]
             for s in range(num_shards)]
    assert sum(p.shape[0] for p in parts) == full.shape[0]
    # shard determinism
    again = lm_batch(dcfg, step, shard=0, num_shards=num_shards)["tokens"]
    np.testing.assert_array_equal(np.asarray(parts[0]), np.asarray(again))
