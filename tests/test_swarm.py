"""End-to-end swarm behaviour: the paper's five §3 properties + §4 + §5.5."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.derailment import no_off_report, simulate_derailment
from repro.core.swarm import NodeSpec, Swarm, SwarmConfig
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem


def _make_swarm(nodes, cfg, n_params=8):
    loss_fn, params0, data_fn, target = tiny_quadratic_problem(n_params)
    opt = SGD(lr=0.1, momentum=0.0)
    swarm = Swarm(loss_fn, params0, opt, nodes, cfg, data_fn)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return swarm, eval_fn, target


def test_honest_swarm_converges():
    nodes = [NodeSpec(f"h{i}") for i in range(6)]
    swarm, eval_fn, _ = _make_swarm(nodes, SwarmConfig(aggregator="mean"))
    losses = swarm.run(40, eval_fn=eval_fn)
    assert losses[-1] < 0.05 * losses[0]


def test_byzantine_breaks_mean_but_not_centered_clip():
    """§3.3: one sign-flipping node derails mean aggregation; CC holds."""
    nodes = [NodeSpec(f"h{i}") for i in range(8)] + \
        [NodeSpec("adv", byzantine="sign_flip", byzantine_scale=20.0)]

    swarm_mean, eval_fn, _ = _make_swarm(nodes, SwarmConfig(aggregator="mean"))
    loss_mean = swarm_mean.run(30, eval_fn=eval_fn)[-1]

    swarm_cc, eval_fn, _ = _make_swarm(
        nodes, SwarmConfig(aggregator="centered_clip",
                           agg_kwargs={"clip_tau": 1.0, "iters": 3}))
    loss_cc = swarm_cc.run(30, eval_fn=eval_fn)[-1]
    assert loss_cc < 0.1 * max(loss_mean, 1e-9) or loss_mean > 10 * loss_cc


def test_elastic_membership():
    """§3 property 3: nodes join and leave without disrupting training."""
    nodes = [NodeSpec("h0"), NodeSpec("h1"),
             NodeSpec("late", join_round=10),
             NodeSpec("early", leave_round=10)]
    swarm, eval_fn, _ = _make_swarm(nodes, SwarmConfig(aggregator="mean"))
    losses = swarm.run(30, eval_fn=eval_fn)
    assert losses[-1] < 0.1 * losses[0]
    assert swarm.history[0]["n_active"] == 3
    assert swarm.history[20]["n_active"] == 3
    # shares minted only while active
    assert swarm.ledger.balances["late"] < swarm.ledger.balances["h0"]


def test_heterogeneous_speed_mints_proportional_shares():
    """§3 property 5 + §4: a 3× faster node earns 3× the shares."""
    nodes = [NodeSpec("fast", speed=3.0), NodeSpec("slow", speed=1.0)]
    swarm, eval_fn, _ = _make_swarm(nodes, SwarmConfig(aggregator="mean"))
    swarm.run(10)
    assert swarm.ledger.balances["fast"] == pytest.approx(
        3.0 * swarm.ledger.balances["slow"])


def test_verification_slashes_cheater():
    """§4.2: a zero-gradient freeloader is audited, slashed, and excluded."""
    vcfg = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3)
    nodes = [NodeSpec(f"h{i}") for i in range(4)] + \
        [NodeSpec("cheat", byzantine="zero")]
    swarm, eval_fn, _ = _make_swarm(
        nodes, SwarmConfig(aggregator="mean", verification=vcfg))
    swarm.run(5)
    assert "cheat" in swarm.slashed
    assert swarm.ledger.burned_stake >= 5.0
    assert not swarm.ledger.can_infer("cheat")
    # honest nodes never slashed despite 100% audit rate
    assert all(f"h{i}" not in swarm.slashed for i in range(4))


def test_verification_with_compression_spares_honest_nodes():
    """Regression: the validator must re-encode its recompute with the
    submitter's wire key — otherwise honest QSGD noise reads as cheating
    (observed: honest nodes slashed at round 0)."""
    vcfg = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3)
    nodes = [NodeSpec(f"h{i}") for i in range(4)] + \
        [NodeSpec("cheat", byzantine="zero")]
    swarm, eval_fn, _ = _make_swarm(
        nodes, SwarmConfig(aggregator="mean", verification=vcfg,
                           compression="qsgd",
                           compression_kwargs={"levels": 64}))
    swarm.run(5)
    assert swarm.slashed == {"cheat"}


def test_wire_compression_still_converges():
    """§3.1: QSGD-compressed gradients reach a good solution."""
    nodes = [NodeSpec(f"h{i}") for i in range(6)]
    swarm, eval_fn, _ = _make_swarm(
        nodes, SwarmConfig(aggregator="mean", compression="qsgd",
                           compression_kwargs={"levels": 64}))
    losses = swarm.run(40, eval_fn=eval_fn)
    assert losses[-1] < 0.2 * losses[0]


# ------------------------------- §5.5 no-off -----------------------------------
def _derail(aggregator, n_attack, verification=None, rounds=25):
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem()
    opt = SGD(lr=0.1, momentum=0.0)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return simulate_derailment(
        loss_fn, params0, opt, data_fn, eval_fn,
        n_honest=8, n_attack=n_attack, rounds=rounds,
        aggregator=aggregator, verification=verification)


def test_derailment_mean_small_attacker_wins():
    """Under mean aggregation a 2/10 attacker fraction derails (the
    emergency off-switch works — and so does any vandal)."""
    res = _derail("mean", n_attack=2)
    assert res.derailed


def test_derailment_robust_agg_resists_minority():
    res = _derail("centered_clip", n_attack=2)
    assert not res.derailed


def test_derailment_robust_agg_fails_past_breakdown():
    """≥ breakdown-point fraction derails even robust aggregation."""
    res = _derail("centered_clip", n_attack=9)       # 9/17 > 1/2
    assert res.derailed


def test_derailment_verification_slashes_attackers():
    """Near-perfect verification: attackers are slashed, training survives —
    the paper's conclusion that only physical intervention remains."""
    v = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3)
    res = _derail("mean", n_attack=2, verification=v)
    assert res.attackers_slashed == 2
    assert not res.derailed


def test_no_off_report_renders():
    rows = [_derail("mean", 2), _derail("centered_clip", 2)]
    rep = no_off_report(rows)
    assert "mean" in rep and "centered_clip" in rep
