"""Kernel conformance suite: every Pallas kernel pinned against its pure-jnp
reference (`pytest -m kernels` runs it standalone; it is part of tier-1).

The contract this suite enforces (docs/kernels.md):

- **masked_agg / qsgd_decode jnp twins** (the CPU fused round path): equal
  to the engine's masked aggregators / wire codec **bit-for-bit** — the
  twins restructure the algorithm (sorting-network median, gram-form krum
  distances, payload-fed decode) but keep every floating-point op of the
  reference.  Krum is the one asterisk: gram d2 != broadcast d2 at the
  last ulp, but krum *selects* a row, so outputs are equal away from exact
  score ties.
- **Pallas kernels** (interpret mode here; compiled jnp twins stand in for
  the compiled axis on CPU — the TPU-compiled path shares this exact
  code): tiled reductions reorder float sums, so decoded/aggregated
  values carry small documented tolerances (~3e-5 like the centralized
  centered_clip kernel); int8 qsgd codes remain bit-exact.
- **fused round == reference round** end to end, including stochastic
  wires: both paths draw identical threefry bits, so params, RoundRecord
  counters, and slashing agree bitwise (hypothesis property test below).

Axes covered: dtypes (fp32 / bf16 inputs), mask patterns (all-live,
churned, single-survivor, all-masked), padding-forcing shapes (D not a
multiple of the block/LANE/bucket), compiled + interpret modes.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import compression
from repro.core.swarm import (_FAR, LaneParams, init_state, make_round_fn,
                              scan_rounds)
from repro.kernels.masked_agg import kernel as magg_kernel
from repro.kernels.masked_agg import ops as magg
from repro.kernels.qsgd_decode import ops as qdec
from repro.kernels.qsgd_decode import ref as qdec_ref

pytestmark = pytest.mark.kernels


def _mask(name: str, n: int):
    return {
        "all_live": jnp.ones(n, bool),
        "churned": jnp.arange(n) % 3 != 0,
        "single_survivor": jnp.arange(n) == min(2, n - 1),
        "all_masked": jnp.zeros(n, bool),
    }[name]


MASKS = ["all_live", "churned", "single_survivor", "all_masked"]
LIVE_MASKS = MASKS[:-1]
# (5, 257): N not a power of two (network pads to 8) and D prime — forces
# the kernel block_d halving loop all the way down and LANE/bucket padding
SHAPES = [(8, 512), (16, 1000), (5, 257)]


def _stack(n, d, dtype=jnp.float32, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 2 + 0.5
    return x.astype(dtype)


# ===================== masked_agg: median warm start ==========================
@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("mask_name", LIVE_MASKS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_median_network_bit_equal(n, d, mask_name, dtype):
    """The Batcher-network median == nanmedian bit-for-bit (pure min/max +
    the same even/odd rank interpolation)."""
    x = _stack(n, d, dtype).astype(jnp.float32)
    m = _mask(mask_name, n)
    ref = agg._masked_median(x, m)
    net = magg.masked_median_net(x, m)
    np.testing.assert_array_equal(np.asarray(net), np.asarray(ref))
    jitted = jax.jit(magg.masked_median_net)(x, m)      # compiled mode
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(ref))


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("mask_name", LIVE_MASKS)
def test_masked_median_pallas_kernel(n, d, mask_name):
    """The Pallas median kernel sorts each tile with the same network —
    bit-equal to nanmedian (no arithmetic reordering to tolerate)."""
    x = _stack(n, d)
    m = _mask(mask_name, n)
    ref = agg._masked_median(x, m)
    out = magg_kernel.masked_median_fwd(x, m, block_d=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ===================== masked_agg: centered_clip ==============================
@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("mask_name", MASKS)
@pytest.mark.parametrize("clip_tau,iters", [(None, 3), (0.7, 2)])
def test_masked_cc_fused_twin_bit_equal(n, d, mask_name, clip_tau, iters):
    """The fused jnp twin == reference masked_centered_clip bitwise — both
    adaptive and fixed τ, interpreted and jit-compiled, incl. the
    all-masked → zeros guard."""
    x = _stack(n, d)
    m = _mask(mask_name, n)
    ref = agg.masked_centered_clip(x, m, clip_tau=clip_tau, iters=iters)
    fused = magg.masked_centered_clip_fused(
        x, m, clip_tau=clip_tau, iters=iters, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    jitted = jax.jit(functools.partial(
        magg.masked_centered_clip_fused, clip_tau=clip_tau, iters=iters,
        use_kernel=False))(x, m)
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(ref))


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("mask_name", MASKS)
@pytest.mark.parametrize("clip_tau", [None, 0.7])
def test_masked_cc_pallas_kernel_bounded(n, d, mask_name, clip_tau):
    """The Pallas CC kernel accumulates per-node norms tile-by-tile —
    reduction order differs from the reference's single jnp.linalg.norm, so
    the aggregate carries the same ~3e-5 tolerance as the centralized
    centered_clip kernel (adaptive τ inherits the perturbed norms)."""
    x = _stack(n, d)
    m = _mask(mask_name, n)
    ref = agg.masked_centered_clip(x, m, clip_tau=clip_tau, iters=3)
    out = magg.masked_centered_clip_fused(
        x, m, clip_tau=clip_tau, iters=3, use_kernel=True, block_d=256,
        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_cc_fused_dtype_coercion(dtype):
    """Fused twins compute in fp32 like the engine's flatten_stack — a
    bf16 stack must agree with the reference fed the fp32-cast stack."""
    x = _stack(8, 300, dtype)
    m = _mask("churned", 8)
    ref = agg.masked_centered_clip(x.astype(jnp.float32), m)
    fused = magg.masked_centered_clip_fused(x, m, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))


# ===================== masked_agg: krum =======================================
@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("mask_name", MASKS)
@pytest.mark.parametrize("f", [1, 2])
def test_masked_krum_fused_selection_equal(n, d, mask_name, f):
    """Gram-form d2 reorders float arithmetic (documented divergence ~1e-6
    relative on scores), but krum RETURNS a selected row — outputs are
    equal away from exact score ties (none at random data)."""
    x = _stack(n, d)
    m = _mask(mask_name, n)
    ref = agg.masked_krum(x, m, f=f)
    for kw in ({"use_kernel": False},
               {"use_kernel": True, "block_d": 256, "interpret": True}):
        out = magg.masked_krum_fused(x, m, f=f, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=str(kw))


def test_krum_d2_kernel_matches_broadcast_reference():
    from repro.kernels.masked_agg.ref import masked_krum_d2_ref
    x = _stack(8, 1000)
    ref = masked_krum_d2_ref(x)
    out = magg_kernel.masked_krum_d2_fwd(x, block_d=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-3)


# ===================== all-masked guards (total churn) ========================
@pytest.mark.parametrize("fn", [
    agg.masked_centered_clip, agg.masked_krum, agg.masked_multi_krum,
    functools.partial(magg.masked_centered_clip_fused, use_kernel=False),
    functools.partial(magg.masked_krum_fused, use_kernel=False),
    functools.partial(magg.masked_centered_clip_fused, use_kernel=True,
                      block_d=256, interpret=True),
    functools.partial(magg.masked_krum_fused, use_kernel=True,
                      block_d=256, interpret=True),
])
def test_all_masked_returns_zeros(fn):
    """Total churn: mask.sum() == 0 is defined to aggregate to zeros (a
    no-op step) — reference and fused twins alike, never NaN or an
    arbitrary surviving row."""
    x = _stack(6, 64)
    out = np.asarray(fn(x, jnp.zeros(6, bool)))
    assert np.array_equal(out, np.zeros_like(out)), out[:8]


# ===================== qsgd_decode ============================================
@pytest.mark.parametrize("size,levels,bucket_size", [
    (100, 16, 1024), (5000, 16, 1024), (3000, 127, 256), (128, 15, 128),
])
def test_wire_encode_bit_compatible_with_compression(size, levels,
                                                     bucket_size):
    """decode(wire_encode(k, x)) == compression.roundtrip("qsgd", k, x):
    same bucketing, same norms, same stochastic draws — the int8 payload
    is a lossless re-encoding of the reference's int32+bool codes."""
    x = jax.random.normal(jax.random.PRNGKey(size), (size,)) * 2
    key = jax.random.PRNGKey(size + 1)
    ref = compression.roundtrip("qsgd", key, x, levels=levels,
                                bucket_size=bucket_size)
    got = qdec.wire_roundtrip(key, x, levels=levels, bucket_size=bucket_size)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_wire_encode_rejects_wide_levels():
    with pytest.raises(ValueError, match="int8"):
        qdec.wire_encode(jax.random.PRNGKey(0), jnp.ones(8), levels=200)


def _payload_stack(n, size, levels, bucket_size, seed=7):
    xs = jax.random.normal(jax.random.PRNGKey(seed), (n, size))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n)
    enc = functools.partial(qdec.wire_encode, levels=levels,
                            bucket_size=bucket_size)
    pay = jax.vmap(enc)(keys, xs)
    dec = jax.vmap(functools.partial(compression.roundtrip, "qsgd",
                                     levels=levels,
                                     bucket_size=bucket_size))(keys, xs)
    return pay, dec


@pytest.mark.parametrize("mask_name", MASKS)
@pytest.mark.parametrize("size,bucket_size", [(5000, 1024), (257, 128)])
def test_decode_accumulate_twin_bit_equal(mask_name, size, bucket_size):
    """Payload-fed masked mean == decode-then-masked_mean bitwise (the jnp
    twin keeps the reference op order; all-masked accumulates to zeros)."""
    n = 8
    pay, dec = _payload_stack(n, size, 16, bucket_size)
    m = _mask(mask_name, n)
    ref = agg.masked_mean(dec, m)
    out = magg.masked_mean_fused(pay, m, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # oracle path (ref.py decodes with explicit sign/magnitude like the
    # wire codec, signed zeros and all)
    k = max(float(jnp.sum(m)), 1.0)
    oracle = qdec_ref.decode_accumulate_ref(pay, m.astype(jnp.float32)) / k
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("mask_name", LIVE_MASKS)
def test_decode_accumulate_pallas_kernel_bounded(mask_name):
    """The Pallas decode-accumulate tile kernel: per-column sums keep the
    node order, so divergence vs the twin is at most reassociation of the
    bucket-scale multiply (~1e-6 relative)."""
    n, size, bucket = 8, 5000, 1024
    pay, dec = _payload_stack(n, size, 16, bucket)
    m = _mask(mask_name, n)
    ref = agg.masked_mean(dec, m)
    out = magg.masked_mean_fused(pay, m, use_kernel=True, block_d=2048,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("agg_name", ["centered_clip", "krum"])
def test_payload_fed_robust_aggregators_bit_equal(agg_name):
    """CC/krum fused twins consume the int8 payload directly and still
    equal the reference fed the decoded fp32 stack."""
    n, size = 8, 1000
    pay, dec = _payload_stack(n, size, 16, 256)
    m = _mask("churned", n)
    if agg_name == "centered_clip":
        ref = agg.masked_centered_clip(dec, m)
        out = magg.masked_centered_clip_fused(pay, m, use_kernel=False)
    else:
        ref = agg.masked_krum(dec, m, f=1)
        out = magg.masked_krum_fused(pay, m, f=1, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ===================== existing kernels: differential table ===================
# The five pre-existing kernels, re-pinned here in one compact table so the
# conformance suite is the single `-m kernels` entry point.  Deeper sweeps
# live in tests/test_kernels.py.
def _case_swa(dtype):
    from repro.kernels.swa_attention.ops import swa_attention
    from repro.models.attention import reference_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32), dtype)
    k = jax.random.normal(ks[1], (1, 256, 2, 32), dtype)
    v = jax.random.normal(ks[2], (1, 256, 2, 32), dtype)
    out = swa_attention(q, k, v, window=96, block_q=64, interpret=True)
    ref = reference_attention(q, k, v, causal=True, window=96)
    return out, ref, (2e-2 if dtype == jnp.bfloat16 else 2e-4)


def _case_qsgd(dtype):
    from repro.kernels.qsgd.ops import qsgd_roundtrip
    from repro.kernels.qsgd.ref import qsgd_roundtrip_ref
    key = jax.random.PRNGKey(2)
    x = (jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 3).astype(dtype)
    out = qsgd_roundtrip(key, x, levels=64, interpret=True)
    ref = qsgd_roundtrip_ref(key, x, levels=64)
    return out, ref, 1e-6


def _case_centered_clip(dtype):
    from repro.kernels.centered_clip.ops import centered_clip as cc_kernel
    from repro.core.aggregation import centered_clip as cc_ref
    x = (jax.random.normal(jax.random.PRNGKey(0), (8, 257)) * 2 + 1).astype(dtype)
    out = cc_kernel(x, clip_tau=1.0, iters=3, interpret=True)
    ref = cc_ref(x.astype(jnp.float32), clip_tau=1.0, iters=3)
    return out, ref, (2e-2 if dtype == jnp.bfloat16 else 3e-5)


def _case_mamba2(dtype):
    from repro.kernels.mamba2_scan.ops import ssd_chunked_pallas
    from repro.models.mamba2 import ssd_reference
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (1, 60, 1, 8), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 60, 1))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (1,)) * 0.5)
    b = (jax.random.normal(ks[3], (1, 60, 4)) * 0.5).astype(dtype)
    c = (jax.random.normal(ks[4], (1, 60, 4)) * 0.5).astype(dtype)
    d = jnp.ones((1,)) * 0.5
    y_ref, _ = ssd_reference(x.astype(jnp.float32), dt.astype(jnp.float32),
                             a, b.astype(jnp.float32), c.astype(jnp.float32), d)
    y, _ = ssd_chunked_pallas(x.astype(jnp.float32), dt.astype(jnp.float32),
                              a, b.astype(jnp.float32), c.astype(jnp.float32),
                              d, chunk=16, interpret=True)
    return y, y_ref, 3e-4


def _case_rwkv6(dtype):
    from repro.kernels.rwkv6_wkv.ops import wkv_chunked_pallas
    from repro.models.rwkv6 import wkv_reference
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (1, 40, 1, 8)) * 0.5
    k = jax.random.normal(ks[1], (1, 40, 1, 8)) * 0.5
    v = jax.random.normal(ks[2], (1, 40, 1, 8))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 40, 1, 8)) - 1) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (1, 8)) * 0.1
    y_ref, _ = wkv_reference(r, k, v, w, u)
    y, _ = wkv_chunked_pallas(r, k, v, w, u, chunk=16, interpret=True)
    return y, y_ref, 3e-4


EXISTING = {"swa_attention": _case_swa, "qsgd": _case_qsgd,
            "centered_clip": _case_centered_clip, "mamba2_scan": _case_mamba2,
            "rwkv6_wkv": _case_rwkv6}


@pytest.mark.parametrize("name", sorted(EXISTING))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_existing_kernel_conformance(name, dtype):
    if dtype == jnp.bfloat16 and name in ("mamba2_scan", "rwkv6_wkv"):
        pytest.skip("recurrent scans are pinned in fp32 (model casts)")
    out, ref, tol = EXISTING[name](dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ===================== fused round == reference round =========================
def _round_problem(n=6, d=96):
    key = jax.random.PRNGKey(3)
    target = jax.random.normal(key, (d,))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["x"] @ target))

    def batch_fn(rnd):
        k = jax.random.fold_in(jax.random.PRNGKey(9), rnd)
        return {"x": jax.random.normal(k, (n, 4, d))}

    return loss_fn, {"w": jnp.zeros((d,))}, batch_fn


def _lane(n, codes, leaves=None, seed=11, p_check=0.0):
    return LaneParams(
        codes=jnp.asarray(codes, jnp.int32),
        scales=jnp.full((n,), 2.0), speeds=jnp.ones((n,)),
        joins=jnp.zeros((n,), jnp.int32),
        leaves=(jnp.full((n,), _FAR, jnp.int32) if leaves is None
                else jnp.asarray(leaves, jnp.int32)),
        base_key=jax.random.PRNGKey(seed), p_check=jnp.asarray(p_check),
        tolerance=jnp.asarray(1e-3), numeric_noise=jnp.asarray(0.0),
        agg_id=jnp.asarray(0, jnp.int32), agg_kwargs={})


def _run_both(aggregator, compression_kind, ckw, lane, *, verify=False,
              rounds=4, n=6, d=96):
    import optax
    loss_fn, params0, batch_fn = _round_problem(n, d)
    opt = optax.sgd(0.05)
    outs = []
    for fused in (False, True):
        rf = make_round_fn(loss_fn, opt, params0, n, aggregator=aggregator,
                           compression_kind=compression_kind,
                           compression_kwargs=ckw, verify=verify,
                           fused=fused)
        st, recs, _ = jax.jit(lambda l, rf=rf: scan_rounds(
            rf, l, init_state(params0, opt, n), rounds, batch_fn))(lane)
        outs.append((st, recs))
    return outs


@pytest.mark.parametrize("aggregator,kind,ckw,verify", [
    ("centered_clip", "qsgd", {"levels": 16, "bucket_size": 64}, True),
    ("centered_clip", None, {}, False),
    ("krum", "qsgd", {"levels": 16, "bucket_size": 64}, False),
    ("mean", "qsgd", {"levels": 16, "bucket_size": 64}, True),
])
def test_fused_round_bit_equal(aggregator, kind, ckw, verify):
    """make_round_fn(fused=True) == fused=False bitwise: final params and
    every RoundRecord counter, through corruption, the stochastic qsgd
    wire, audits/slashing, and churn."""
    n = 6
    lane = _lane(n, [0, 0, 1, 0, 3, 2], leaves=[_FAR] * 5 + [2],
                 p_check=0.5 if verify else 0.0)
    (st_u, rec_u), (st_f, rec_f) = _run_both(aggregator, kind, ckw, lane,
                                             verify=verify)
    np.testing.assert_array_equal(np.asarray(st_u.params["w"]),
                                  np.asarray(st_f.params["w"]))
    np.testing.assert_array_equal(np.asarray(st_u.slashed),
                                  np.asarray(st_f.slashed))
    for fld in ("keep", "caught", "agg_norm", "n_active"):
        np.testing.assert_array_equal(
            np.asarray(getattr(rec_u, fld)), np.asarray(getattr(rec_f, fld)),
            err_msg=fld)


def test_fused_auto_threshold_and_exposure():
    """fused=None resolves by stack bytes; the choice is inspectable on the
    returned round_fn; unsupported combinations raise for fused=True."""
    import optax
    loss_fn, params0, _ = _round_problem()
    opt = optax.sgd(0.1)
    mk = functools.partial(make_round_fn, loss_fn, opt)
    small = mk(params0, 6, aggregator="centered_clip")
    assert small.fused is False and small.stack_bytes < magg.FUSED_MIN_BYTES
    big_params = {"w": jnp.zeros((magg.FUSED_MIN_BYTES // 4 // 6 + 1,))}
    big = mk(big_params, 6, aggregator="centered_clip")
    assert big.fused is True
    assert mk(big_params, 6, aggregator="trimmed_mean").fused is False
    assert mk(big_params, 6, aggregator="centered_clip",
              compression_kind="topk").fused is False
    with pytest.raises(ValueError, match="fused=True unsupported"):
        mk(params0, 6, aggregator="median", fused=True)
    with pytest.raises(ValueError, match="levels"):
        mk(params0, 6, aggregator="mean", compression_kind="qsgd",
           compression_kwargs={"levels": 200}, fused=True)


# ===================== fused-round property ===================================
# The property: for ANY roster behaviour mix, seed, churn point, and wire
# choice, the fused centered_clip round reproduces the reference round
# bit-exactly (stochastic rounding included — both paths consume the same
# threefry draws).  A fixed grid always runs; hypothesis fuzzes the same
# property when installed (tier-1 containers without it keep the grid).
def _check_fused_round_property(codes, seed, leave, compressed):
    n = 6
    leaves = [_FAR] * (n - 1) + [leave]
    lane = _lane(n, codes, leaves=leaves, seed=seed)
    kind = "qsgd" if compressed else None
    ckw = {"levels": 16, "bucket_size": 64} if compressed else {}
    (st_u, rec_u), (st_f, rec_f) = _run_both("centered_clip", kind, ckw,
                                             lane, rounds=3)
    np.testing.assert_array_equal(np.asarray(st_u.params["w"]),
                                  np.asarray(st_f.params["w"]))
    np.testing.assert_array_equal(np.asarray(rec_u.agg_norm),
                                  np.asarray(rec_f.agg_norm))


@pytest.mark.parametrize("codes,seed,leave,compressed", [
    ([0, 0, 0, 0, 0, 0], 0, 5, True),          # all honest
    ([1, 2, 3, 4, 5, 0], 7, 2, True),          # every behaviour at once
    ([3, 3, 3, 0, 0, 0], 123, 1, False),       # noise-heavy, early churn
    ([0, 5, 0, 5, 0, 5], 2**31 - 1, 4, True),  # alternating inner_product
])
def test_fused_round_property_grid(codes, seed, leave, compressed):
    _check_fused_round_property(codes, seed, leave, compressed)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        codes=st.lists(st.integers(0, 5), min_size=6, max_size=6),
        seed=st.integers(0, 2**31 - 1),
        leave=st.integers(1, 5),
        compressed=st.booleans(),
    )
    def test_fused_round_property_fuzzed(codes, seed, leave, compressed):
        _check_fused_round_property(codes, seed, leave, compressed)
except ImportError:                              # pragma: no cover
    pass
