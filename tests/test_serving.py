"""Serving engine tests: scanned-decoder equivalence, continuous-batching
equivalence with per-request greedy decoding, custody-gated halting,
on-device credential admission, and the serving sweep."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import serving
from repro.core.scenarios import get_serving_grid, list_serving_grids
from repro.core.unextractable import ShardCustody, assign_matrix
from repro.models.model import build_model

_FAR = np.iinfo(np.int32).max


@pytest.fixture(scope="module")
def serve_model():
    cfg = get_config("protocol-125m").reduced(
        num_layers=1, d_model=32, num_heads=2, head_dim=16, d_ff=64,
        vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def workload(serve_model):
    cfg, model, params = serve_model
    r, p = 6, 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (r, p), 0,
                                 cfg.vocab_size)
    plens = np.array([6, 4, 5, 6, 3, 4], np.int32)
    return prompts, plens


@pytest.fixture(scope="module")
def greedy_reference(serve_model, workload):
    """Per-request python-loop greedy outputs — the oracle."""
    _, model, params = serve_model
    prompts, plens = workload
    max_new = 5
    refs = []
    for r in range(prompts.shape[0]):
        gen, _ = serving.greedy_decode_loop(
            model, params, prompts[r:r + 1, :int(plens[r])], max_new)
        refs.append(np.asarray(gen[0]))
    return np.stack(refs), max_new


@pytest.fixture(scope="module")
def engine(serve_model, workload):
    _, model, _ = serve_model
    prompts, _ = workload
    cfg = serving.ServingConfig(slots=3, max_new=5, steps=44)
    return serving.ServingEngine(model, cfg, prompts)


# ---------------------- scanned greedy decoder ---------------------------------
def test_scanned_greedy_matches_loop(serve_model, workload):
    _, model, params = serve_model
    prompts, _ = workload
    g_scan, stats = serving.greedy_decode(model, params, prompts, 6)
    g_loop, _ = serving.greedy_decode_loop(model, params, prompts, 6)
    assert np.array_equal(np.asarray(g_scan), np.asarray(g_loop))
    assert g_scan.shape == (prompts.shape[0], 6)
    assert stats.tokens_out == 6


# ---------------------- continuous-batching equivalence ------------------------
@pytest.mark.parametrize("order", [
    [0, 1, 2, 3, 4, 5],          # arrival = request order
    [5, 3, 1, 0, 2, 4],          # shuffled admission order
    [2, 2, 2, 9, 9, 9],          # bursts (ties admitted in request order)
])
def test_engine_reproduces_per_request_greedy(serve_model, workload,
                                              greedy_reference, engine, order):
    """The engine's continuous batching — queueing on 3 slots, mixed
    prefill/decode slot states, slot recycling — must deliver exactly the
    tokens per-request greedy decoding delivers, whatever the admission
    order."""
    _, model, params = serve_model
    prompts, plens = workload
    refs, max_new = greedy_reference
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0, 100.0],
        fee=1.0, arrivals=np.asarray(order, np.int32))
    res = engine.run(params, lane)
    assert res.done.all(), "all requests must complete within the horizon"
    assert np.array_equal(res.tokens, refs)


def test_engine_recycles_slots_without_leaking_cache(serve_model, workload,
                                                     greedy_reference, engine):
    """6 requests through 3 slots forces every slot to serve two requests;
    outputs staying bit-exact proves the masked cache reset (pristine KV
    state per admission) works."""
    _, model, params = serve_model
    prompts, plens = workload
    refs, _ = greedy_reference
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=10.0)                          # everything arrives at step 0
    res = engine.run(params, lane)
    assert res.done.all()
    assert int(res.n_active.max()) == 3     # the pool really was saturated
    assert np.array_equal(res.tokens, refs)


def test_engine_honours_per_request_decode_budgets(serve_model, workload,
                                                   engine):
    """Per-request max_new: a slot retires the moment ITS request is done
    (no head-of-line padding to the batch max), and each request's tokens
    equal its own greedy decode of exactly that length."""
    _, model, params = serve_model
    prompts, plens = workload
    budgets = np.array([5, 2, 4, 1, 3, 5], np.int32)
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=budgets,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=10.0)
    res = engine.run(params, lane)
    assert res.done.all()
    for r in range(prompts.shape[0]):
        ref, _ = serving.greedy_decode(
            model, params, prompts[r:r + 1, :int(plens[r])], int(budgets[r]))
        np.testing.assert_array_equal(res.tokens[r, :budgets[r]],
                                      np.asarray(ref[0]))
        assert (res.tokens[r, budgets[r]:] == 0).all()   # untouched buffer


# ---------------------- custody coupling ---------------------------------------
def test_serving_halts_exactly_when_coverage_below_one(serve_model, workload,
                                                       engine):
    """Tokens are served on a step iff every shard has a live holder —
    serving halts exactly when coverage < 1, and resumes when the outage
    heals."""
    _, model, params = serve_model
    prompts, plens = workload
    custody = assign_matrix(4, 8, redundancy=1, seed=0, max_fraction=0.5)
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=0.5, custody=custody)
    # node 0 suffers an outage mid-horizon, then returns
    down_from = np.full(4, _FAR, np.int32)
    down_until = np.full(4, _FAR, np.int32)
    down_from[0], down_until[0] = 8, 20
    lane = lane._replace(node_down_from=jnp.asarray(down_from),
                         node_down_until=jnp.asarray(down_until))
    res = engine.run(params, lane)
    assert (res.live == (res.coverage >= 1.0)).all()
    assert not res.live[8:20].any()          # redundancy 1: outage kills it
    assert (res.new_tokens[~res.live] == 0).all()
    assert res.new_tokens[20:].sum() > 0     # serving resumed after the heal
    assert res.done.all()                    # and finished the backlog
    assert res.availability < 1.0


@pytest.mark.parametrize("departed", [[], ["n0"], ["n1", "n2"], ["n3"]])
def test_availability_agrees_with_tolerates_departures(serve_model, workload,
                                                       engine, departed):
    """A static departed set halts serving iff the custody engine says the
    swarm does not tolerate those departures."""
    _, model, params = serve_model
    prompts, plens = workload
    holds = assign_matrix(4, 8, redundancy=2, seed=0, max_fraction=0.5)
    custody = ShardCustody(8, 2, tuple(f"n{i}" for i in range(4)),
                           jnp.asarray(holds))
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=0.5, custody=holds)
    down_from = np.full(4, _FAR, np.int32)
    for d in departed:
        down_from[int(d[1:])] = 0
    lane = lane._replace(node_down_from=jnp.asarray(down_from))
    res = engine.run(params, lane)
    assert bool(res.live.all()) == custody.tolerates_departures(departed)


# ---------------------- credential admission -----------------------------------
def test_admission_gated_by_credentials_on_device(serve_model, workload,
                                                  engine):
    """Requests whose holder cannot afford the fee (strict
    balance - fee > min_shares, the Ledger.can_infer boundary) are never
    admitted; funded holders' requests all complete and pay their fees."""
    _, model, params = serve_model
    prompts, plens = workload
    # holder 0 funds requests 0/2/4; holder 1 (requests 1/3/5) holds exactly
    # one fee — strictly > 0 is required AFTER the spend, so only nothing
    # can be afforded: balance - fee == 0 is refused at the boundary
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0, 1.0], fee=1.0,
        load=10.0)
    res = engine.run(params, lane)
    assert res.admitted[0::2].all() and res.done[0::2].all()
    assert not res.admitted[1::2].any() and not res.done[1::2].any()
    np.testing.assert_allclose(res.balances, [97.0, 1.0])


def test_same_step_burst_cannot_overdraw_credentials(serve_model, workload,
                                                     engine):
    """Regression: funding used to be checked against step-start balances
    for every candidate independently, so a same-holder burst admitted in
    one step could drive the balance negative.  The k-th same-step sibling
    must afford k+1 fees — with balance 2.5 and fee 1 only two of three
    burst requests are ever served (0.5 left cannot strictly exceed 0
    after another fee)."""
    _, model, params = serve_model
    prompts, plens = workload
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[2.5, 100.0], fee=1.0,
        holders=np.array([0, 1, 0, 1, 0, 1], np.int32),
        load=10.0)                          # everything arrives at step 0
    res = engine.run(params, lane)
    assert res.done[1::2].all()             # holder 1: all served
    assert int(res.admitted[0::2].sum()) == 2   # holder 0: exactly two
    assert not res.done[4]                  # the third sibling never runs
    np.testing.assert_allclose(res.balances, [0.5, 97.0])
    assert res.balances.min() >= 0.0
    # refused waiters are not demand: the lane still reads fully available
    assert res.availability == 1.0


def test_admission_is_fifo_by_arrival_not_request_index(serve_model):
    """Regression: admission used to rank waiting requests by request
    index, so a later-arriving low-index request preempted an
    earlier-arriving high-index one.  On a 1-slot engine with a horizon
    that only fits two requests, the long-waiting request must win the
    contested slot."""
    cfg, model, params = serve_model
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 3), 0,
                                 cfg.vocab_size)
    scfg = serving.ServingConfig(slots=1, max_new=2, steps=10)
    engine = serving.ServingEngine(model, scfg, prompts)
    lane = serving.build_lane(
        n_requests=3, prompt_lens=np.full(3, 3, np.int32), max_new=2,
        steps=scfg.steps, n_nodes=2, balances=[100.0], fee=1.0,
        arrivals=np.array([5, 0, 0], np.int32))
    res = engine.run(params, lane)
    # r1 serves first (steps 0-4); at step 5 both r0 (arrived 5) and r2
    # (arrived 0, waited 5 steps) contend — FIFO admits r2
    assert res.done.tolist() == [False, True, True]


def test_engine_validates_lane_shapes(serve_model, workload, engine):
    """Prompts longer than the buffer (or a mis-shaped prompts override)
    would silently re-feed the last buffered token — refuse them."""
    _, model, params = serve_model
    prompts, plens = workload
    bad = plens.copy()
    bad[0] = prompts.shape[1] + 3
    lane = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=bad, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=1.0)
    with pytest.raises(ValueError, match="prompt buffer width"):
        engine.run(params, lane)
    good = serving.build_lane(
        n_requests=prompts.shape[0], prompt_lens=plens, max_new=5,
        steps=engine.cfg.steps, n_nodes=4, balances=[100.0], fee=1.0,
        load=1.0)
    with pytest.raises(ValueError, match="max_new"):
        engine.run(params, good._replace(
            max_new=jnp.full((prompts.shape[0],), 99, jnp.int32)))
    # a zero decode budget would never satisfy the retirement condition
    # and wedge its slot for the whole horizon
    with pytest.raises(ValueError, match="wedge"):
        engine.run(params, good._replace(
            max_new=jnp.zeros((prompts.shape[0],), jnp.int32)))
    with pytest.raises(ValueError, match="compiled shape"):
        engine.run(params, good, prompts=jnp.zeros((2, 2), jnp.int32))


# ---------------------- the serving campaign -----------------------------------
def test_serving_sweep_one_program_and_table(serve_model):
    _, model, params = serve_model
    grid = get_serving_grid("serving_smoke")
    res = serving.sweep(model, params, grid)
    assert res.n_programs == 1
    assert res.n_runs == grid.n_points == len(res.cells)
    table = res.availability_table()
    assert "load=" in table and "S=served" in table
    # zero churn at any redundancy serves everything with full availability
    for c in res.cells:
        if c.churn_rate == 0 and c.coalition_fraction == 0:
            assert c.regime == "served" and c.availability == 1.0
    # the sweep exercises all three grid axes
    assert {c.redundancy for c in res.cells} == set(grid.redundancies)
    assert {c.load for c in res.cells} == set(grid.loads)
    assert {c.churn_rate for c in res.cells} == set(grid.churn_rates)


def test_sweep_lane_matches_single_run(serve_model):
    """Lane k of the vmapped campaign reproduces the single-lane run —
    the serving twin of the campaign-vs-Swarm equivalence tests."""
    _, model, params = serve_model
    grid = get_serving_grid("serving_smoke")
    prompts = jax.random.randint(jax.random.PRNGKey(0),
                                 (grid.n_requests, grid.prompt_len), 0,
                                 model.cfg.vocab_size)
    res = serving.sweep(model, params, grid, prompts=prompts)
    # rebuild lane 2 (load, churn, red ordering as in sweep) by hand
    cell = res.cells[2]
    cfg = serving.ServingConfig(slots=grid.slots, max_new=grid.max_new,
                                steps=grid.steps)
    plens = (grid.prompt_len // 2 + np.arange(grid.n_requests)
             % (grid.prompt_len - grid.prompt_len // 2 + 1)).astype(np.int32)
    lane = serving.build_lane(
        n_requests=grid.n_requests, prompt_lens=plens,
        max_new=grid.max_new, steps=grid.steps,
        n_nodes=grid.n_nodes,
        balances=np.full(grid.n_holders,
                         grid.fee * grid.n_requests + 1.0, np.float32),
        fee=grid.fee, load=cell.load,
        custody=assign_matrix(grid.n_nodes, grid.num_shards,
                              cell.redundancy, seed=0,
                              max_fraction=grid.max_fraction),
        churn_rate=cell.churn_rate, coalition_fraction=cell.coalition_fraction,
        defect_step=grid.defect_step, seed=cell.seed)
    single = serving.ServingEngine(model, cfg, prompts).run(params, lane)
    assert int(single.done.sum()) == cell.completed
    assert single.tokens_served == cell.tokens_served
    assert single.availability == pytest.approx(cell.availability)


def test_serving_grids_registered():
    names = list_serving_grids()
    assert {"serving_frontier", "serving_coalition",
            "serving_smoke"} <= set(names)
    with pytest.raises(KeyError, match="serving_smoke"):
        get_serving_grid("nope")
