"""Custody engine coverage: the vectorized custody matrix, the swarm's
custody lane, and the §4.1 extractability axis of the campaign engine.

The load-bearing property: **custody is pure observability** — a
fully-redundant custody lane (every node holds every shard) reproduces the
plain ``Swarm`` histories bit-exactly, including under churn and
decentralized topology (the custody analogue of PR 3's FC-decentralized ≡
centralized test).  Plus the acceptance path: a (redundancy × coalition
fraction × seed) custody sweep compiles to ONE device program, emits an
extractability phase table, and its reconstruct-attack eval gives
sub-coverage coalitions garbage loss while full coverage matches the
honest model exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unextractable as unext
from repro.core.derailment import sweep
from repro.core.scenarios import Regime, SweepGrid, get_scenario
from repro.core.swarm import (
    NodeSpec,
    SwarmConfig,
    lane_for_nodes,
    make_swarm,
    run_campaign,
    stack_lanes,
)
from repro.core.unextractable import CustodyConfig
from repro.optim.optimizer import SGD

from conftest import tiny_quadratic_problem


def _full_custody(n: int, shards: int = 8) -> CustodyConfig:
    """Every node holds every shard — the maximally redundant lane."""
    return CustodyConfig(num_shards=shards, redundancy=n, max_fraction=1.0)


# ------------------- custody is pure observability -----------------------------
@pytest.mark.parametrize("scenario", [
    "sign_flip_minority",
    "audit_heavy",
    "high_churn_elastic",
    "gossip_ring_honest",          # decentralized: per-node replicas
    "byzantine_neighborhood",      # decentralized + byzantine
])
def test_fully_redundant_custody_matches_plain_swarm(scenario):
    """The custody lane must never perturb training: with every node
    holding every shard, the custody run's histories and final params are
    bit-identical to the plain run's — including churn (membership gates
    coverage, not math) and decentralized topology (replicas + gossip)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes, cfg = get_scenario(scenario).build(n_nodes=8, seed=0)
    ccfg = dataclasses.replace(cfg, custody=_full_custody(8))
    opt = lambda: SGD(lr=0.1, momentum=0.0)
    plain = make_swarm(loss_fn, params0, opt(), nodes, cfg, data_fn)
    custody = make_swarm(loss_fn, params0, opt(), nodes, ccfg, data_fn)
    for r in range(12):
        plain.step(r)
        custody.step(r)
    np.testing.assert_array_equal(
        [h["agg_norm"] for h in custody.history],
        [h["agg_norm"] for h in plain.history], err_msg=scenario)
    assert [h["caught"] for h in custody.history] == \
        [h["caught"] for h in plain.history]
    np.testing.assert_array_equal(
        [h["consensus_error"] for h in custody.history],
        [h["consensus_error"] for h in plain.history])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), custody.params, plain.params)
    # and full redundancy means the frontier never moves
    assert all(h["coverage"] == 1.0 for h in custody.history)
    assert custody.ledger.balances == pytest.approx(plain.ledger.balances)


def test_fully_redundant_scanned_run_matches_plain_scan():
    """Same equivalence through the lax.scan fast path (no per-round
    host round-trips)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes, cfg = get_scenario("high_churn_elastic").build(n_nodes=8, seed=0)
    ccfg = dataclasses.replace(cfg, custody=_full_custody(8))
    opt = lambda: SGD(lr=0.1, momentum=0.0)
    plain = make_swarm(loss_fn, params0, opt(), nodes, cfg, data_fn)
    custody = make_swarm(loss_fn, params0, opt(), nodes, ccfg, data_fn)
    plain.run(12)
    custody.run(12)
    np.testing.assert_array_equal(
        [h["agg_norm"] for h in custody.history],
        [h["agg_norm"] for h in plain.history])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), custody.params, plain.params)


# ------------------------ the coverage frontier --------------------------------
def test_coverage_trace_collapses_under_churn():
    """custody_churn_collapse: once every holder of some shard has
    departed, the live coverage drops below 1 and — with a leave-only
    roster — never recovers (the frontier is monotone nonincreasing)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarm = get_scenario("custody_churn_collapse").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=9)
    for r in range(14):
        swarm.step(r)
    cov = [h["coverage"] for h in swarm.history]
    assert cov[0] == 1.0                       # everyone present at round 0
    assert cov[-1] < 1.0                       # some shard lost every holder
    assert all(a >= b for a, b in zip(cov, cov[1:]))   # leave-only: monotone
    # the engine's host view agrees with the device trace at the last round
    active = [i for i, n in enumerate(swarm.nodes)
              if n.active(13) and n.node_id not in swarm.slashed]
    assert swarm._coverage_of(active) == pytest.approx(cov[-1])


def test_custody_leech_coalition_below_coverage():
    """custody_leech: the leech coalition stays below full coverage (the
    0.4 custody bound), the swarm keeps full live coverage, and the
    scenario's custody matrix respects the per-node cap."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    swarm = get_scenario("custody_leech").build_swarm(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn, n_nodes=8)
    swarm.run(10)
    assert all(h["coverage"] == 1.0 for h in swarm.history)
    holds = swarm.custody_matrix
    cap = int(np.ceil(0.4 * holds.shape[1]))
    assert (holds.sum(axis=1) <= cap).all()
    coal = unext.coalition_tail_mask(8, 0.25)      # the 2 leeches
    assert float(unext.coverage_frac(jnp.asarray(holds),
                                     jnp.asarray(coal))) < 1.0


# ----------------- the §4.1 custody axis of the campaign engine ----------------
def test_custody_axis_sweep_is_one_program():
    """Acceptance: a (redundancy × coalition fraction × seed) custody grid
    compiles to ONE device program, emits an extractability phase table,
    and the reconstruct-attack eval prices sub-coverage coalitions as
    garbage while full coverage matches the honest model exactly."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = SweepGrid(
        name="cust", description="", n_honest=6, attacker_counts=(0,),
        seeds=(0, 1), rounds=8,
        regimes=(Regime("mean", "mean"),),
        redundancies=(1, 2), coalition_fractions=(0.5, 1.0),
        num_shards=8, custody_leave_fraction=0.34)
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)
    assert res.n_programs == 1
    assert len(res.results) == grid.n_points == 8
    assert res.n_runs == 8 + 2                 # + per-seed baselines
    for r in res.results:
        assert np.isfinite(r.final_loss) and np.isfinite(r.extracted_loss)
        if r.coalition_coverage >= 1.0:
            assert r.extractability == "extractable"
            # full coverage: masked_reconstruct is the identity, so the
            # reconstruct-attack eval IS the honest eval, bit for bit
            assert r.extracted_loss == r.final_loss
        else:
            assert r.extractability in ("protocol_model", "degraded")
            # sub-coverage reconstruction is strictly worse than the honest
            # model, and clearly garbage once most shards are missing
            assert r.extracted_loss > r.final_loss
            if r.coalition_coverage <= 0.7:
                assert r.extracted_loss > 2.0 * r.final_loss
    # churn starves redundancy-1 cells: some shard loses its only holder
    assert any(r.extractability == "degraded" for r in res.results
               if r.redundancy == 1)
    table = res.extractability_table()
    assert "extractable" in table and "protocol_model" in table
    assert "r=1" in table and "coal=0.50" in table


def test_custody_sweep_coverage_trace_matches_engine():
    """A custody sweep lane's coverage trace equals the single-run engine's
    history for the same roster/schedule (the campaign is just the scanned
    engine vmapped)."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    nodes = [NodeSpec(f"h{i}") for i in range(5)] + \
        [NodeSpec("leaver", leave_round=4)]
    cfg = SwarmConfig(aggregator="mean", custody=CustodyConfig(
        num_shards=8, redundancy=1, max_fraction=0.5))
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    swarm = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0), nodes,
                       cfg, data_fn)
    swarm.run(8)
    _, recs, final = run_campaign(
        loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
        stack_lanes([lane_for_nodes(nodes, cfg)]), rounds=8,
        aggregator="mean", eval_fn=eval_fn)
    np.testing.assert_allclose(np.asarray(recs.coverage[0]),
                               [h["coverage"] for h in swarm.history])
    assert np.asarray(final).shape == (1, 2)   # [honest, extracted]


def test_custody_axis_composes_with_topology_axis():
    """Custody and topology are orthogonal traced lanes: a decentralized
    custody sweep runs per-node replicas + gossip AND the reconstruct
    attack (on the consensus params) in the same single program."""
    loss_fn, params0, data_fn, _ = tiny_quadratic_problem(8)
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = SweepGrid(
        name="cust_topo", description="", n_honest=6, attacker_counts=(0,),
        seeds=(0,), rounds=6,
        regimes=(Regime("mean", "mean"),),
        topologies=("ring",),
        redundancies=(2,), coalition_fractions=(0.5, 1.0), num_shards=8)
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)
    assert res.n_programs == 1 and len(res.results) == 2
    by = {r.coalition_fraction: r for r in res.results}
    assert by[1.0].extracted_loss == by[1.0].final_loss
    assert by[0.5].extracted_loss > by[0.5].final_loss
    assert all(r.topology == "ring" for r in res.results)


# --------------------- vectorized coalition analysis ---------------------------
def test_stacked_coalitions_evaluate_in_one_call():
    """The vectorized reductions take a (K, N) stack of coalitions and
    agree with the per-coalition name-keyed methods."""
    nodes = [f"n{i}" for i in range(8)]
    c = unext.ShardCustody.assign(nodes, 16, redundancy=2, max_fraction=0.4)
    rng = np.random.default_rng(0)
    masks = rng.random((20, 8)) < 0.4
    cov = unext.coverage_frac(c.holds, jnp.asarray(masks))
    can = unext.can_extract_all(c.holds, jnp.asarray(masks))
    tol = unext.tolerates_departures_all(c.holds, jnp.asarray(masks))
    assert cov.shape == can.shape == tol.shape == (20,)
    for k in range(20):
        coalition = [nodes[i] for i in np.flatnonzero(masks[k])]
        assert float(cov[k]) == pytest.approx(c.coverage(coalition))
        assert bool(can[k]) == c.can_extract(coalition)
        assert bool(tol[k]) == c.tolerates_departures(coalition)


def test_min_extraction_coalition_exact_mode():
    """Greedy set cover is an UPPER bound on the minimum coalition (the old
    docstring claimed 'lower'); exact=True brute-forces the true minimum,
    which is feasible and never larger than greedy."""
    nodes = [f"n{i}" for i in range(8)]
    c = unext.ShardCustody.assign(nodes, 16, redundancy=2, max_fraction=0.4,
                                  seed=3)
    greedy = c.min_extraction_coalition()
    exact = c.min_extraction_coalition(exact=True)
    assert 0 < exact <= greedy
    # exact is achieved by SOME coalition of that size...
    import itertools
    holds = np.asarray(c.holds)
    assert any(holds[list(combo)].any(0).all()
               for combo in itertools.combinations(range(8), exact))
    # ...and no smaller coalition covers
    if exact > 1:
        assert not any(holds[list(combo)].any(0).all()
                       for combo in itertools.combinations(range(8), exact - 1))
    # per-node bound: nobody covers alone, so the minimum is >= ceil(1/0.4)
    assert exact >= 3


def test_masked_reconstruct_roundtrip_and_garbage():
    """masked_reconstruct == shard_params -> reconstruct_params: identity at
    full coverage (mixed dtypes, padding), zero-filled chunks below it."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (5, 7)),
              "b": jnp.asarray(np.linspace(-2, 2, 11), jnp.bfloat16)}
    S = 7                                       # 46 elements -> pad to 49
    shards, true_size = unext.shard_params(params, S)
    full = unext.masked_reconstruct(params, jnp.ones(S, bool))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), full, params)
    covered = jnp.asarray(np.arange(S) < 3)
    got = unext.masked_reconstruct(params, covered)
    want = unext.reconstruct_params({i: shards[i] for i in range(3)}, params,
                                    S, true_size)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), got, want)
