"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import build_model
from repro.optim.optimizer import AdamW


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_smoke_forward_and_train_step(arch, dtype):
    """One forward + one train step on a reduced same-family variant."""
    cfg = get_config(arch).reduced(dtype=dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.concrete_batch(jax.random.PRNGKey(1), 2, 64)

    loss, aux = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt = AdamW(lr=1e-3)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    new_params, _ = opt.update(grads, opt.init(params), params)
    loss2, _ = jax.jit(model.loss)(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    # shapes preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                 params, new_params)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, tok, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                 cache, cache2)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-1.6b",
                                  "zamba2-1.2b", "h2o-danube-1.8b"])
def test_decode_matches_teacher_forcing(arch):
    """Stepping tokens through decode == full-sequence forward logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    seq = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, seq), 0, cfg.vocab_size)

    cache = model.init_cache(1, seq)
    step = jax.jit(model.decode_step)
    last = None
    for i in range(seq):
        last, cache = step(params, toks[:, i:i + 1], cache)

    prefill_logits = model.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(last[:, -1]), np.asarray(prefill_logits),
        rtol=2e-3, atol=2e-3)


def test_vlm_media_tokens():
    cfg = get_config("qwen2-vl-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.concrete_batch(jax.random.PRNGKey(1), 2, 64)
    assert batch["media"].shape == (2, cfg.num_media_tokens, cfg.d_model)
    assert batch["tokens"].shape[1] == 64 - cfg.num_media_tokens
    loss, _ = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


def test_audio_encdec_shapes():
    cfg = get_config("seamless-m4t-medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.concrete_batch(jax.random.PRNGKey(1), 2, 32)
    assert batch["frames"].shape == (2, 32, cfg.d_model)
    loss, _ = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


def test_moe_router_aux_loss_nonzero():
    cfg = get_config("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.concrete_batch(jax.random.PRNGKey(1), 2, 64)
    _, aux = model.loss(params, batch)
    assert "aux" in aux or "router" in str(aux) or len(aux) > 0


def test_swa_cache_is_ring_buffer():
    """SWA archs allocate min(seq, window) cache — O(w), not O(S)."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window is not None
    model = build_model(cfg)
    cache = model.init_cache(1, 10 * cfg.sliding_window)
    k_leaves = [l for path, l in
                jax.tree_util.tree_flatten_with_path(cache)[0]
                if "k" == str(getattr(path[-1], "key", ""))]
    assert k_leaves, "no k cache found"
    for l in k_leaves:
        assert l.shape[-3] <= cfg.sliding_window


def test_loss_decreases_markov_data():
    """The synthetic pipeline has learnable structure: 30 steps cut loss."""
    from repro.data.pipeline import DataConfig, model_batch
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, state = opt.update(g, state, params)
        return params, state, l

    losses = []
    for i in range(30):
        params, state, l = step(params, state, model_batch(cfg, dcfg, i))
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5, losses
