"""Numeric-sanitizer smoke (``pytest -m sanitize``, registered in conftest).

Re-runs one real campaign and one real serving scenario under
``jax_debug_nans`` + ``jax_debug_infs``: every primitive's output is
checked on device, so a NaN/Inf produced *anywhere* in the hot path —
gradient, corruption table, wire codec, optimizer update, admission
arithmetic — raises ``FloatingPointError`` at the producing primitive
instead of silently corrupting a phase diagram.

Scope note: the campaign runs the ``mean`` aggregator.  The robust
aggregators are deliberately out of sanitizer scope — their masked
fixed-shape forms use NaN/Inf *sentinels by design* (NaN-padding +
``nanquantile`` for medians, +inf-padding for order statistics; see
``core/aggregation.py``), which is exactly what a NaN-checker flags.
Their numeric correctness is pinned by tests/test_scenarios.py and the
kernel conformance suite instead.
"""
import contextlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import serving, swarm
from repro.core.swarm import NodeSpec, SwarmConfig
from repro.optim.optimizer import SGD

pytestmark = pytest.mark.sanitize


@contextlib.contextmanager
def _sanitizers():
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_debug_infs", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", False)
        jax.config.update("jax_debug_infs", False)


def test_campaign_clean_under_nan_inf_sanitizers():
    d, n = 8, 4
    params = {"w": jnp.zeros((d,), jnp.float32)}
    w_true = jnp.arange(d, dtype=jnp.float32) / d

    def data_fn(i, rnd):
        k = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(7), i),
                               rnd)
        x = jax.random.normal(k, (4, d))
        return {"x": x, "y": x @ w_true}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def eval_fn(p):
        x = jax.random.normal(jax.random.PRNGKey(3), (16, d))
        return jnp.mean((x @ p["w"] - x @ w_true) ** 2)

    rosters = [
        [NodeSpec(node_id=f"n{i}") for i in range(n)],
        [NodeSpec(node_id="n0", byzantine="sign_flip"),
         NodeSpec(node_id="n1"), NodeSpec(node_id="n2", join_round=1),
         NodeSpec(node_id="n3", leave_round=2)],
    ]
    lanes = swarm.stack_lanes(
        [swarm.lane_for_nodes(r, SwarmConfig()) for r in rosters])
    with _sanitizers():
        state, recs, finals = swarm.run_campaign(
            loss_fn, params, SGD(lr=0.05), data_fn, lanes, rounds=3,
            aggregator="mean", eval_fn=eval_fn)
        finals = np.asarray(finals)
    assert finals.shape == (2,)
    assert np.isfinite(finals).all()
    assert np.isfinite(np.asarray(recs.agg_norm)).all()


def test_serving_clean_under_nan_inf_sanitizers():
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config("protocol-125m").reduced(
        num_layers=1, d_model=32, num_heads=2, head_dim=16, d_ff=64,
        vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 5), 0,
                                 cfg.vocab_size)
    engine = serving.ServingEngine(
        model, serving.ServingConfig(slots=2, max_new=3, steps=16), prompts)
    lane = serving.build_lane(
        n_requests=4, prompt_lens=[5, 3, 4, 5], max_new=3, steps=16,
        n_nodes=3, balances=[5.0, 5.0], fee=1.0, load=1.0)
    with _sanitizers():
        result = engine.run(params, lane)
    assert np.asarray(result.done).all()
    assert np.isfinite(np.asarray(result.balances)).all()
