"""Mesh-sharded campaign engine (core/placement.py): sharded-vs-unsharded
conformance on a fake-device host mesh, the MeshPlan/mesh-factory
validation rules, and the batch/cache pspec dedupe regression.

The conformance suite runs in a subprocess with its own XLA_FLAGS
(``--xla_force_host_platform_device_count=8``, the test_launch.py pattern)
because the flag must be set before jax imports.  Inside it:

- a lane-sharded ``derailment.sweep`` is **bit-equal** to the single-device
  sweep — final params, the whole SwarmState, and every ``RoundRecord``
  counter (lanes are embarrassingly parallel, so sharding the run axis
  must not change a single training bit); the one exception is the final
  *eval* scalar, where XLA may fuse the eval matmul differently under a
  mesh — pinned 1-ULP allclose instead;
- a param-sharded (model-axis) plan is **allclose** (resharding reorders
  float reductions);
- the campaign program does **not recompile** under a mesh (second call,
  same shardings -> jit cache hit);
- a lane-sharded serving campaign returns bit-equal tokens;
- an indivisible plan raises the MeshPlan validation error.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core.placement import MeshPlan, lane_axis_size
from repro.launch import mesh as mesh_lib
from repro.models.sharding import batch_pspecs, cache_pspecs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------- sharded conformance (subprocess) ---------------------
CAMPAIGN_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import derailment, serving
from repro.core.placement import MeshPlan
from repro.core.scenarios import Regime, ServingGrid, SweepGrid
from repro.core.swarm import (NodeSpec, SwarmConfig, init_state,
                              lane_for_nodes, make_round_fn, run_campaign,
                              scan_rounds, stack_lanes)
from repro.optim.optimizer import SGD

assert len(jax.devices()) == 8

n_params = 64
key = jax.random.PRNGKey(42)
k1, k2 = jax.random.split(key)
target = jax.random.normal(k1, (n_params,))

def loss_fn(params, batch):
    return jnp.mean(jnp.square((batch["x"] @ (params["w"] - target))))

def data_fn(node_idx, rnd):
    k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
    return {"x": jax.random.normal(k, (16, n_params))}

def eval_fn(params):
    k = jax.random.fold_in(k2, 999)
    return loss_fn(params, {"x": jax.random.normal(k, (64, n_params))})

params0 = {"w": jnp.zeros((n_params,))}
opt = SGD(lr=0.1, momentum=0.0)

def assert_tree_bitequal(a, b, what):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), what

# -- 1) run_campaign: every output leaf bit-equal under lane sharding -----------
nodes = [NodeSpec("h%d" % i) for i in range(4)] + [
    NodeSpec("adv", byzantine="sign_flip", byzantine_scale=20.0)]
lanes = stack_lanes([lane_for_nodes(nodes, SwarmConfig(seed=s))
                     for s in range(8)])
ref = run_campaign(loss_fn, params0, opt, data_fn, lanes, rounds=4,
                   aggregator="centered_clip", eval_fn=eval_fn)
plan = MeshPlan.for_lanes(8)
assert plan.lane_devices == 8, plan.mesh
out = run_campaign(loss_fn, params0, opt, data_fn, lanes, rounds=4,
                   aggregator="centered_clip", eval_fn=eval_fn, plan=plan)
st_r, rec_r, fin_r = ref
st_o, rec_o, fin_o = out
for f in rec_r._fields:
    assert_tree_bitequal(getattr(rec_o, f), getattr(rec_r, f),
                         "RoundRecord." + f)
assert_tree_bitequal(st_o.params, st_r.params, "state.params")
assert_tree_bitequal(st_o, st_r, "SwarmState")
# the final eval matmul is the one op XLA may fuse differently under a
# mesh: the training state is bit-exact, the eval scalar is 1-ULP close
assert np.allclose(np.asarray(fin_o), np.asarray(fin_r), rtol=1e-6), \
    (fin_o, fin_r)
print("RUN_CAMPAIGN_BITEXACT_OK")

# -- 2) derailment.sweep: lane-sharded phase diagram bit-equal -------------------
grid = SweepGrid(name="t", description="",
                 regimes=(Regime("mean", "mean"),
                          Regime("cc", "centered_clip")),
                 n_honest=4, attacker_counts=(1, 2), seeds=(0, 1),
                 scales=(20.0,), rounds=4)
sref = derailment.sweep(loss_fn, params0, opt, data_fn, eval_fn, grid)
splan = MeshPlan.from_grid(grid)
sshd = derailment.sweep(loss_fn, params0, opt, data_fn, eval_fn, grid,
                        plan=splan)
assert sshd.n_devices == splan.n_devices > 1
for a, b in zip(sref.results, sshd.results):
    assert np.isclose(a.final_loss, b.final_loss, rtol=1e-6), (a, b)
    assert np.isclose(a.baseline_loss, b.baseline_loss, rtol=1e-6)
    assert a.attackers_slashed == b.attackers_slashed
print("SWEEP_BITEXACT_OK")

# -- 3) within-lane model-axis sharding: allclose --------------------------------
mplan = MeshPlan.from_grid(grid, model=2)
assert mplan.model_devices == 2, mplan.mesh
mshd = derailment.sweep(loss_fn, params0, opt, data_fn, eval_fn, grid,
                        plan=mplan)
for a, b in zip(sref.results, mshd.results):
    assert np.isclose(a.final_loss, b.final_loss, rtol=1e-5), (a, b)
    assert a.attackers_slashed == b.attackers_slashed
print("MODEL_SHARDED_ALLCLOSE_OK")

# -- 4) no recompile under the mesh ----------------------------------------------
round_fn = make_round_fn(loss_fn, opt, params0, 5,
                         aggregator="centered_clip")
state0 = init_state(params0, opt, 5)
def batch_fn(rnd):
    return jax.vmap(lambda i: data_fn(i, rnd))(jnp.arange(5))
def one_run(lane):
    return scan_rounds(round_fn, lane, state0, 4, batch_fn)
fn = jax.jit(jax.vmap(one_run, spmd_axis_name=plan.lanes_axis))
lanes_s = plan.place_lanes(lanes)
with plan.mesh:
    jax.block_until_ready(fn(lanes_s))
    jax.block_until_ready(fn(lanes_s))
if hasattr(fn, "_cache_size"):
    assert fn._cache_size() == 1, fn._cache_size()
print("NO_RECOMPILE_OK")

# -- 5) serving campaign: lane-sharded tokens bit-equal --------------------------
from repro.configs import get_config
from repro.models.model import build_model
cfg = get_config("protocol-125m").reduced()
model = build_model(cfg)
mparams = model.init(jax.random.PRNGKey(0))
sgrid = ServingGrid(name="t", description="", loads=(0.5, 1.0),
                    churn_rates=(0.0, 0.5), redundancies=(1, 2), seeds=(0,),
                    n_nodes=6, num_shards=8, n_requests=8, n_holders=3,
                    slots=3, prompt_len=6, max_new=4, steps=24)
vref = serving.sweep(model, mparams, sgrid)
vshd = serving.sweep(model, mparams, sgrid, plan=MeshPlan.from_grid(sgrid))
for a, b in zip(vref.cells, vshd.cells):
    assert (a.completed, a.tokens_served, a.availability) == \
           (b.completed, b.tokens_served, b.availability), (a, b)
print("SERVING_BITEXACT_OK")

# -- 6) indivisible lane counts raise the MeshPlan validation error --------------
from repro.launch.mesh import make_campaign_mesh
bad = MeshPlan(mesh=make_campaign_mesh(lanes=8))
lanes12 = stack_lanes([lane_for_nodes(nodes, SwarmConfig(seed=s))
                       for s in range(12)])
try:
    bad.place_lanes(lanes12)
except ValueError as e:
    assert "shard evenly" in str(e), e
else:
    raise AssertionError("indivisible lane count did not raise")
print("CAMPAIGN_SHARDED_OK")
"""


@pytest.mark.slow
def test_campaign_sharded_conformance_subprocess():
    """Lane sharding bit-exact, model sharding allclose, no recompiles,
    serving bit-exact, and the divisibility error — on 8 fake devices."""
    out = subprocess.run(
        [sys.executable, "-c", CAMPAIGN_SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    for sentinel in ("RUN_CAMPAIGN_BITEXACT_OK", "SWEEP_BITEXACT_OK",
                     "MODEL_SHARDED_ALLCLOSE_OK", "NO_RECOMPILE_OK",
                     "SERVING_BITEXACT_OK", "CAMPAIGN_SHARDED_OK"):
        assert sentinel in out.stdout, (sentinel, out.stdout)


# ------------------------------ placement math ---------------------------------
def test_lane_axis_size_picks_largest_divisor():
    assert lane_axis_size(30, 8) == 6
    assert lane_axis_size(16, 8) == 8
    assert lane_axis_size(7, 8) == 7
    assert lane_axis_size(13, 8) == 1     # prime > devices: single device
    assert lane_axis_size(1, 8) == 1
    assert lane_axis_size(8, 1) == 1


def test_meshplan_for_lanes_single_device():
    plan = MeshPlan.for_lanes(10)         # host: however many devices exist
    assert plan.lane_devices >= 1
    assert 10 % plan.lane_devices == 0
    plan.validate_lanes(10)               # must accept its own lane count
    assert plan.n_devices == plan.lane_devices * plan.data_devices \
        * plan.model_devices


def test_meshplan_rejects_oversized_within_lane_factors():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        MeshPlan.for_lanes(8, model=n + 1)


# ------------------------------ mesh factories ----------------------------------
def test_make_host_mesh_default_unchanged():
    mesh = mesh_lib.make_host_mesh()
    n = len(jax.devices())
    assert mesh.devices.shape == (n, 1)
    assert mesh.axis_names == mesh_lib.SINGLE_POD_AXES


def test_make_host_mesh_model_factor_validation():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="divide"):
        mesh_lib.make_host_mesh(model=n + 1)
    with pytest.raises(ValueError):
        mesh_lib.make_host_mesh(model=0)
    mesh = mesh_lib.make_host_mesh(model=n)   # n always divides n
    assert mesh.devices.shape == (1, n)


def test_make_campaign_mesh_shapes_and_validation():
    n = len(jax.devices())
    mesh = mesh_lib.make_campaign_mesh()
    assert mesh.axis_names == mesh_lib.CAMPAIGN_AXES
    assert mesh.devices.shape == (n, 1, 1)
    sub = mesh_lib.make_campaign_mesh(lanes=1)   # subset mesh is legal
    assert sub.devices.shape == (1, 1, 1)
    with pytest.raises(ValueError, match="needs"):
        mesh_lib.make_campaign_mesh(lanes=n + 1)
    with pytest.raises(ValueError):
        mesh_lib.make_campaign_mesh(lanes=1, data=0)


# --------------------- pspec dedupe regression (satellite) ----------------------
def test_batch_pspecs_dedupes_data_axis():
    """Passing data_axis inside extra_batch_axes used to produce a
    PartitionSpec naming the axis twice — invalid under any mesh."""
    batch = {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32),
             "positions": jax.ShapeDtypeStruct((3, 4, 8), jnp.int32)}
    specs = batch_pspecs(batch, {"data": 2, "pod": 2}, data_axis="data",
                         extra_batch_axes=("pod", "data"))
    assert specs["x"][0] == ("pod", "data")
    assert specs["positions"][1] == ("pod", "data")
    for spec in jax.tree.leaves(specs):
        flat = [a for part in spec if part is not None
                for a in (part if isinstance(part, tuple) else (part,))]
        assert len(flat) == len(set(flat)), spec


def test_cache_pspecs_dedupes_data_axis():
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 8, 2, 4), jnp.float32),
             "v": jax.ShapeDtypeStruct((2, 4, 8, 2, 4), jnp.float32)}
    specs = cache_pspecs(cache, None, {"data": 2, "model": 1, "pod": 2},
                         data_axis="data", extra_batch_axes=("pod", "data"))
    for spec in jax.tree.leaves(specs):
        flat = [a for part in spec if part is not None
                for a in (part if isinstance(part, tuple) else (part,))]
        assert len(flat) == len(set(flat)), spec
    assert specs["k"][1] == ("pod", "data")
