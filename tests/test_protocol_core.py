"""Core protocol mechanisms: aggregation, compression, gossip, verification,
ledger, unextractability — unit tests (hypothesis property tests live in
test_properties.py behind an importorskip guard)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, compression, gossip, verification
from repro.core.ledger import Ledger
from repro.core.unextractable import (
    ShardCustody,
    extraction_cost_flops,
    is_protocol_model,
    reconstruct_params,
    retrain_cost_flops,
    shard_params,
)

# =============================== aggregation ===================================


def _updates(n=10, d=32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 0.1 + 1.0


def test_mean_not_byzantine_robust():
    """Paper §3.3 / [6]: one unbounded node moves the mean arbitrarily."""
    x = _updates()
    x = x.at[0].set(1e9)
    agg = aggregation.mean(x)
    assert float(jnp.max(jnp.abs(agg))) > 1e6


@pytest.mark.parametrize("name", ["median", "trimmed_mean", "krum",
                                  "multi_krum", "centered_clip"])
def test_robust_aggregators_bound_attack(name):
    x = _updates(n=12)
    x = x.at[0].set(1e9).at[1].set(-1e9)
    kw = {"f": 2} if "krum" in name else {}
    agg = aggregation.get_aggregator(name, **kw)(x)
    assert float(jnp.max(jnp.abs(agg - 1.0))) < 2.0, name


def test_krum_selects_honest_point():
    x = _updates(n=9)
    x = x.at[0].set(50.0)
    agg = aggregation.krum(x, f=1)
    assert float(jnp.max(jnp.abs(agg - 1.0))) < 1.0


def test_centered_clip_adaptive_tau_tracks_gradient_scale():
    """Regression: fixed τ=1 on norm~100 updates froze v at its warm start;
    adaptive τ (median distance) must recover the honest centre."""
    honest = jax.random.normal(jax.random.PRNGKey(0), (9, 64)) * 5 + 100.0
    attack = jnp.full((3, 64), -2000.0)
    x = jnp.concatenate([honest, attack])
    v = aggregation.centered_clip(x, iters=8)          # adaptive
    honest_mean = jnp.mean(honest, 0)
    assert float(jnp.linalg.norm(v - honest_mean)) < \
        0.5 * float(jnp.linalg.norm(honest_mean))


def test_centered_clip_warm_start():
    x = _updates()
    v0 = jnp.full((32,), 1.0)
    a = aggregation.centered_clip(x, clip_tau=1.0, iters=3, v0=v0)
    assert float(jnp.max(jnp.abs(a - jnp.mean(x, 0)))) < 0.5


def test_aggregators_work_on_pytrees():
    tree = {"a": jnp.ones((4, 3)), "b": {"c": jnp.zeros((4, 2, 2))}}
    out = aggregation.coordinate_median(tree)
    assert out["a"].shape == (3,) and out["b"]["c"].shape == (2, 2)


def test_breakdown_points():
    assert aggregation.breakdown_point("mean", 10) == 0.0
    assert aggregation.breakdown_point("median", 10) == 0.5
    assert 0 < aggregation.breakdown_point("krum", 10) < 0.5


def test_krum_breakdown_point_is_n_minus_3_over_2n():
    """Krum tolerates f byzantine iff N >= 2f+3 [6], i.e. f <= (N-3)/2 —
    so the breakdown *fraction* is (N-3)/2N (the module docstring used to
    claim (N-2)/2N, which is wrong: f = (N-2)/2 violates N >= 2f+3)."""
    for n in (9, 10, 11, 16):
        assert aggregation.breakdown_point("krum", n) == \
            pytest.approx((n - 3) / (2 * n))


def test_masked_krum_at_the_breakdown_boundary():
    """Pin (N-3)/2N against masked_krum behaviour: with f_max = (N-3)//2
    colluding attackers krum still selects an honest point; one attacker
    past the boundary, the attacker cluster is large enough to become its
    own nearest-neighbour set and krum selects from it."""
    n = 11
    f_max = (n - 3) // 2                               # = floor(n * bp) = 4
    rng = np.random.default_rng(0)
    honest = rng.normal(size=(n, 8)).astype(np.float32) * 0.1 + 1.0
    mask = jnp.ones(n, bool)

    x = jnp.asarray(honest).at[:f_max].set(100.0)      # 4 attackers: tolerated
    out = aggregation.masked_krum(x, mask, f=f_max)
    assert float(jnp.max(jnp.abs(out - 1.0))) < 1.0

    x = jnp.asarray(honest).at[:f_max + 1].set(100.0)  # 5 attackers: breakdown
    out = aggregation.masked_krum(x, mask, f=f_max + 1)
    assert float(jnp.min(out)) > 50.0                  # an attacker row wins


# =============================== compression ===================================


def test_qsgd_compress_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    acc = jnp.zeros_like(x)
    n = 200
    for i in range(n):
        c = compression.qsgd_compress(jax.random.PRNGKey(i), x, levels=8)
        acc += compression.qsgd_decompress(c)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(x),
                               rtol=0.2, atol=0.05)


def test_qsgd_compression_ratio():
    x = jnp.ones((10000,), jnp.float32)
    c = compression.qsgd_compress(jax.random.PRNGKey(0), x, levels=16)
    assert compression.compression_ratio(c) > 5.0     # 32b -> ~5b


def test_topk_keeps_largest():
    x = jnp.array([0.0, 5.0, -0.1, -7.0, 0.3])
    c = compression.topk_compress(x, k_frac=0.4)      # k = 2
    y = compression.topk_decompress(c)
    np.testing.assert_allclose(np.asarray(y),
                               [0.0, 5.0, 0.0, -7.0, 0.0])


def test_topk_error_feedback_accumulates():
    """Error feedback: what wasn't sent this round is added next round."""
    x = jnp.array([1.0, 0.5, 0.25, 0.1])
    err = jnp.zeros_like(x)
    sent = jnp.zeros_like(x)
    rounds = 30
    for _ in range(rounds):
        c, err = compression.topk_with_error_feedback(x, err, k_frac=0.25)
        sent += compression.topk_decompress(c)
    # error feedback guarantees every coordinate is eventually transmitted,
    # and the running average converges to x
    assert float(jnp.min(sent)) > 0.0
    np.testing.assert_allclose(np.asarray(sent / rounds), np.asarray(x),
                               rtol=0.35, atol=0.05)


def test_powersgd_low_rank_exact_on_low_rank_input():
    u = jax.random.normal(jax.random.PRNGKey(0), (32, 2))
    v = jax.random.normal(jax.random.PRNGKey(1), (16, 2))
    x = u @ v.T
    c = compression.powersgd_compress(jax.random.PRNGKey(2), x, rank=2, iters=2)
    y = compression.powersgd_decompress(c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-3,
                               atol=1e-3)


def test_powersgd_rejects_zero_iters():
    """Regression: iters=0 used to escape the projection loop with the left
    factor unbound (UnboundLocalError) — now a clear ValueError."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    with pytest.raises(ValueError, match="iters >= 1"):
        compression.powersgd_compress(jax.random.PRNGKey(1), x, iters=0)


def test_roundtrip_carries_powersgd_matrices():
    """Regression: roundtrip rejected 'powersgd' even though it sits in
    DECOMPRESSORS.  2-D payloads go through natively."""
    u = jax.random.normal(jax.random.PRNGKey(0), (32, 2))
    v = jax.random.normal(jax.random.PRNGKey(1), (16, 2))
    x = u @ v.T
    y = compression.roundtrip("powersgd", jax.random.PRNGKey(2), x,
                              rank=2, iters=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-3,
                               atol=1e-3)


def test_roundtrip_powersgd_reshapes_flat_payloads():
    """The swarm's wire carries flat gradients: they are padded onto the
    squarest 2-D grid, compressed, and sliced back — exact when the grid
    view is low-rank, shape-preserving and finite always."""
    base = jnp.outer(jnp.arange(1.0, 12.0), jnp.arange(1.0, 12.0))   # rank 1
    flat = base.reshape(-1)[:119]                  # 119 pads onto 11x11
    y = compression.roundtrip("powersgd", jax.random.PRNGKey(0), flat,
                              rank=2, iters=2)
    assert y.shape == flat.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(flat), rtol=1e-3,
                               atol=1e-3)
    z = compression.roundtrip("powersgd", jax.random.PRNGKey(0),
                              jax.random.normal(jax.random.PRNGKey(1), (37,)))
    assert z.shape == (37,) and bool(jnp.isfinite(z).all())


def test_roundtrip_unknown_codec_names_the_carried_ones():
    with pytest.raises(ValueError, match="powersgd"):
        compression.roundtrip("gzip", jax.random.PRNGKey(0), jnp.ones((4,)))


# ================================= gossip ======================================


def test_gossip_converges_to_mean():
    n, d = 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    w = jnp.asarray(gossip.metropolis_weights(gossip.ring_adjacency(n)))
    mean = jnp.mean(x, 0)
    out = gossip.gossip_average(x, w, rounds=400)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.asarray(mean), (n, d)),
                               rtol=1e-3, atol=1e-3)


def test_gossip_rate_matches_spectral_gap():
    n = 12
    w = gossip.metropolis_weights(gossip.ring_adjacency(n))
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    e0 = float(gossip.consensus_error(x))
    rounds = gossip.rounds_for_tolerance(w, 1e-2)
    out = gossip.gossip_average(x, jnp.asarray(w), rounds)
    assert float(gossip.consensus_error(out)) < 1e-2 * e0 * 10


def test_gossip_traffic_scales_with_degree_not_n():
    d = 1000
    ring = gossip.gossip_traffic_bytes(gossip.ring_adjacency(100), d)
    full = gossip.gossip_traffic_bytes(gossip.fully_connected_adjacency(100), d)
    assert ring < full / 10
    # per-node: ring is O(2·D) regardless of N
    assert ring == 100 * 2 * d * 4


def test_denser_graph_larger_gap():
    ring = gossip.spectral_gap(
        gossip.metropolis_weights(gossip.ring_adjacency(16)))
    reg4 = gossip.spectral_gap(
        gossip.metropolis_weights(gossip.random_regular_adjacency(16, 6)))
    assert reg4 > ring


# ============================== verification ===================================


def _fake_grads(seed=0):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), (16,))}


def test_audit_passes_honest_work():
    cfg = verification.VerificationConfig(tolerance=1e-3, numeric_noise=1e-5)
    claimed = _fake_grads()
    ok, mm = verification.audit(claimed, lambda: _fake_grads(),
                                cfg, jax.random.PRNGKey(1))
    assert ok and float(mm) < 1e-3


def test_audit_catches_fake_work():
    cfg = verification.VerificationConfig(tolerance=1e-3)
    ok, mm = verification.audit(_fake_grads(seed=1), lambda: _fake_grads(0),
                                cfg, jax.random.PRNGKey(1))
    assert not ok and float(mm) > 1e-3


def test_audit_tolerance_absorbs_nondeterminism():
    """Paper §4.2: proofs fail because honest recompute ≠ bit-identical;
    the tolerance must accept simulated numerical spread."""
    cfg = verification.VerificationConfig(tolerance=1e-3, numeric_noise=1e-4)
    ok, _ = verification.audit(_fake_grads(), lambda: _fake_grads(), cfg,
                               jax.random.PRNGKey(2))
    assert ok


def test_audit_noise_keys_fold_in_per_leaf():
    """Regression: one PRNG key across every leaf drew the *same* noise
    pattern on same-shaped leaves (correlated 'nondeterminism') — each leaf
    must get an independent fold_in key."""
    cfg = verification.VerificationConfig(numeric_noise=1e-3)
    x = jax.random.normal(jax.random.PRNGKey(0), (16,))
    tree = {"a": x, "b": x}                        # identical leaves
    noisy = verification._perturbed(tree, jax.random.PRNGKey(1), cfg)
    na, nb = noisy["a"] - x, noisy["b"] - x
    assert float(jnp.max(jnp.abs(na))) > 0.0
    assert float(jnp.max(jnp.abs(na - nb))) > 1e-7  # decorrelated draws


def test_audit_matches_audit_flat_on_flattened_tree():
    """audit on a single-leaf (flattened) tree is the same noise-and-compare
    formula as audit_flat given that leaf's fold_in key — the two engines'
    pass/slash decisions agree at the tolerance boundary."""
    cfg = verification.VerificationConfig(tolerance=1e-3, numeric_noise=1e-4)
    key = jax.random.PRNGKey(7)
    flat = jax.random.normal(jax.random.PRNGKey(0), (64,))
    claimed = flat + 1e-4 * jax.random.normal(jax.random.PRNGKey(1), (64,))
    ok_tree, mm_tree = verification.audit([claimed], lambda: [flat], cfg, key)
    ok_flat, mm_flat = verification.audit_flat(
        claimed, flat, jax.random.fold_in(key, 0), cfg)
    assert ok_tree == bool(ok_flat)
    np.testing.assert_allclose(float(mm_tree), float(mm_flat), rtol=1e-6)


def test_cheating_economics():
    cfg = verification.VerificationConfig(p_check=0.2, stake=10.0)
    assert verification.cheating_irrational(gain_per_step=1.0, cfg=cfg)
    assert not verification.cheating_irrational(gain_per_step=5.0, cfg=cfg)
    assert verification.min_p_check(1.0, 10.0) == pytest.approx(0.1)


def test_min_p_check_makes_cheating_irrational():
    """The documented contract: the 'smallest audit rate making cheating
    irrational' actually does — including at the EV == 0 boundary (counts
    as irrational: faking work has unpriced effort cost) and under float
    rounding (min_p_check nudges the quotient up by ulps until
    p * stake >= gain).  Seeded random sweep; the hypothesis twin lives in
    test_properties.py."""
    # the exact boundary: p * stake == gain -> EV == 0 -> irrational
    cfg = verification.VerificationConfig(p_check=0.1, stake=10.0)
    assert verification.expected_cheat_value(1.0, cfg) == 0.0
    assert verification.cheating_irrational(1.0, cfg)
    # non-positive gain needs no auditing
    assert verification.min_p_check(0.0, 10.0) == 0.0
    assert verification.min_p_check(-3.0, 10.0) == 0.0
    rng = np.random.default_rng(0)
    for _ in range(2000):
        gain = float(rng.uniform(-2.0, 50.0))
        stake = float(rng.uniform(1e-9, 100.0))
        p = verification.min_p_check(gain, stake)
        assert 0.0 <= p <= 1.0
        if p < 1.0:     # any sufficient rate <= 1 exists -> p must suffice
            assert verification.cheating_irrational(
                gain, verification.VerificationConfig(p_check=p, stake=stake)
            ), (gain, stake, p)


# ================================= ledger ======================================


def test_ledger_proportional_ownership():
    led = Ledger()
    led.record_contribution("a", 3.0)
    led.record_contribution("b", 1.0)
    assert led.ownership_fraction("a") == pytest.approx(0.75)


def test_ledger_transfer_and_credentials():
    led = Ledger()
    led.record_contribution("a", 2.0)
    led.transfer("a", "user", 1.0)
    assert led.can_infer("user")
    with pytest.raises(ValueError):
        led.transfer("a", "user", 100.0)


def test_ledger_credential_spend_is_strict():
    """The can_infer boundary is strict (> min_shares): a holder who
    transfers their ENTIRE balance away is subsequently refused — spending
    credentials and keeping them are mutually exclusive."""
    led = Ledger()
    led.record_contribution("a", 2.0)
    assert led.can_infer("a")
    led.transfer("a", "user", 2.0)             # entire balance away
    assert not led.can_infer("a")              # 0.0 > 0.0 is False
    assert led.can_infer("user")
    # the boundary itself: exactly min_shares is refused, above is served
    assert not led.can_infer("user", min_shares=2.0)
    assert led.can_infer("user", min_shares=1.9)


def test_ledger_conservation_under_transfer_then_slash():
    """Transfers move shares without minting; slashing after a transfer
    burns only what the slashed node still holds — conservation
    (total + burned == minted) holds through the whole sequence."""
    led = Ledger()
    led.record_contribution("a", 3.0)
    led.record_contribution("b", 2.0)
    led.stake("b", 5.0)
    led.transfer("b", "a", 1.5)                # b keeps 0.5
    assert led.check_conservation()
    lost = led.slash("b")
    assert lost == pytest.approx(5.5)          # 5.0 stake + 0.5 shares
    assert led.burned == pytest.approx(0.5)    # transferred shares survive
    assert led.balances["a"] == pytest.approx(4.5)
    assert led.check_conservation()


def test_ledger_balance_vector_view():
    led = Ledger()
    led.record_contribution("a", 2.0)
    led.record_contribution("b", 1.0)
    assert led.balance_vector(["b", "ghost", "a"]) == [1.0, 0.0, 2.0]


def test_ledger_slash_burns():
    led = Ledger()
    led.stake("evil", 5.0)
    led.record_contribution("evil", 2.0)
    lost = led.slash("evil")
    assert lost == pytest.approx(7.0)
    assert not led.can_infer("evil")
    assert led.check_conservation()


def test_ledger_slash_unknown_node_is_noop():
    """Slashing a node the ledger has never seen records NOTHING: no
    phantom ("slash", node, 0.0) event may enter the audit trail for a
    participant that never staked or contributed."""
    led = Ledger()
    led.record_contribution("a", 3.0)
    led.stake("a", 5.0)
    before = list(led.history)
    assert led.slash("ghost") == 0.0
    assert led.history == before
    assert led.burned == 0.0 and led.burned_stake == 0.0
    assert "ghost" not in led.balances and "ghost" not in led.stakes
    assert led.check_conservation()
    # a node with ONLY a stake (no shares yet) is still slashable
    led.stake("b", 2.0)
    assert led.slash("b") == pytest.approx(2.0)
    assert led.history[-1] == ("slash", "b", 2.0)
    # and a second slash of the now-gone node is again a no-op
    n_events = len(led.history)
    assert led.slash("b") == 0.0
    assert len(led.history) == n_events


# ============================ unextractability =================================


def test_custody_respects_max_fraction():
    nodes = [f"n{i}" for i in range(8)]
    c = ShardCustody.assign(nodes, num_shards=16, redundancy=2,
                            max_fraction=0.5)
    for n in nodes:
        assert len(c.node_shards[n]) <= 8
        assert c.coverage([n]) <= 0.5


def test_no_single_node_extracts():
    nodes = [f"n{i}" for i in range(8)]
    c = ShardCustody.assign(nodes, 16, redundancy=2, max_fraction=0.4)
    for n in nodes:
        assert not c.can_extract([n])
    assert c.can_extract(nodes)
    assert c.min_extraction_coalition() >= 3       # ceil(1 / 0.4)


def test_custody_missing_shard_ids():
    """ShardCustody.missing_shards returns the uncovered shard *ids*
    (diagnosable outages), consistent with the traced count reduction."""
    from repro.core.unextractable import missing_shards as missing_count
    nodes = [f"n{i}" for i in range(8)]
    c = ShardCustody.assign(nodes, 16, redundancy=2, max_fraction=0.4)
    assert c.missing_shards(nodes) == []
    ids = c.missing_shards(nodes[:2])
    held = set()
    for n in nodes[:2]:
        held |= c.node_shards[n]
    assert ids == sorted(set(range(16)) - held)
    assert len(ids) == int(missing_count(c.holds, c.coalition_mask(nodes[:2])))


def test_custody_tolerates_departures():
    nodes = [f"n{i}" for i in range(8)]
    c = ShardCustody.assign(nodes, 16, redundancy=3)
    assert c.tolerates_departures(["n0", "n1"])


def test_reconstruct_zero_coverage_returns_zero_template():
    """Regression: a coalition holding NO shards crashed on reshaping a
    size-0 vector — it must get the fully zero-filled (unusable) template."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8)),
              "b": jnp.ones((8,))}
    _, true_size = shard_params(params, 8)
    out = reconstruct_params({}, params, 8, true_size)
    assert jax.tree.structure(out) == jax.tree.structure(params)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        assert got.shape == want.shape and got.dtype == want.dtype
        assert float(jnp.abs(got).max()) == 0.0


def test_reconstruct_partial_is_garbage():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8)),
              "b": jnp.ones((8,))}
    shards, true_size = shard_params(params, 8)
    full = reconstruct_params(dict(enumerate(shards)), params, 8, true_size)
    np.testing.assert_allclose(np.asarray(full["w"]), np.asarray(params["w"]),
                               rtol=1e-6)
    partial = reconstruct_params({0: shards[0]}, params, 8, true_size)
    assert float(jnp.linalg.norm(partial["w"] - params["w"])) > 1.0


def test_protocol_model_inequality():
    nodes = [f"n{i}" for i in range(10)]
    c = ShardCustody.assign(nodes, 20, redundancy=2, max_fraction=0.3)
    n_params, tokens = 10**9, 10**10
    cost_per_shard = retrain_cost_flops(n_params, tokens)  # huge per shard
    assert is_protocol_model(c, ["n0"], n_params, tokens, cost_per_shard)
    assert not is_protocol_model(c, nodes, n_params, tokens, cost_per_shard)
