"""Config registry: the 10 assigned architectures carry their exact specs."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    REGISTRY,
    applicable_shapes,
    get_config,
)

# (arch, layers, d_model, heads, kv_heads, d_ff, vocab)
ASSIGNED_SPECS = [
    ("stablelm-3b", 32, 2560, 32, 32, 6912, 50304),
    ("mixtral-8x7b", 32, 4096, 32, 8, 14336, 32000),
    ("h2o-danube-1.8b", 24, 2560, 32, 8, 6912, 32000),
    ("zamba2-1.2b", 38, 2048, 32, 32, 8192, 32000),
    ("rwkv6-1.6b", 24, 2048, None, None, 7168, 65536),
    ("qwen2-vl-2b", 28, 1536, 12, 2, 8960, 151936),
    ("granite-20b", 52, 6144, 48, 1, 24576, 49152),
    ("tinyllama-1.1b", 22, 2048, 32, 4, 5632, 32000),
    ("qwen3-moe-30b-a3b", 48, 2048, 32, 4, 768, 151936),
    ("seamless-m4t-medium", 12, 1024, 16, 16, 4096, 256206),
]


@pytest.mark.parametrize("arch,L,d,h,kv,ff,v", ASSIGNED_SPECS)
def test_assigned_dims(arch, L, d, h, kv, ff, v):
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    if h is not None:
        assert cfg.num_heads == h
        assert cfg.num_kv_heads == kv


def test_all_assigned_present():
    assert sorted(ASSIGNED_ARCHS) == sorted(a for a, *_ in ASSIGNED_SPECS)


def test_moe_configs():
    mix = get_config("mixtral-8x7b")
    assert (mix.num_experts, mix.experts_per_token) == (8, 2)
    assert mix.sliding_window is not None          # SWA per [2401.04088]
    q3 = get_config("qwen3-moe-30b-a3b")
    assert (q3.num_experts, q3.experts_per_token) == (128, 8)


def test_ssm_hybrid_configs():
    z = get_config("zamba2-1.2b")
    assert z.family == "hybrid" and z.ssm_state_size == 64
    r = get_config("rwkv6-1.6b")
    assert r.family == "ssm" and r.attention_free


def test_param_counts_in_band():
    """Analytic N within ±40% of the marketing size (arch names are loose)."""
    expect = {
        "stablelm-3b": 3e9, "mixtral-8x7b": 46e9, "h2o-danube-1.8b": 1.8e9,
        "zamba2-1.2b": 1.2e9, "rwkv6-1.6b": 1.6e9, "qwen2-vl-2b": 2e9,
        "granite-20b": 20e9, "tinyllama-1.1b": 1.1e9,
        "qwen3-moe-30b-a3b": 30e9, "seamless-m4t-medium": 1.2e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.6 * n, f"{arch}: {got:.2e} vs {n:.2e}"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert active < 0.25 * cfg.param_count()       # A3B: ~3B of 30B active


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


def test_long_decode_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §3)."""
    runs_long = {a for a in ASSIGNED_ARCHS
                 if "long_500k" in applicable_shapes(get_config(a))}
    assert runs_long == {"mixtral-8x7b", "h2o-danube-1.8b", "zamba2-1.2b",
                         "rwkv6-1.6b"}


def test_reduced_configs_are_small():
    for arch in ASSIGNED_ARCHS:
        red = get_config(arch).reduced()
        assert red.num_layers == 2 and red.d_model <= 512
        if red.num_experts:
            assert red.num_experts <= 4
        assert red.family == get_config(arch).family
