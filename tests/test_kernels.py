"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# -- swa_attention --------------------------------------------------------------
from repro.kernels.swa_attention.ops import swa_attention
from repro.kernels.swa_attention.ref import swa_attention_ref
from repro.models.attention import reference_attention


@pytest.mark.parametrize("b,s,hq,hkv,hd,window,bq", [
    (1, 256, 4, 2, 32, 64, 64),
    (2, 128, 2, 1, 64, 32, 64),      # window < block_q (regression: coverage)
    (1, 256, 4, 4, 32, 96, 64),      # window not a multiple of block_q
    (1, 512, 8, 2, 64, 128, 128),
    (2, 128, 8, 8, 16, 128, 64),     # window == seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_kernel(b, s, hq, hkv, hd, window, bq, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    out = swa_attention(q, k, v, window=window, block_q=bq, interpret=True)
    ref = reference_attention(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_swa_kernel_layout_ref_agrees():
    """ref.py's (B,H,S,hd) layout oracle == model-level math."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 16))
    k = jax.random.normal(ks[1], (1, 2, 64, 16))
    v = jax.random.normal(ks[2], (1, 2, 64, 16))
    a = swa_attention_ref(q, k, v, window=32)
    b = jnp.swapaxes(reference_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True, window=32), 1, 2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# -- qsgd ------------------------------------------------------------------------
from repro.kernels.qsgd.ops import qsgd_encode, qsgd_roundtrip
from repro.kernels.qsgd.ref import qsgd_roundtrip_ref


@pytest.mark.parametrize("shape", [(1000,), (128, 128), (7,), (3, 5, 17)])
@pytest.mark.parametrize("levels", [16, 64, 127])
def test_qsgd_kernel_bit_exact(shape, levels):
    """The int8 CODES are bit-exact vs the oracle (the §4.2 verification
    requirement); the decoded floats agree to 1 ulp (fusion order differs)."""
    from repro.kernels.qsgd.ops import _to_lanes, qsgd_encode
    from repro.kernels.qsgd.ref import qsgd_encode_ref
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 3
    q_k, norm = qsgd_encode(key, x, levels=levels, interpret=True)
    x2d, _ = _to_lanes(x)
    rnd = jax.random.uniform(key, x2d.shape, jnp.float32)
    q_r = qsgd_encode_ref(x2d, rnd, jnp.linalg.norm(x2d), levels=levels)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    a = qsgd_roundtrip(key, x, levels=levels, interpret=True)
    b = qsgd_roundtrip_ref(key, x, levels=levels)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)


def test_qsgd_unbiased():
    """E[decode(encode(x))] == x (statistical, many keys)."""
    x = jnp.array([0.3, -1.7, 0.001, 4.0, -0.25])
    acc = jnp.zeros_like(x)
    n = 300
    for i in range(n):
        acc += qsgd_roundtrip(jax.random.PRNGKey(i), x, levels=4,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(x),
                               rtol=0.15, atol=0.05)


def test_qsgd_codes_fit_int8():
    q, _ = qsgd_encode(jax.random.PRNGKey(0),
                       jax.random.normal(jax.random.PRNGKey(1), (512,)),
                       levels=127, interpret=True)
    assert q.dtype == jnp.int8


# -- kernel vs core.compression parity -------------------------------------------
from repro.core import compression
from repro.kernels.qsgd.ops import single_bucket_regime


def test_single_bucket_regime_predicate():
    """The regime boundary, pinned: the kernel (one global norm, LANE-padded
    uniform draws) and the wire codec (per-bucket norms) coincide exactly
    when one bucket spans the whole LANE-padded tensor."""
    assert single_bucket_regime(100, bucket_size=128)
    assert single_bucket_regime(128, bucket_size=128)
    assert single_bucket_regime(129, bucket_size=256)     # pads to (2, 128)
    assert single_bucket_regime(1000, bucket_size=1024)
    assert not single_bucket_regime(129, bucket_size=128)  # two buckets
    assert not single_bucket_regime(512, bucket_size=128)
    assert not single_bucket_regime(100, bucket_size=256)  # pad 128 != 256
    assert not single_bucket_regime(1025, bucket_size=1024)


@pytest.mark.parametrize("size,bucket_size", [
    (100, 128), (128, 128), (129, 256), (1000, 1024),
])
@pytest.mark.parametrize("levels", [16, 64, 127])
def test_qsgd_kernel_matches_compression_roundtrip(size, bucket_size, levels):
    """Single-bucket regime (``single_bucket_regime`` True): the Pallas qsgd
    op and the swarm wire codec share scale/clip semantics — |x|/norm *
    levels, floor + stochastic carry from the same uniform draws (threefry
    bits depend only on the total padded count, so the kernel's (R, 128)
    draw IS the codec's (1, R*128) draw), signed magnitudes, decode
    q/levels*norm.  Tolerance: the two compute the norm with different
    reduction shapes, so decoded floats agree to ~1 ulp of norm/levels
    (atol 1e-6 * norm), not bit-for-bit."""
    assert single_bucket_regime(size, bucket_size=bucket_size)
    key = jax.random.PRNGKey(size + levels)
    x = jax.random.normal(jax.random.PRNGKey(0), (size,)) * 2
    kern = qsgd_roundtrip(key, x, levels=levels, interpret=True)
    wire = compression.roundtrip("qsgd", key, x, levels=levels,
                                 bucket_size=bucket_size)
    norm = float(jnp.linalg.norm(x))
    np.testing.assert_allclose(np.asarray(kern), np.asarray(wire),
                               atol=1e-6 * norm, rtol=0)


@pytest.mark.parametrize("size,bucket_size", [
    (512, 128), (129, 128), (100, 256), (2000, 1024),
])
def test_qsgd_kernel_vs_compression_bucketed_divergence_bounded(size,
                                                                bucket_size):
    """Bucketed regime (``single_bucket_regime`` False): the two
    INTENTIONALLY diverge — the kernel normalizes by the global norm, the
    wire codec per bucket (tighter scale per bucket) — but both stay
    unbiased quantizations of the same tensor, so each is within the QSGD
    error bound sqrt(d)/levels * ||x|| of the input (and hence within 2
    bounds of each other)."""
    assert not single_bucket_regime(size, bucket_size=bucket_size)
    levels = 64
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(1), (size,))
    kern = qsgd_roundtrip(key, x, levels=levels, interpret=True)
    wire = compression.roundtrip("qsgd", key, x, levels=levels,
                                 bucket_size=bucket_size)
    bound = np.sqrt(size) / levels * float(jnp.linalg.norm(x))
    assert float(jnp.linalg.norm(kern - x)) <= bound
    assert float(jnp.linalg.norm(wire - x)) <= bound
    assert float(jnp.linalg.norm(kern - wire)) <= 2 * bound


# -- centered_clip ---------------------------------------------------------------
from repro.core.aggregation import centered_clip as cc_ref
from repro.kernels.centered_clip.ops import centered_clip as cc_kernel


@pytest.mark.parametrize("n,d", [(8, 4096), (16, 1000), (5, 257), (32, 128)])
@pytest.mark.parametrize("tau,iters", [(1.0, 3), (0.5, 1), (10.0, 5)])
def test_centered_clip_kernel(n, d, tau, iters):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d)) * 2 + 1
    a = cc_kernel(x, clip_tau=tau, iters=iters, interpret=True)
    b = cc_ref(x, clip_tau=tau, iters=iters)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


def test_centered_clip_kernel_matches_masked_aggregation_reference():
    """The engine-facing form: ``aggregation.masked_centered_clip`` with a
    full keep-mask is the same fixed-τ iteration the Pallas kernel runs
    (median warm start, clip(x_i − v, τ), mean step).  Pinned so the
    masked aggregator the swarm round actually calls and the kernel twin
    cannot drift apart.  Tolerance 3e-5: fp32 reduction order differs
    between the blocked kernel and the jnp einsum path."""
    from repro.core.aggregation import masked_centered_clip
    x = jax.random.normal(jax.random.PRNGKey(5), (12, 300)) * 2 + 1
    mask = jnp.ones(12, bool)
    for tau, iters in [(0.5, 1), (1.5, 4), (10.0, 3)]:
        a = cc_kernel(x, clip_tau=tau, iters=iters, interpret=True)
        b = masked_centered_clip(x, mask, clip_tau=tau, iters=iters)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-5,
                                   err_msg=f"tau={tau} iters={iters}")


def test_centered_clip_kernel_robust_to_outlier():
    """With a robust warm start (as [27] warm-starts from the previous
    aggregate), an unbounded attacker moves v by at most τ per iteration."""
    honest = jax.random.normal(jax.random.PRNGKey(0), (9, 512)) * 0.1 + 1.0
    attack = jnp.full((1, 512), 1e6)
    x = jnp.concatenate([honest, attack])
    v0 = jnp.median(x, axis=0)
    v = cc_kernel(x, clip_tau=1.0, iters=5, v0=v0, interpret=True)
    assert float(jnp.max(jnp.abs(v - 1.0))) < 1.0      # attacker bounded


# -- mamba2_scan -----------------------------------------------------------------
from repro.kernels.mamba2_scan.ops import ssd_chunked_pallas
from repro.models.mamba2 import ssd_chunked, ssd_reference


@pytest.mark.parametrize("bsz,s,h,p,n,chunk", [
    (2, 64, 3, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (1, 60, 1, 8, 4, 16),            # seq not a multiple of chunk
])
def test_mamba2_scan_kernel(bsz, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, n)) * 0.5
    d = jnp.ones((h,)) * 0.5
    y_ref, h_ref = ssd_reference(x, dt, a, b, c, d)
    y_k, h_k = ssd_chunked_pallas(x, dt, a, b, c, d, chunk=chunk,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=3e-4, atol=3e-4)


def test_mamba2_model_chunked_matches_reference():
    """The model-level chunked scan is itself validated vs token-by-token."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    bsz, s, h, p, n = 2, 48, 2, 8, 4
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, n)) * 0.5
    d = jnp.zeros((h,))
    y1, h1 = ssd_chunked(x, dt, a, b, c, d, chunk=16)
    y2, h2 = ssd_reference(x, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


# -- rwkv6_wkv -------------------------------------------------------------------
from repro.kernels.rwkv6_wkv.ops import wkv_chunked_pallas
from repro.models.rwkv6 import wkv_chunked, wkv_reference


@pytest.mark.parametrize("bsz,s,h,dk,chunk", [
    (2, 64, 2, 16, 16),
    (1, 96, 3, 32, 32),
    (1, 40, 1, 8, 16),               # seq not a multiple of chunk
])
def test_rwkv6_wkv_kernel(bsz, s, h, dk, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (bsz, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (bsz, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (bsz, s, h, dk))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bsz, s, h, dk)) - 1) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    y_ref, s_ref = wkv_reference(r, k, v, w, u)
    y_k, s_k = wkv_chunked_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_rwkv6_model_chunked_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    bsz, s, h, dk = 1, 48, 2, 8
    r = jax.random.normal(ks[0], (bsz, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (bsz, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (bsz, s, h, dk))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bsz, s, h, dk))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    y1, s1 = wkv_chunked(r, k, v, w, u, chunk=16)
    y2, s2 = wkv_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


# -- model-level kernel integration (inference paths) ----------------------------
import dataclasses

from repro.configs import get_config
from repro.models.model import build_model


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "rwkv6-1.6b",
                                  "zamba2-1.2b"])
def test_model_prefill_with_pallas_kernels_matches_jnp(arch):
    """cfg.use_pallas_kernels swaps the SWA / WKV / SSD compute for the
    Pallas kernels (interpret mode on CPU); prefill logits must match the
    pure-jnp path."""
    cfg = get_config(arch).reduced()
    model_jnp = build_model(cfg)
    model_krn = build_model(dataclasses.replace(cfg, use_pallas_kernels=True))
    params = model_jnp.init(jax.random.PRNGKey(0))
    batch = model_jnp.concrete_batch(jax.random.PRNGKey(1), 2, 64)
    a = model_jnp.prefill(params, batch)
    b = model_krn.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)
