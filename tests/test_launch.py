"""Launch layer: train step correctness, microbatch equivalence, serving,
protocol server, checkpointing, data pipeline, HLO cost model, and the
multi-pod dry-run (subprocess with its own XLA_FLAGS)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, lm_batch, model_batch, sample_tokens
from repro.launch import mesh as mesh_lib
from repro.launch.train import TrainOptions, TrainState, make_train_step
from repro.models.model import build_model
from repro.optim.optimizer import AdamW

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ------------------------------ train step -------------------------------------
def test_train_step_reduces_loss(tiny_model):
    cfg, model, params = tiny_model
    opt = AdamW(lr=3e-3)
    mesh = mesh_lib.make_host_mesh()
    step = jax.jit(make_train_step(model, opt, mesh))
    state = TrainState(params, opt.init(params))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    losses = []
    for i in range(15):
        state, m = step(state, model_batch(cfg, dcfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_grad_equivalence(tiny_model):
    """Accumulated microbatch gradients == full-batch gradients."""
    cfg, model, params = tiny_model
    opt = AdamW(lr=0.0, weight_decay=0.0, clip_norm=None)
    mesh = mesh_lib.make_host_mesh()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    batch = model_batch(cfg, dcfg, 0)

    from repro.launch.train import _grad_fn
    l1, g1 = jax.jit(_grad_fn(model, 1))(params, batch)
    l4, g4 = jax.jit(_grad_fn(model, 4))(params, batch)
    assert float(l1) == pytest.approx(float(l4), rel=1e-4)
    flat1 = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                             for x in jax.tree.leaves(g1)])
    flat4 = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                             for x in jax.tree.leaves(g4)])
    np.testing.assert_allclose(np.asarray(flat1), np.asarray(flat4),
                               rtol=1e-3, atol=1e-4)


def test_train_cli_runs():
    from repro.launch.train import main
    main(["--arch", "protocol-125m", "--steps", "3", "--batch", "2",
          "--seq", "32", "--log-every", "10"])


def test_pod_sync_registry_and_identity():
    """Every pod-sync mode runs under shard_map; at pod-size 1 each is an
    identity (all_gather of one, mean of one, one-neighbour gossip)."""
    from repro.core.hierarchical import POD_SYNC
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.arange(8.0).reshape(2, 4), "b": jnp.ones((3,))}
    for name, fn in POD_SYNC.items():
        if name == "gossip":
            continue                      # ring needs >= 2 members
        from repro import compat
        out = jax.jit(compat.shard_map(
            lambda g: fn(g, "pod"), mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),),
            out_specs=jax.sharding.PartitionSpec(),
            check=False))(grads)
        for k in grads:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(grads[k]),
                                       rtol=2e-2, atol=2e-2, err_msg=name)


# ------------------------------ serving ----------------------------------------
def test_greedy_decode_serves(tiny_model):
    cfg, model, params = tiny_model
    from repro.launch.serve import greedy_decode
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    gen, stats = greedy_decode(model, params, prompts, max_new=6)
    assert gen.shape == (2, 6)
    assert stats.tokens_out == 6


def test_protocol_server_gates_and_serves(tiny_model):
    cfg, model, params = tiny_model
    from repro.core.ledger import Ledger
    from repro.core.protocol import (CredentialError, ExtractionError,
                                     ProtocolModelServer)
    nodes = [f"n{i}" for i in range(6)]
    led = Ledger()
    led.record_contribution("n0", 1.0)
    srv = ProtocolModelServer.create(model, params, nodes, led,
                                     num_shards=12, redundancy=2,
                                     max_fraction=0.4)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    # no credentials -> rejected
    with pytest.raises(CredentialError):
        srv.serve("outsider", batch)
    # full swarm -> logits
    logits = srv.serve("n0", batch)
    assert logits.shape == (1, cfg.vocab_size)
    ref = model.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # partial swarm -> cannot serve
    with pytest.raises(ExtractionError):
        srv.serve("n0", batch, online_nodes=nodes[:2])
    # coalition extraction yields garbage params
    broken = srv.attempt_extraction(nodes[:2])
    broken_logits = model.prefill(broken, batch)
    assert float(jnp.max(jnp.abs(broken_logits - ref))) > 1e-2


def test_protocol_server_caches_per_online_set(tiny_model):
    """serve() reconstructs params once per online-node set (cached on the
    frozenset, order-free) instead of per request, and a failed gather
    names the missing shard ids."""
    cfg, model, params = tiny_model
    from repro.core.ledger import Ledger
    from repro.core.protocol import ExtractionError, ProtocolModelServer
    nodes = [f"n{i}" for i in range(6)]
    led = Ledger()
    led.record_contribution("n0", 1.0)
    srv = ProtocolModelServer.create(model, params, nodes, led,
                                     num_shards=12, redundancy=2,
                                     max_fraction=0.4)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    srv.serve("n0", batch)
    assert len(srv._params_cache) == 1
    cached = srv._params_cache[frozenset(nodes)]
    srv.serve("n0", batch, online_nodes=list(reversed(nodes)))  # same set
    assert len(srv._params_cache) == 1
    assert srv._params_cache[frozenset(nodes)] is cached        # reused
    # a different (still-covering) set is a separate entry
    survivors = [n for n in nodes if n != "n5"]
    if srv.custody.tolerates_departures(["n5"]):
        srv.serve("n0", batch, online_nodes=survivors)
        assert len(srv._params_cache) == 2
    # failure is diagnosable: the error names the uncovered shard ids
    with pytest.raises(ExtractionError) as err:
        srv.serve("n0", batch, online_nodes=nodes[:1])
    missing = srv.custody.missing_shards(nodes[:1])
    assert str(missing) in str(err.value)
    # the scanned decode path serves tokens without exposing weights
    prompts = jnp.zeros((2, 4), jnp.int32)
    gen, _ = srv.decode("n0", prompts, 3)
    from repro.core.serving import greedy_decode
    ref, _ = greedy_decode(model, params, prompts, 3)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(ref))


# ------------------------------ checkpoint -------------------------------------
def test_checkpoint_roundtrip(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    from repro.checkpoint import checkpoint as ckpt
    path = str(tmp_path / "ck")
    ckpt.save(path, params, step=7)
    restored = ckpt.restore(path, jax.eval_shape(lambda: params))
    assert ckpt.load_step(path) == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored)


def test_checkpoint_restore_validates_dtypes(tmp_path):
    """restore validates manifest dtypes against the template like shapes:
    an fp32 checkpoint restored into a bf16 template is an ERROR naming
    the offending key, not a silent precision change.  (bf16 checkpoints
    themselves can't serialize — np.savez has no bf16 cast — so the
    mismatch is probed from the fp32-on-disk side.)"""
    from repro.checkpoint import checkpoint as ckpt
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "opt": {"m": jnp.zeros((4,), jnp.float32)}}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=1)
    restored = ckpt.restore(path, tree)     # matching template: exact
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)
    with pytest.raises(ValueError, match="dtype mismatch for w"):
        ckpt.restore(path, {"w": tree["w"].astype(jnp.bfloat16),
                            "opt": tree["opt"]})
    with pytest.raises(ValueError, match="dtype mismatch for opt/m"):
        ckpt.restore(path, {"w": tree["w"],
                            "opt": {"m": tree["opt"]["m"].astype(jnp.bfloat16)}})
    with pytest.raises(ValueError, match="shape mismatch for w"):
        ckpt.restore(path, {"w": jnp.zeros((3, 2), jnp.float32),
                            "opt": tree["opt"]})


def test_custody_checkpoint_enforces_coverage(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    from repro.checkpoint import checkpoint as ckpt
    from repro.core.unextractable import ShardCustody
    nodes = [f"n{i}" for i in range(5)]
    custody = ShardCustody.assign(nodes, 10, redundancy=2, max_fraction=0.5)
    path = str(tmp_path / "custody_ck")
    ckpt.save_custody(path, params, custody)
    with pytest.raises(PermissionError):
        ckpt.restore_custody(path, params, holders=["n0"])
    restored = ckpt.restore_custody(path, params, holders=nodes)
    flat_a = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                              for x in jax.tree.leaves(params)])
    flat_b = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                              for x in jax.tree.leaves(restored)])
    np.testing.assert_allclose(np.asarray(flat_a), np.asarray(flat_b),
                               rtol=1e-6)


# ------------------------------ data pipeline ----------------------------------
def test_data_deterministic():
    dcfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8)
    a = sample_tokens(dcfg, step=3)
    b = sample_tokens(dcfg, step=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = sample_tokens(dcfg, step=4)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_labels_are_next_tokens():
    dcfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
    b = lm_batch(dcfg, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ------------------------------ hlo cost model ----------------------------------
def test_hlo_cost_counts_matmul_flops():
    m, k, n = 64, 128, 32

    def f(a, b):
        return a @ b

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    hlo = lowered.compile().as_text()
    from repro.launch.hlo_cost import analyze_hlo
    cost = analyze_hlo(hlo, total_devices=1)
    assert cost.flops == pytest.approx(2 * m * k * n, rel=0.01)


def test_hlo_cost_multiplies_loop_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32))
    compiled = lowered.compile()
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze_hlo
    cost = analyze_hlo(hlo, total_devices=1)
    expected = 10 * 2 * 32 * 64 * 64
    assert cost.flops == pytest.approx(expected, rel=0.05)
    # the raw XLA analysis would report ~1/10th of this
    from repro import compat
    xla = compat.cost_analysis_dict(compiled)
    if xla.get("flops"):
        assert cost.flops > 5 * float(xla["flops"])


def test_roofline_terms():
    from repro.launch.roofline import Roofline
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                 wire_bytes_per_device=0.0, model_flops_global=197e12,
                 num_chips=1)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_wire_byte_model():
    from repro.launch.hlo_cost import _wire_bytes
    # all-reduce moves 2(n-1)/n of the buffer per device
    assert _wire_bytes("all-reduce", 1000, 4) == pytest.approx(1500.0)
    assert _wire_bytes("all-gather", 1000, 4) == pytest.approx(750.0)
    assert _wire_bytes("collective-permute", 1000, 4) == 1000.0
    assert _wire_bytes("all-reduce", 1000, 1) == 0.0


# ------------------------------ dry-run (subprocess) ----------------------------
@pytest.mark.slow
def test_dryrun_subprocess_single_pod(tmp_path):
    """The real 256-chip dry-run for one cheap combination."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(
        tmp_path / "tinyllama-1.1b__decode_32k__single__dense.json"))
    assert rec["status"] == "ok"
    assert rec["num_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="old-jax (0.4.x) SPMD partitioner aborts on grad-of-scan inside a "
           "partial-manual shard_map (IsManualSubgroup check); the layer "
           "stack is a differentiated scan, so non-dense pod sync needs the "
           "new-API stack.  The sync collectives themselves are covered by "
           "test_pod_sync_partial_manual_subprocess.")
def test_dryrun_subprocess_multi_pod_qsgd(tmp_path):
    """512-chip multi-pod with int8-on-the-wire pod sync lowers + compiles."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "train_4k",
         "--multi-pod", "--pod-sync", "qsgd", "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(
        tmp_path / "tinyllama-1.1b__train_4k__multi__qsgd.json"))
    assert rec["status"] == "ok" and rec["num_chips"] == 512


# ------------------------- pod sync under partial-manual ------------------------
POD_SYNC_PARTIAL_MANUAL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.hierarchical import POD_SYNC
mesh = jax.make_mesh((4, 2), ("pod", "data"))
grads = {"w": jnp.arange(32.0).reshape(4, 8), "b": jnp.ones((4, 2))}
pod_ids = jnp.arange(4, dtype=jnp.int32)
for name in ("dense", "qsgd", "median", "centered_clip", "gossip"):
    fn = POD_SYNC[name]
    out = jax.jit(compat.shard_map(
        lambda g, i: fn(g, "pod", pod_index=i[0]), mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P("pod"), grads), P("pod")),
        out_specs=jax.tree.map(lambda _: P("pod"), grads),
        axis_names={"pod"}, check=False))(grads, pod_ids)
    for k in grads:
        mean = np.asarray(jnp.mean(grads[k], 0))
        got = np.asarray(out[k])
        if name == "gossip":
            # one ring round only contracts toward consensus
            before = np.abs(np.asarray(grads[k]) - mean).max()
            after = np.abs(got - mean).max()
            assert after < 0.8 * before + 1e-6, (name, before, after)
        else:
            # exact/robust/lossy cross-pod average: near the mean everywhere
            np.testing.assert_allclose(
                got, np.broadcast_to(mean, got.shape), rtol=0.25, atol=0.35,
                err_msg=name)
print("POD_SYNC_PM_OK")
"""


@pytest.mark.slow
def test_pod_sync_partial_manual_subprocess():
    """Every pod-sync mode lowers and runs inside a *partial-manual*
    shard_map (the multi-pod train-step context) — on old jax this exercises
    compat's psum-emulated all_gather/ppermute with data-derived pod ids."""
    out = subprocess.run(
        [sys.executable, "-c", POD_SYNC_PARTIAL_MANUAL_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "POD_SYNC_PM_OK" in out.stdout


# ------------------------------ pipeline parallel (subprocess) ------------------
PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.pipeline.pipeline import make_pipeline_apply, bubble_fraction
mesh = jax.make_mesh((4,), ("pipe",))
L, d, mb, m = 8, 16, 4, 6
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (L, d, d)) * 0.1}
def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"])
apply = make_pipeline_apply(layer_fn, mesh)
xs = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
ys = apply(params, xs)
# sequential reference
ref = xs
for i in range(L):
    ref = jnp.tanh(ref @ params["w"][i])
np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), rtol=2e-4, atol=2e-4)
assert abs(bubble_fraction(6, 4) - 3/9) < 1e-9
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    """SWARM-style pipeline == sequential layer apply, on a real 4-stage mesh."""
    out = subprocess.run(
        [sys.executable, "-c", PIPELINE_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PIPELINE_OK" in out.stdout
