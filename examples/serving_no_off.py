"""The no-off problem at inference time (§4.1 × §5): who can refuse or
halt *serving* when custody holders churn or defect?

One ``serving.sweep`` call compiles the whole serving phase diagram —
(load × churn rate × custody redundancy × coalition fraction × seed),
every lane a full continuous-batching run with admission queues, per-slot
KV caches, on-device credential fees, and coverage-gated availability —
into a single device program: the custody matrix, the outage windows, and
the arrival schedule all ride as traced lanes, exactly like the training
campaign's mixing/custody lanes.

    PYTHONPATH=src python examples/serving_no_off.py            # both grids
    PYTHONPATH=src python examples/serving_no_off.py --smoke    # tiny
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import serving
from repro.core.scenarios import get_serving_grid
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="the 8-lane serving_smoke grid only")
    args = ap.parse_args()

    cfg = get_config("protocol-125m").reduced(
        num_layers=1, d_model=32, num_heads=2, head_dim=16, d_ff=64,
        vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    grids = (["serving_smoke"] if args.smoke
             else ["serving_frontier", "serving_coalition"])
    for name in grids:
        grid = get_serving_grid(name)
        print(f"\n== {name}: {grid.n_points} serving lanes as one compiled "
              f"program ==")
        print(f"   ({grid.slots} slots, {grid.n_requests} requests/lane, "
              f"{grid.num_shards} shards over {grid.n_nodes} nodes, "
              f"horizon {grid.steps} steps)")
        res = serving.sweep(model, params, grid)
        print(f"   {res.n_runs} lanes in {res.n_programs} program, "
              f"{res.wall_s:.1f}s -> {res.runs_per_s:.1f} lanes/s, "
              f"{res.tok_per_s:.0f} tok/s aggregate")
        print(res.availability_table())

    print(
        "\nReading: a Protocol Model's inference inherits an off-switch "
        "nobody designed.  Serving halts exactly when custody coverage "
        "drops below 1 — with a shard missing there is no model to run, "
        "so whoever holds a shard's LAST live copy holds a serving veto.  "
        "At redundancy 1 every holder is such a veto (churn alone halts "
        "serving); redundancy buys availability under churn (gaps heal -> "
        "'degraded', not 'halted') but widens the coalition needed to "
        "refuse serving — the same redundancy dial that §4.1 trades "
        "against extractability.  Load, by contrast, only backlogs: "
        "overload delays requests, it cannot halt the swarm.  The no-off "
        "property cuts both ways at inference: nobody can switch the "
        "model off unilaterally at high redundancy, and nobody can *keep "
        "it on* against a shard-covering coalition's exit.")


if __name__ == "__main__":
    main()
