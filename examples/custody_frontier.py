"""The extractability frontier (§4.1 × §5.5): at what redundancy and
coalition fraction does a swarm stop being a Protocol Model?

One ``derailment.sweep`` call compiles the whole custody phase diagram —
(redundancy × coalition fraction × churn seed), every lane tracing the
live coverage frontier and running the reconstruct-attack eval — into a
single device program: the (N, S) custody matrix and the coalition mask
ride as traced lanes of the campaign, exactly like PR 3's mixing matrix.

    PYTHONPATH=src python examples/custody_frontier.py           # small LM
    PYTHONPATH=src python examples/custody_frontier.py --tiny    # quadratic
"""
import argparse

from repro.core import unextractable as unext
from repro.core.derailment import no_off_report, sweep
from repro.core.scenarios import Regime, SweepGrid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--seeds", type=int, default=3,
                    help="churn seeds per phase-diagram cell")
    ap.add_argument("--tiny", action="store_true",
                    help="convex toy problem instead of the small LM")
    args = ap.parse_args()

    from common import small_lm_problem, tiny_quadratic_problem
    loss_fn, params, data_fn, eval_fn, opt = (
        tiny_quadratic_problem() if args.tiny else small_lm_problem())
    n_honest, num_shards = 10, 12
    grid = SweepGrid(
        name="custody_frontier_example",
        description="§4.1 extractability frontier",
        regimes=(Regime("mean", "mean"),),
        n_honest=n_honest,
        attacker_counts=(0,),
        seeds=tuple(range(args.seeds)),
        rounds=args.rounds,
        redundancies=(1, 2, 3),
        coalition_fractions=(0.2, 0.4, 0.6, 0.8, 1.0),
        num_shards=num_shards,
        custody_max_fraction=0.4,
        custody_leave_fraction=0.3,
    )

    print(f"custody: {num_shards} shards over {n_honest} nodes, per-node "
          f"bound 0.4; 30% of the roster churns out mid-run")
    for red in grid.redundancies:
        c = unext.ShardCustody.assign(
            [f"h{i}" for i in range(n_honest)], num_shards, redundancy=red,
            max_fraction=grid.custody_max_fraction)
        print(f"  redundancy {red}: min extraction coalition "
              f"{c.min_extraction_coalition(exact=True)} nodes (exact; "
              f"greedy upper bound {c.min_extraction_coalition()})")

    print(f"\nrunning the {grid.n_points}-point custody phase diagram as "
          f"one compiled program (coverage trace + reconstruct-attack eval "
          "inside the program)...")
    res = sweep(loss_fn, params, opt, data_fn, eval_fn, grid)
    print(f"  {res.n_runs} runs in {res.n_programs} program, "
          f"{res.wall_s:.1f}s -> {res.runs_per_s:.2f} runs/s")

    print("\n== §4.1 extractability phase table ==")
    print(res.extractability_table())

    print("\n== per-cell detail (extracted/honest prices the attack) ==")
    print(no_off_report(sorted(
        res.results,
        key=lambda r: (r.redundancy, r.coalition_fraction, r.seed))))

    print("\nReading: the custody bound draws the frontier.  Below full "
          "coverage the reconstruct-attack eval shows the coalition "
          "reassembles garbage — extracted loss far above honest, by as "
          "many orders of magnitude as training has actually progressed "
          "(a barely-trained model is cheap to 'steal' because there is "
          "nothing to steal yet) — the Protocol Model property; the moment "
          "the coalition "
          "covers every shard the extracted model IS the model "
          "(extracted/honest = 1.0).  Redundancy trades the two risks "
          "against each other: r=1 keeps coalitions small but lets churn "
          "collapse the live frontier ('degraded' — nobody holds the full "
          "model any more), higher r survives churn but hands bigger "
          "coalitions full coverage.  Unextractability is an *operating "
          "point*, not a free property.")


if __name__ == "__main__":
    main()
