"""Quickstart: build any assigned architecture, train it on the synthetic
LM pipeline, checkpoint it, and serve a few greedy tokens.

    PYTHONPATH=src python examples/quickstart.py --arch tinyllama-1.1b
"""
import argparse

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data.pipeline import DataConfig, model_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import greedy_decode
from repro.launch.train import TrainOptions, TrainState, make_train_step
from repro.models.model import build_model
from repro.optim.optimizer import AdamW, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=ASSIGNED_ARCHS + ["protocol-125m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()          # CPU-sized, same family
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"(reduced: {cfg.num_layers}L d={cfg.d_model}); "
          f"full-size N={get_config(args.arch).param_count():,}")

    opt = AdamW(lr=cosine_schedule(3e-3, 10, args.steps))
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params))
    step_fn = jax.jit(make_train_step(model, opt, make_host_mesh(),
                                      TrainOptions()))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)

    for step in range(args.steps):
        state, metrics = step_fn(state, model_batch(cfg, dcfg, step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}")

    ckpt.save(args.ckpt, state.params, step=args.steps)
    print(f"checkpoint -> {args.ckpt} (step {ckpt.load_step(args.ckpt)})")

    restored = ckpt.restore(args.ckpt, jax.eval_shape(lambda: state.params))
    prompts = model_batch(cfg, dcfg, 0)["tokens"][:2, :8]
    gen, stats = greedy_decode(model, restored, prompts, max_new=16)
    print(f"served {stats.batch}x{stats.tokens_out} tokens "
          f"({stats.tok_per_s:.1f} tok/s): {gen[0].tolist()}")


if __name__ == "__main__":
    main()
