"""Protocol Model serving (§4.1): credential-gated, custody-sharded
batched inference — weights never leave the protocol.

Demonstrates: (1) credential gating + transferable credentials, (2) serving
requires the live swarm, (3) a partial coalition reassembles only garbage,
(4) the extraction-vs-retrain economics that define a Protocol Model.

    PYTHONPATH=src python examples/protocol_inference.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.ledger import Ledger
from repro.core.protocol import (
    CredentialError,
    ExtractionError,
    ProtocolModelServer,
)
from repro.core.unextractable import (
    extraction_cost_flops,
    is_protocol_model,
    retrain_cost_flops,
)
from repro.models.model import build_model


def main():
    cfg = get_config("protocol-125m").reduced(
        num_layers=4, d_model=256, num_heads=4, head_dim=64, d_ff=1024,
        vocab_size=2048)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    nodes = [f"node{i}" for i in range(8)]
    ledger = Ledger()
    for i, n in enumerate(nodes):
        ledger.record_contribution(n, float(1 + i % 3))    # training shares

    srv = ProtocolModelServer.create(model, params, nodes, ledger,
                                     num_shards=16, redundancy=2,
                                     max_fraction=0.35)
    print(f"model sharded into {srv.custody.num_shards} custody shards over "
          f"{len(nodes)} nodes (redundancy {srv.custody.redundancy}, "
          f"max fraction 0.35)")

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab_size)}

    # 1. credential gating + transfer
    try:
        srv.serve("customer", batch)
    except CredentialError as e:
        print(f"no credentials -> refused: {e}")
    ledger.transfer("node0", "customer", 0.5)
    logits = srv.serve("customer", batch)
    print(f"after credential transfer: served batch of 4, "
          f"logits {logits.shape}, top tok {int(jnp.argmax(logits[0]))}")

    # 2. elasticity: serving survives departures (redundancy 2) ...
    online = [n for n in nodes if n != "node3"]
    srv.serve("customer", batch, online_nodes=online)
    print(f"node3 offline: still served ({srv.custody.tolerates_departures(['node3'])})")
    # ... but not a collapsed swarm — and the failure names the shard ids
    # the survivors are missing, so the outage is diagnosable
    try:
        srv.serve("customer", batch, online_nodes=nodes[:2])
    except ExtractionError as e:
        print(f"swarm collapsed to 2 nodes -> {e}")
        print(f"  (missing shard ids: {srv.custody.missing_shards(nodes[:2])})")

    # 3. a coalition below full coverage extracts garbage
    coalition = nodes[:3]
    cov = srv.custody.coverage(coalition)
    broken = srv.attempt_extraction(coalition)
    ref = model.prefill(params, batch)
    got = model.prefill(broken, batch)
    print(f"coalition of 3 covers {cov * 100:.0f}% of shards; "
          f"extracted-model logit error: "
          f"{float(jnp.max(jnp.abs(got - ref))):.2f} (unusable)")

    # 4. the defining inequality: acquire-missing-shards vs retrain
    n_params = cfg.param_count()
    tokens = 20 * n_params                                 # chinchilla-ish
    cost_per_shard = retrain_cost_flops(n_params, tokens) / 4
    extract = extraction_cost_flops(srv.custody, coalition, cost_per_shard)
    retrain = retrain_cost_flops(n_params, tokens)
    print(f"extraction cost {extract:.2e} FLOPs vs retrain {retrain:.2e} "
          f"-> protocol model: "
          f"{is_protocol_model(srv.custody, coalition, n_params, tokens, cost_per_shard)}")
    print(f"min coalition for full coverage: "
          f"{srv.custody.min_extraction_coalition()} of {len(nodes)} nodes")


if __name__ == "__main__":
    main()
