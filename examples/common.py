"""Shared example plumbing: the reduced benchmark LM that the phase-diagram
examples sweep.  One definition keeps `derailment_no_off.py` and
`topology_no_off.py` numbers comparable — tweak the model here and both
diagrams move together."""
import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, data_fn_for_swarm, model_batch
from repro.models.model import build_model
from repro.optim.optimizer import SGD


def tiny_quadratic_problem(n_params: int = 16):
    """(loss_fn, params, data_fn, eval_fn, optimizer) for the convex toy
    problem — the --tiny fast path of the phase-diagram examples."""
    import jax.numpy as jnp
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    target = jax.random.normal(k1, (n_params,))
    loss_fn = lambda p, b: jnp.mean(jnp.square(b["x"] @ (p["w"] - target)))

    def data_fn(node_idx, rnd):
        k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
        return {"x": jax.random.normal(k, (16, n_params))}

    params = {"w": jnp.zeros((n_params,))}
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    return loss_fn, params, data_fn, eval_fn, SGD(lr=0.1, momentum=0.0)


def small_lm_problem():
    """(loss_fn, params, data_fn, eval_fn, optimizer) for a small LM that
    sweeps a whole phase diagram in minutes on a 2-core CPU box."""
    cfg = get_config("protocol-125m").reduced(
        num_layers=2, d_model=64, num_heads=4, head_dim=16, d_ff=256,
        vocab_size=256)
    model = build_model(cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=32)
    params = model.init(jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model.loss(p, b)[0]
    data_fn = data_fn_for_swarm(cfg, dcfg, 32)
    eval_fn = lambda p: loss_fn(p, model_batch(cfg, dcfg, 10**6))
    return loss_fn, params, data_fn, eval_fn, SGD(lr=0.5, momentum=0.9)
