"""The No-Off Problem without the center (§3.2 × §5.5): when aggregation
itself is decentralized — per-node replicas, neighborhood robust
aggregation over a gossip graph, no global aggregate — at what spectral
gap does local robust aggregation stop resisting derailment?

One ``derailment.sweep`` call compiles the whole decentralized phase
diagram — (topology × attacker fraction × seed) for every aggregation
regime, honest baselines trained per topology — into a single device
program: the mixing matrix rides as a traced lane of the campaign.

    PYTHONPATH=src python examples/topology_no_off.py           # small LM
    PYTHONPATH=src python examples/topology_no_off.py --tiny    # quadratic
"""
import argparse

from repro.core import topology
from repro.core.derailment import no_off_report, sweep
from repro.core.scenarios import Regime, SweepGrid

TOPOLOGIES = ("ring", "clustered", "random_regular", "fully_connected")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--seeds", type=int, default=2,
                    help="seeds per phase-diagram cell")
    ap.add_argument("--tiny", action="store_true",
                    help="convex toy problem instead of the small LM")
    args = ap.parse_args()

    from common import small_lm_problem, tiny_quadratic_problem
    loss_fn, params, data_fn, eval_fn, opt = (
        tiny_quadratic_problem() if args.tiny else small_lm_problem())
    n_honest = 8
    grid = SweepGrid(
        name="no_off_decentralized",
        description="§5.5 without the center",
        regimes=(Regime("mean", "mean"),
                 Regime("centered_clip", "centered_clip")),
        topologies=TOPOLOGIES,
        n_honest=n_honest,
        attacker_counts=(1, 4, 8),
        seeds=tuple(range(args.seeds)),
        scales=(20.0,),
        rounds=args.rounds,
    )

    n_total = n_honest + max(grid.attacker_counts)
    print("spectral gaps at swarm size", n_total, "(higher = faster mixing):")
    for t in TOPOLOGIES:
        gap = topology.spectral_gap(topology.mixing_matrix(t, n_total))
        print(f"  {t:16s} gap={gap:.4f}")

    print(f"\nrunning the {grid.n_points}-point decentralized phase diagram "
          f"as one compiled program ({grid.n_points + len(TOPOLOGIES) * len(grid.seeds)}"
          " decentralized runs incl per-topology baselines)...")
    res = sweep(loss_fn, params, opt, data_fn, eval_fn, grid)
    print(f"  {res.n_runs} runs in {res.n_programs} program, "
          f"{res.wall_s:.1f}s -> {res.runs_per_s:.2f} runs/s")

    print("\n== decentralized §5.5 phase diagram "
          "(derailed seeds / total, s = attackers slashed) ==")
    print(res.phase_table())

    print("\n== per-cell detail ==")
    print(no_off_report(sorted(
        res.results, key=lambda r: (r.regime, r.topology, r.attacker_fraction))))

    print("\nReading: the centralized breakdown point is a *global* "
          "fraction, but a sparse graph is attacked neighborhood by "
          "neighborhood — the same coalition that CenteredClip shrugs off "
          "on the complete graph can exceed the local breakdown point of a "
          "low-gap ring or near-partitioned swarm and let the poison "
          "gossip outward.  Robust aggregation's resistance to derailment "
          "degrades with the spectral gap: decentralization widens the "
          "no-off gap the paper warns about.")


if __name__ == "__main__":
    main()
