"""The No-Off Problem (§5.5), measured: can a derailment attack — the one
*digital* emergency brake — actually halt a protocol-learning run?

Sweeps attacker fraction × aggregation × verification on a real (small) LM
and prints the paper's qualitative table with numbers attached, plus the
attack's price tag.

    PYTHONPATH=src python examples/derailment_no_off.py
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.derailment import (
    attack_cost,
    no_off_report,
    simulate_derailment,
)
from repro.core.verification import VerificationConfig
from repro.data.pipeline import DataConfig, data_fn_for_swarm, model_batch
from repro.models.model import build_model
from repro.optim.optimizer import SGD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config("protocol-125m").reduced(
        num_layers=2, d_model=128, num_heads=4, head_dim=32, d_ff=512,
        vocab_size=512)
    model = build_model(cfg)
    n_honest = 8
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=32)
    params = model.init(jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model.loss(p, b)[0]
    data_fn = data_fn_for_swarm(cfg, dcfg, 32)
    eval_fn = lambda p: loss_fn(p, model_batch(cfg, dcfg, 10**6))
    opt = SGD(lr=0.5, momentum=0.9)

    vcfg = VerificationConfig(p_check=0.5, stake=10.0, tolerance=1e-3)
    results = []
    print("running derailment sweep on the batched swarm engine "
          "(this trains a small LM repeatedly)...")
    # one shared honest baseline for every cell (it would otherwise be
    # recomputed 9x) — the registry's honest_baseline scenario
    from repro.core.scenarios import get_scenario
    base_swarm = get_scenario("honest_baseline").build_swarm(
        loss_fn, params, opt, data_fn, n_nodes=n_honest)
    baseline_loss = base_swarm.run(args.rounds, eval_fn=eval_fn,
                                   eval_every=args.rounds)[-1]
    print(f"  honest baseline loss after {args.rounds} rounds: "
          f"{baseline_loss:.3f}")
    for aggregator, verification in [("mean", None),
                                     ("centered_clip", None),
                                     ("mean", vcfg)]:
        for n_attack in [1, 4, 10]:
            res = simulate_derailment(
                loss_fn, params, opt, data_fn, eval_fn,
                n_honest=n_honest, n_attack=n_attack, rounds=args.rounds,
                aggregator=aggregator, verification=verification,
                attack="inner_product", scale=20.0,
                baseline_loss=baseline_loss)
            results.append(res)
            print(f"  {aggregator:14s} verified={verification is not None!s:5s} "
                  f"attackers={n_attack:2d} -> derailed={res.derailed}")

    print("\n== §5.5 No-Off table ==")
    print(no_off_report(results))

    print("\n== attack economics ==")
    for n_attack in [4, 10]:
        c_unv = attack_cost(n_attack, args.rounds, compute_cost_per_round=1.0,
                            verification=None)
        c_ver = attack_cost(n_attack, args.rounds, compute_cost_per_round=1.0,
                            verification=vcfg)
        print(f"  {n_attack:2d} attackers x {args.rounds} rounds: "
              f"unverified={c_unv:.0f} units, verified={c_ver:.0f} units "
              f"(stakes burned)")

    print("\nReading: under mean aggregation the off-switch works (and so "
          "does any vandal); robust aggregation raises the bar to the "
          "breakdown point; near-perfect verification neutralizes it — "
          "the paper's conclusion that only physical intervention remains.")


if __name__ == "__main__":
    main()
