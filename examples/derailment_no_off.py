"""The No-Off Problem (§5.5), measured: can a derailment attack — the one
*digital* emergency brake — actually halt a protocol-learning run?

One ``derailment.sweep`` call compiles the whole phase diagram — attacker
fraction × seed for every (aggregator, verification) regime, honest
baselines included — into a single device program (``lax.scan`` over
rounds, ``vmap`` over runs) on a real (small) LM, then prints the paper's
qualitative table with numbers attached, plus the attack's price tag.

    PYTHONPATH=src python examples/derailment_no_off.py
"""
import argparse

from common import small_lm_problem

from repro.core.derailment import attack_cost, no_off_report, sweep
from repro.core.scenarios import Regime, SweepGrid
from repro.core.verification import VerificationConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per phase-diagram cell")
    args = ap.parse_args()

    # small enough that the whole phase diagram (counts x regimes lanes,
    # each lane an 18-node swarm) sweeps in minutes on a 2-core CPU box
    loss_fn, params, data_fn, eval_fn, opt = small_lm_problem()
    n_honest = 8

    vcfg = VerificationConfig(p_check=0.5, stake=10.0, tolerance=1e-3)
    grid = SweepGrid(
        name="no_off_lm",
        description="§5.5 table on a real (small) LM",
        regimes=(Regime("mean", "mean"),
                 Regime("centered_clip", "centered_clip"),
                 Regime("mean+verified", "mean", verification=vcfg)),
        n_honest=n_honest,
        attacker_counts=(1, 4, 10),
        seeds=tuple(range(args.seeds)),
        scales=(20.0,),
        rounds=args.rounds,
    )

    print(f"running the {grid.n_points}-point derailment phase diagram as "
          "one compiled program (this trains a small LM "
          f"{grid.n_points + len(grid.seeds)} times on device)...")
    res = sweep(loss_fn, params, opt, data_fn, eval_fn, grid)
    print(f"  {res.n_runs} runs (incl {len(grid.seeds)} shared honest "
          f"baselines) in {res.n_programs} program, {res.wall_s:.1f}s "
          f"-> {res.runs_per_s:.2f} runs/s")

    print("\n== §5.5 phase diagram (derailed seeds / total, s = attackers "
          "slashed) ==")
    print(res.phase_table())

    print("\n== per-cell detail ==")
    print(no_off_report(sorted(res.results,
                               key=lambda r: (r.regime, r.attacker_fraction))))

    print("\n== attack economics ==")
    for n_attack in (4, 10):
        c_unv = attack_cost(n_attack, args.rounds, compute_cost_per_round=1.0,
                            verification=None)
        c_ver = attack_cost(n_attack, args.rounds, compute_cost_per_round=1.0,
                            verification=vcfg)
        print(f"  {n_attack:2d} attackers x {args.rounds} rounds: "
              f"unverified={c_unv:.0f} units, verified={c_ver:.0f} units "
              f"(stakes burned)")

    print("\nReading: under mean aggregation the off-switch works (and so "
          "does any vandal); robust aggregation raises the bar to the "
          "breakdown point; near-perfect verification neutralizes it — "
          "the paper's conclusion that only physical intervention remains.")


if __name__ == "__main__":
    main()
