"""END-TO-END DRIVER: train the paper's demonstrator LM across a simulated
incentivized swarm on the batched vmap/jit engine.

The default "showcase" roster exercises all five §3 properties + §4
incentives at once:

  - 10 heterogeneous nodes (speeds 0.5-3x), elastic (2 join late, 1 leaves),
  - 2 byzantine nodes (inner-product attack [87]),
  - QSGD-compressed wire (§3.1), CenteredClip aggregation (§3.3, [27, 40]),
  - stake/slash verification audits (§4.2),
  - fractional-ownership ledger + custody-sharded checkpoint (§4.1).

Any scenario from the registry (docs/scenarios.md) runs the same driver:

    PYTHONPATH=src python examples/swarm_byzantine_training.py             # showcase, ~2 min
    PYTHONPATH=src python examples/swarm_byzantine_training.py --scenario audit_heavy --nodes 16
    PYTHONPATH=src python examples/swarm_byzantine_training.py --full      # true 125M
"""
import argparse
import time

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.core.scenarios import batched_data_fn_for, get_scenario, list_scenarios
from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.core.unextractable import ShardCustody
from repro.core.verification import VerificationConfig
from repro.data.pipeline import DataConfig, data_fn_for_swarm, model_batch
from repro.models.model import build_model
from repro.optim.optimizer import AdamW


def showcase_roster(rounds: int):
    """The all-properties-at-once roster (not a registry scenario: it mixes
    every regime deliberately; the registry keeps regimes isolated)."""
    nodes = [
        NodeSpec("h0", speed=3.0),
        NodeSpec("h1", speed=1.0),
        NodeSpec("h2", speed=1.0),
        NodeSpec("h3", speed=0.5),
        NodeSpec("h4", speed=1.0, leave_round=rounds // 2),
        NodeSpec("h5", speed=1.0),
        NodeSpec("late0", speed=2.0, join_round=rounds // 4),
        NodeSpec("late1", speed=1.0, join_round=rounds // 4),
        NodeSpec("adv0", byzantine="inner_product", byzantine_scale=20.0),
        NodeSpec("adv1", byzantine="sign_flip", byzantine_scale=10.0),
    ]
    cfg = SwarmConfig(
        aggregator="centered_clip",
        agg_kwargs={"clip_tau": 2.0, "iters": 3},
        verification=VerificationConfig(p_check=0.25, stake=10.0,
                                        tolerance=1e-3, jackpot=5.0),
        compression="qsgd",
        compression_kwargs={"levels": 127, "bucket_size": 512},
    )
    return nodes, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--scenario", default="showcase",
                    choices=["showcase"] + list_scenarios())
    ap.add_argument("--nodes", type=int, default=10,
                    help="swarm size (registry scenarios only)")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sequential"])
    ap.add_argument("--full", action="store_true",
                    help="true 125M params (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_swarm_custody_ckpt")
    args = ap.parse_args()

    cfg = get_config("protocol-125m")
    if not args.full:
        cfg = cfg.reduced(num_layers=4, d_model=256, num_heads=4,
                          head_dim=64, d_ff=1024, vocab_size=2048)
    model = build_model(cfg)
    print(f"model: {cfg.name} N={model.cfg.param_count():,} "
          f"({'full' if args.full else 'reduced'})")

    if args.scenario == "showcase":
        nodes, swarm_cfg = showcase_roster(args.rounds)
    else:
        nodes, swarm_cfg = get_scenario(args.scenario).build(n_nodes=args.nodes)
    n_nodes = len(nodes)
    print(f"scenario: {args.scenario} ({n_nodes} nodes, engine={args.engine})")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                      global_batch=n_nodes * 2)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=5e-3)
    loss_fn = lambda p, b: model.loss(p, b)[0]
    data_fn = data_fn_for_swarm(cfg, dcfg, n_nodes)
    # the synthetic pipeline is jax-pure in the node index, so the batched
    # engine can build all N node batches in a single vmapped dispatch
    bdf = (batched_data_fn_for(data_fn, n_nodes)
           if args.engine == "batched" else None)
    swarm = make_swarm(loss_fn, params, opt, nodes, swarm_cfg, data_fn,
                       engine=args.engine, batched_data_fn=bdf)
    eval_fn = lambda p: loss_fn(p, model_batch(cfg, dcfg, 10**6))

    t0 = time.time()
    print(f"{'round':>6} {'active':>6} {'byz':>4} {'loss':>8}  slashed")
    for r in range(args.rounds):
        rec = swarm.step(r)
        if r % 20 == 0 or r == args.rounds - 1:
            loss = float(eval_fn(swarm.eval_params()))
            print(f"{r:6d} {rec['n_active']:6d} {rec['n_byzantine']:4d} "
                  f"{loss:8.4f}  {sorted(swarm.slashed)}")

    dt = time.time() - t0
    print(f"\ntrained {args.rounds} rounds in {dt:.0f}s "
          f"({args.rounds / max(dt, 1e-9):.1f} rounds/s)")

    # §4: ownership proportional to verified (speed-weighted) work
    print("\nfractional ownership (ledger):")
    for node, bal in sorted(swarm.ledger.balances.items(),
                            key=lambda kv: -kv[1]):
        print(f"  {node:10s} {bal:8.1f} shares "
              f"({swarm.ledger.ownership_fraction(node) * 100:5.1f}%)")
    print(f"  burned stake: {swarm.ledger.burned_stake:g} "
          f"(slashed: {sorted(swarm.slashed)})")
    assert swarm.ledger.check_conservation()

    # §4.1: the checkpoint itself is custody-sharded — no node holds it all
    holders = [n.node_id for n in nodes if n.node_id not in swarm.slashed]
    custody = ShardCustody.assign(holders, num_shards=16, redundancy=2,
                                  max_fraction=0.4)
    # decentralized scenarios checkpoint the consensus replica
    ckpt.save_custody(args.ckpt, swarm.eval_params(), custody)
    print(f"\ncustody checkpoint -> {args.ckpt}")
    print(f"  min extraction coalition: {custody.min_extraction_coalition()} "
          f"of {len(holders)} nodes")
    try:
        ckpt.restore_custody(args.ckpt, swarm.eval_params(),
                             holders=holders[:2])
        raise RuntimeError("partial coalition restored — bug!")
    except PermissionError as e:
        print(f"  partial-coalition restore correctly refused: {e}")


if __name__ == "__main__":
    main()
