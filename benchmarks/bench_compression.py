"""Paper §3.1: gradient compression cuts communication with minor loss
impact.  Measures wire ratio + end-task loss delta on a real (small) LM,
and times the QSGD Pallas kernel against its jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core import compression
from repro.core.swarm import NodeSpec, Swarm, SwarmConfig
from repro.data.pipeline import DataConfig, data_fn_for_swarm, model_batch
from repro.models.model import build_model
from repro.optim.optimizer import SGD


def _swarm_loss(compression_mode, kwargs, rounds=25):
    cfg = get_config("tinyllama-1.1b").reduced(d_model=64, d_ff=128,
                                               vocab_size=256, num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    nodes = [NodeSpec(f"h{i}") for i in range(4)]
    swarm = Swarm(lambda p, b: model.loss(p, b)[0], params, SGD(lr=0.3),
                  nodes, SwarmConfig(aggregator="mean",
                                     compression=compression_mode,
                                     compression_kwargs=kwargs),
                  data_fn_for_swarm(cfg, dcfg, 4))
    eval_fn = lambda p: model.loss(p, model_batch(cfg, dcfg, 9999))[0]
    return swarm.run(rounds, eval_fn=eval_fn)[-1]


def run() -> list:
    rows: list[Row] = []

    # wire ratios on a 1M-element gradient
    x = jax.random.normal(jax.random.PRNGKey(0), (1 << 20,))
    c = compression.qsgd_compress(jax.random.PRNGKey(1), x, levels=16)
    rows.append(("compression.qsgd16_ratio", 0.0,
                 f"{compression.compression_ratio(c):.1f}x fewer bits"))
    c8 = compression.topk_compress(x, k_frac=0.01)
    rows.append(("compression.top1pct_ratio", 0.0,
                 f"{compression.compression_ratio(c8):.1f}x fewer bits"))

    # loss impact (paper: 'minor effect on performance')
    base = _swarm_loss(None, {})
    q = _swarm_loss("qsgd", {"levels": 64})
    t = _swarm_loss("topk", {"k_frac": 0.05})
    rows.append(("compression.loss_uncompressed", 0.0, f"{base:.3f}"))
    rows.append(("compression.loss_qsgd64", 0.0,
                 f"{q:.3f} (delta {q - base:+.3f})"))
    rows.append(("compression.loss_top5pct", 0.0,
                 f"{t:.3f} (delta {t - base:+.3f})"))

    # kernel timing (interpret mode on CPU — correctness-path timing only)
    from repro.kernels.qsgd.ops import qsgd_roundtrip
    from repro.kernels.qsgd.ref import qsgd_roundtrip_ref
    xs = jax.random.normal(jax.random.PRNGKey(2), (1 << 16,))
    key = jax.random.PRNGKey(3)
    us_k = timeit(lambda: qsgd_roundtrip(key, xs, interpret=True))
    us_r = timeit(lambda: qsgd_roundtrip_ref(key, xs))
    rows.append(("compression.qsgd_kernel_interpret", us_k, "64k elements"))
    rows.append(("compression.qsgd_oracle_jnp", us_r, "64k elements"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
