"""Bounded-staleness async rounds: rounds/s vs the sync engine, straggler
utilization.

Times the identical swarm round — centered_clip over an (N, D) stack with a
heterogeneous-speed roster — built synchronously (``staleness_bound=0``)
and with the bounded-staleness ring (``staleness_bound=K``: snapshot write,
per-node delay draw, per-node gather, vmapped per-snapshot gradients).  Two
numbers per setting:

- **engine overhead**: async rounds/s vs the sync baseline — what the ring
  costs in wall time per round (both are one compiled ``lax.scan``);
- **straggler-utilization ratio**: what asynchrony buys back.  A
  bulk-synchronous round waits for the slowest node (round time
  ``max(1/speed)``, average utilization ``mean(1/speed) / max(1/speed)``);
  with bound K a slow node spreads its round over K+1 protocol rounds, so
  the modeled round time is ``max(mean(1/speed), max(1/speed) / (K+1))``.
  The ratio (async utilization / sync utilization) is the §3 property-5
  claim quantified against this roster.

Settings:

  tiny    N=8,  D=8 192     (CI smoke)
  large   N=16, D=262 144   (the stack the ring gather must move)

CLI:  ``python benchmarks/bench_async.py [--tiny] [--json F]``
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core.swarm import (_FAR, LaneParams, init_state, make_round_fn,
                              scan_rounds)
from repro.optim.optimizer import SGD

#: filled by run() for the --json artifact
LAST_META: dict = {}

#: stragglers: a 4x spread, slowest node 16x behind the fastest
_SPEEDS = (4.0, 1.0, 1.0, 0.25)


def _problem(n: int, d_cols: int):
    target = jax.random.normal(jax.random.PRNGKey(0), (64, d_cols)) * 0.1

    def loss_fn(params, batch):
        return jnp.mean(jnp.square(batch["x"] @ params["w"]
                                   - batch["x"] @ target))

    def batch_fn(rnd):
        k = jax.random.fold_in(jax.random.PRNGKey(7), rnd)
        return {"x": jax.random.normal(k, (n, 8, 64))}

    return loss_fn, {"w": jnp.zeros((64, d_cols))}, batch_fn


def _lane(n: int, staleness_bound: int) -> LaneParams:
    speeds = jnp.asarray([_SPEEDS[i % len(_SPEEDS)] for i in range(n)])
    return LaneParams(
        codes=jnp.zeros((n,), jnp.int32), scales=jnp.ones((n,)),
        speeds=speeds, joins=jnp.zeros((n,), jnp.int32),
        leaves=jnp.full((n,), _FAR, jnp.int32),
        delays=(jnp.full((n,), staleness_bound, jnp.int32)
                if staleness_bound > 0 else None),
        base_key=jax.random.PRNGKey(11), p_check=jnp.asarray(0.0),
        tolerance=jnp.asarray(1e-3), numeric_noise=jnp.asarray(0.0),
        agg_id=jnp.asarray(0, jnp.int32), agg_kwargs={})


def _compile(n: int, d_cols: int, rounds: int, staleness_bound: int):
    loss_fn, params0, batch_fn = _problem(n, d_cols)
    opt = SGD(lr=0.05, momentum=0.0)
    rf = make_round_fn(loss_fn, opt, params0, n, aggregator="centered_clip",
                       staleness_bound=staleness_bound)

    def prog(lane):
        return scan_rounds(rf, lane,
                           init_state(params0, opt, n,
                                      staleness_bound=staleness_bound),
                           rounds, batch_fn)

    return jax.jit(prog).lower(_lane(n, staleness_bound)).compile()


def _time_per_round(compiled, lane, rounds: int, repeats: int):
    out = compiled(lane)                      # warm (allocs, transfers)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(lane))
        best = min(best, time.perf_counter() - t0)
    return best / rounds, out


def _utilization(n: int, staleness_bound: int):
    """The straggler model documented in the module docstring: per-unit-work
    times 1/speed, sync rounds gated by the slowest node, async rounds by
    max(mean, slowest / (K+1))."""
    t = 1.0 / np.asarray([_SPEEDS[i % len(_SPEEDS)] for i in range(n)])
    sync_round = t.max()
    async_round = max(t.mean(), t.max() / (staleness_bound + 1))
    return t.mean() / sync_round, t.mean() / async_round


def _bench_setting(name: str, n: int, d_cols: int, rounds: int,
                   repeats: int, staleness_bound: int = 3) -> list:
    rows: list[Row] = []
    d = 64 * d_cols
    per_round = {}
    mean_staleness = 0.0
    for k in (0, staleness_bound):
        compiled = _compile(n, d_cols, rounds, k)
        sec, out = _time_per_round(compiled, _lane(n, k), rounds, repeats)
        per_round[k] = sec
        mode = "sync" if k == 0 else "async"
        extra = ""
        if k > 0:
            _, recs, _ = out
            mean_staleness = float(np.asarray(recs.staleness).mean())
            extra = f" mean_staleness={mean_staleness:.2f}"
        rows.append((
            f"async.{name}.{mode}", sec * 1e6,
            f"{1.0 / sec:.2f} rounds/s (N={n} D={d} K={k}"
            f" centered_clip{extra})"))

    overhead = per_round[staleness_bound] / per_round[0]
    util_sync, util_async = _utilization(n, staleness_bound)
    ratio = util_async / util_sync
    rows.append((f"async.{name}.overhead", 0.0,
                 f"{overhead:.2f}x async wall cost per round over sync "
                 f"(the K+1-snapshot ring's gather + vmapped grads)"))
    rows.append((f"async.{name}.utilization", 0.0,
                 f"straggler-utilization {util_sync:.2f} sync -> "
                 f"{util_async:.2f} async = {ratio:.2f}x at K="
                 f"{staleness_bound} (speeds {_SPEEDS})"))

    LAST_META[name] = {
        "n": n, "d": d, "rounds": rounds,
        "staleness_bound": staleness_bound,
        "sync_s_per_round": per_round[0],
        "async_s_per_round": per_round[staleness_bound],
        "async_overhead": overhead,
        "mean_realized_staleness": mean_staleness,
        "util_sync": util_sync,
        "util_async": util_async,
        "straggler_util_ratio": ratio,
    }
    return rows


def run(tiny_only: bool = False) -> list:
    rows = _bench_setting("tiny", n=8, d_cols=128, rounds=4, repeats=3)
    if not tiny_only:
        rows += _bench_setting("large", n=16, d_cols=4096, rounds=3,
                               repeats=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny setting only")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny_only=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                               for n, us, d in rows],
                       "settings": LAST_META}, f, indent=2)
