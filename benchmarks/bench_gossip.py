"""Paper §3.2: gossip replaces the synchronous all-reduce — convergence to
the exact mean is geometric in the spectral gap; per-round traffic is
O(degree), not O(N)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core import gossip


def run() -> list:
    rows: list[Row] = []
    d = 4096
    for n, topo_name, adj in [
        (16, "ring", gossip.ring_adjacency(16)),
        (64, "ring", gossip.ring_adjacency(64)),
        (64, "reg6", gossip.random_regular_adjacency(64, 6)),
        (256, "reg8", gossip.random_regular_adjacency(256, 8)),
    ]:
        w = gossip.metropolis_weights(adj)
        gap = gossip.spectral_gap(w)
        rounds = gossip.rounds_for_tolerance(w, 1e-3)
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        wj = jnp.asarray(w)
        e0 = float(gossip.consensus_error(x))
        out = gossip.gossip_average(x, wj, rounds)
        e1 = float(gossip.consensus_error(out))
        us = timeit(lambda: gossip.gossip_average(x, wj, 10))
        per_node = gossip.gossip_traffic_bytes(adj, d) // n
        ar_per_node = gossip.allreduce_traffic_bytes(n, d) // n
        rows.append((
            f"gossip.n{n}_{topo_name}", us,
            f"gap={gap:.4f} rounds_to_1e-3={rounds} "
            f"err {e0:.1f}->{e1:.5f} "
            f"bytes/node/round={per_node} (allreduce total/node={ar_per_node})"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
