"""Paper §3.2: gossip replaces the synchronous all-reduce — convergence to
the exact mean is geometric in the spectral gap; per-round traffic is
O(degree), not O(N).

Two layers:

1. raw gossip mixing (``gossip.gossip_average``) across the registered
   topologies — gap, analytic round count, error contraction, bytes/round;
2. the **full decentralized swarm round** — a topology-axis derailment
   sweep (``no_off_topology`` grid) through ``derailment.sweep``: per-node
   replicas, neighborhood robust aggregation, gossip mixing, all
   (topology × fraction × seed) lanes in ONE compiled program, reported as
   runs/s next to ``bench_derailment``'s centralized numbers.

CLI:  ``python benchmarks/bench_gossip.py [--tiny] [--json F]``
``--tiny`` runs the 4-point ``no_off_topology_smoke`` grid and skips the
large raw-mixing sizes (the CI smoke job); ``--json`` dumps rows + sweep
metadata.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core import gossip, topology

#: filled by run() for the --json artifact
LAST_SWEEP_META: dict = {}


def _mixing_rows(tiny: bool) -> list:
    rows: list[Row] = []
    d = 512 if tiny else 4096
    cases = [
        (16, "ring"),
        (16, "clustered"),
        (16, "torus"),
    ] if tiny else [
        (16, "ring"),
        (64, "ring"),
        (64, "torus"),
        (64, "clustered"),
        (64, "random_regular"),
        (256, "random_regular"),
    ]
    for n, topo_name in cases:
        adj = topology.get_topology(topo_name).builder(n, seed=0)
        w = topology.metropolis_weights(adj)
        gap = topology.spectral_gap(w)
        rounds = gossip.rounds_for_tolerance(w, 1e-3)
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        wj = jnp.asarray(w)
        e0 = float(gossip.consensus_error(x))
        out = gossip.gossip_average(x, wj, rounds)
        e1 = float(gossip.consensus_error(out))
        us = timeit(lambda: gossip.gossip_average(x, wj, 10))
        per_node = gossip.gossip_traffic_bytes(adj, d) // n
        ar_per_node = gossip.allreduce_traffic_bytes(n, d) // n
        rows.append((
            f"gossip.n{n}_{topo_name}", us,
            f"gap={gap:.4f} rounds_to_1e-3={rounds} "
            f"err {e0:.1f}->{e1:.5f} "
            f"bytes/node/round={per_node} (allreduce total/node={ar_per_node})"))
    return rows


def _decentralized_rows(grid_name: str) -> list:
    """The decentralized swarm round end-to-end: one topology-axis sweep."""
    from benchmarks.bench_byzantine import _problem
    from repro.core.derailment import sweep
    from repro.core.scenarios import get_sweep_grid
    from repro.optim.optimizer import SGD

    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = get_sweep_grid(grid_name)
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)

    n_total = grid.n_honest + max(grid.attacker_counts)
    for reg in grid.regimes:
        for topo in grid.topologies:
            gap = topology.spectral_gap(topology.mixing_matrix(topo, n_total))
            cell = [r for r in res.results
                    if r.topology == topo and r.regime == reg.name]
            der = sum(r.derailed for r in cell)
            slashed = sum(r.attackers_slashed for r in cell)
            rows.append((
                f"gossip.decentralized.{reg.name}@{topo}", 0.0,
                f"gap={gap:.4f} derailed={der}/{len(cell)} "
                f"slashed={slashed} (neighborhood {reg.aggregator})"))
    rows.append((
        "gossip.decentralized.runs_per_s", 1e6 / res.runs_per_s,
        f"{res.runs_per_s:.1f} runs/s ({res.n_runs} decentralized runs incl "
        f"per-topology baselines, {len(res.results)} grid points, "
        f"{res.n_programs} program, {res.wall_s:.2f}s end-to-end)"))
    LAST_SWEEP_META.update(
        grid=grid_name, n_points=len(res.results), n_runs=res.n_runs,
        n_programs=res.n_programs, sweep_wall_s=res.wall_s,
        sweep_runs_per_s=res.runs_per_s,
        topologies=list(grid.topologies))
    return rows


def run(tiny: bool = False) -> list:
    rows = _mixing_rows(tiny)
    rows += _decentralized_rows("no_off_topology_smoke" if tiny
                                else "no_off_topology")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small mixing sizes + the smoke sweep grid")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + sweep metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "sweep": LAST_SWEEP_META}, f, indent=2)
