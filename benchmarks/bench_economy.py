"""Paper §4: the incentive phase diagram — stake markets, Sybil pressure,
and adaptive adversaries as campaign axes.

Runs an economy ``scenarios.SweepGrid`` (identity cost × fee × reward
schedule × fixed-vs-adaptive × seed per regime) through ``derailment.sweep``:
every economy knob rides in the traced ``EconParams`` lane, so the whole
incentive grid — Sybil funding, stake-gated admission, escrowed rewards,
pool-funded jackpots, and the coalition's best-response inner step — compiles
to ONE ``jit(vmap(scan))`` program.  Three claims measured:

- **phase structure**: each lane classified sustained / death_spiral /
  captured; identity cost and fee schedule move the boundary;
- **the adaptivity gap**: the best-response coalition derails the
  weakly-defended (mean) regime that the same-menu fixed attack cannot
  touch, and robust aggregation closes the gap — reported as the median
  adaptive/fixed final-loss ratio over matched cells (``loss_ratio``);
- **one-program speedup**: the fused sweep vs the replaced path — one
  rebuilt-and-recompiled engine per knob combo (``make_swarm`` per cell),
  measured on the smoke grid in both modes (target >= 10x).

CLI:  ``python benchmarks/bench_economy.py [--grid G] [--tiny] [--json F]``
``--tiny`` runs the 16-point ``no_off_economy_smoke`` grid (the CI smoke
job); the default grid is the full 144-lane ``no_off_economy``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import Row
from repro.core import economy
from repro.core.derailment import sweep
from repro.core.economy import EconomyConfig
from repro.core.scenarios import get_sweep_grid
from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.optim.optimizer import SGD

from benchmarks.bench_byzantine import _problem

#: filled by run() for the --json artifact
LAST_META: dict = {}

#: the grid the sequential lane-loop comparison always runs on — matched
#: against its own one-program sweep, small enough that the replaced path
#: (one compile per knob combo) stays under a CI minute
_SPEEDUP_GRID = "no_off_economy_smoke"


def _phase_rows(res) -> list:
    """Outcome counts per (regime, fixed|adaptive) half of the diagram."""
    rows: list[Row] = []
    for reg in res.grid.regimes:
        for adp in (False, True):
            cell = [r for r in res.econ_results
                    if r.regime == reg.name and r.adaptive == adp
                    and r.coalition_size > 0]
            if not cell:
                continue
            counts = {o: sum(r.outcome == o for r in cell)
                      for o in economy.OUTCOMES}
            hp = float(np.median([r.honest_payoff for r in cell]))
            rows.append((
                f"economy.{reg.name}.{'adaptive' if adp else 'fixed'}", 0.0,
                f"sustained={counts['sustained']} "
                f"death_spiral={counts['death_spiral']} "
                f"captured={counts['captured']} of {len(cell)} lanes "
                f"(median honest payoff {hp:+.2f})"))
    return rows


def _sequential_lane_loop(grid, loss_fn, params0, opt, data_fn, eval_fn):
    """The replaced path: every (regime × cost × fee × schedule × adaptive ×
    count × scale × seed) cell as its own ``make_swarm`` engine — rebuilt,
    recompiled, and run one lane at a time — plus the per-seed honest
    baselines the sweep shares."""
    n_runs = 0
    for seed in grid.seeds:                       # shared honest baselines
        base = make_swarm(
            loss_fn, params0, opt,
            [NodeSpec(f"h{i}") for i in range(grid.n_honest)],
            SwarmConfig(aggregator="mean", seed=seed,
                        economy=EconomyConfig(
                            identity_cost=grid.identity_costs[0],
                            budget=grid.econ_budget,
                            min_stake=grid.econ_min_stake,
                            fee_income=grid.fees[0],
                            reward_rate=grid.reward_schedules[0][0],
                            op_cost=grid.econ_op_cost,
                            jackpot=grid.reward_schedules[0][1],
                            honest_reserve=grid.econ_reserve)),
            data_fn)
        base.run(grid.rounds)
        float(eval_fn(base.params))
        n_runs += 1
    for reg in grid.regimes:
        for icost in grid.identity_costs:
            for fee in grid.fees:
                for sched in grid.reward_schedules:
                    for adp in grid.adaptive or (False,):
                        for count in grid.attacker_counts:
                            for scale in grid.scales:
                                for seed in grid.seeds:
                                    nodes = (
                                        [NodeSpec(f"h{i}")
                                         for i in range(grid.n_honest)]
                                        + [NodeSpec(f"adv{i}",
                                                    byzantine=grid.attack,
                                                    byzantine_scale=scale)
                                           for i in range(count)])
                                    cfg = SwarmConfig(
                                        aggregator=reg.aggregator,
                                        agg_kwargs=reg.agg_kwargs,
                                        verification=reg.verification,
                                        seed=seed,
                                        economy=EconomyConfig(
                                            identity_cost=icost,
                                            budget=grid.econ_budget,
                                            min_stake=grid.econ_min_stake,
                                            fee_income=fee,
                                            reward_rate=sched[0],
                                            op_cost=grid.econ_op_cost,
                                            jackpot=sched[1],
                                            honest_reserve=grid.econ_reserve,
                                            adaptive=adp))
                                    sw = make_swarm(loss_fn, params0, opt,
                                                    nodes, cfg, data_fn)
                                    sw.run(grid.rounds)
                                    float(eval_fn(sw.params))
                                    n_runs += 1
    return n_runs


def run(grid_name: str = "no_off_economy", tiny_only: bool = False) -> list:
    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    opt = SGD(lr=0.1, momentum=0.0)
    if tiny_only:
        grid_name = _SPEEDUP_GRID
    grid = get_sweep_grid(grid_name)

    # warm jax's one-time process machinery out of both measurements
    import jax
    import jax.numpy as jnp
    float(eval_fn(params0))
    jax.block_until_ready(jnp.stack([jnp.zeros(4, jnp.float32)] * 2))

    # the whole incentive phase diagram: one compiled program
    res = sweep(loss_fn, params0, opt, data_fn, eval_fn, grid)
    rows += _phase_rows(res)
    gap = res.economy_adaptive_gap()
    rows.append((
        "economy.adaptive_gap", 0.0,
        f"loss_ratio={gap['loss_ratio']:.1f}x adaptive/fixed median final "
        f"loss, non-sustained frac {gap['bad_frac_fixed']:.2f}->"
        f"{gap['bad_frac_adaptive']:.2f} over {gap['cells']} matched cells "
        f"(measurable gap: ratio > 1)"))
    rows.append((
        "economy.sweep.runs_per_s", 1e6 / res.runs_per_s,
        f"{res.runs_per_s:.1f} runs/s ({res.n_runs} runs incl baselines, "
        f"{len(res.econ_results)} grid points, {res.n_programs} programs, "
        f"{res.wall_s:.2f}s end-to-end)"))
    LAST_META.update(
        grid=grid_name, n_points=len(res.econ_results), n_runs=res.n_runs,
        n_programs=res.n_programs, sweep_wall_s=res.wall_s,
        sweep_runs_per_s=res.runs_per_s, adaptive_gap=gap,
        outcomes={o: sum(r.outcome == o for r in res.econ_results)
                  for o in economy.OUTCOMES})

    # the one-program speedup, measured on the smoke grid in both modes:
    # same cells, one compiled program vs one rebuilt engine per knob combo
    sgrid = get_sweep_grid(_SPEEDUP_GRID)
    sres = res if grid_name == _SPEEDUP_GRID else sweep(
        loss_fn, params0, opt, data_fn, eval_fn, sgrid)
    t0 = time.perf_counter()
    n_seq = _sequential_lane_loop(sgrid, loss_fn, params0, opt, data_fn,
                                  eval_fn)
    dt_seq = time.perf_counter() - t0
    speedup = dt_seq / sres.wall_s
    rows.append((
        "economy.sequential.runs_per_s", 1e6 * dt_seq / n_seq,
        f"{n_seq / dt_seq:.2f} runs/s ({n_seq} make_swarm engines incl "
        f"baselines on {_SPEEDUP_GRID}, {dt_seq:.2f}s)"))
    rows.append((
        "economy.sweep.speedup", 0.0,
        f"{speedup:.1f}x end-to-end vs the per-cell engine loop for "
        f"{len(sres.econ_results)} points (target >=10x)"))
    LAST_META.update(sequential_wall_s=dt_seq, sequential_runs=n_seq,
                     smoke_sweep_wall_s=sres.wall_s, speedup=speedup)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="no_off_economy")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: no_off_economy_smoke grid")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + sweep metadata as JSON")
    args = ap.parse_args()

    rows = run(grid_name=args.grid, tiny_only=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                               for n, us, d in rows],
                       "economy": LAST_META}, f, indent=2)
