"""Benchmark driver — one module per paper table/claim (DESIGN.md §0).
Prints ``name,us_per_call,derived`` CSV rows.

  §2   capacity          centralized vs volunteer vs incentivized watts/FLOPS
  §3.1 compression       wire ratios + loss impact + qsgd kernel
  §3.2 gossip            convergence vs spectral gap, traffic vs all-reduce
  §3.2 pipeline_scaling  SWARM square-cube: comm/compute shrinks with d_model
  §3.3 byzantine         attacks x aggregators (+ centered_clip kernel)
  §4.2 verification      stake/slash EV grid + measured catch rate
  §4.1 custody           coalition reductions + the extractability frontier
  §4.1 serving           scanned decode + continuous batching vs the loop
                         driver + the (load x churn x redundancy) sweep
  §5.5 derailment        no-off frontier + attack economics
  §4   economy           incentive phase diagram + the adaptivity gap
  §3   async             bounded-staleness rounds/s vs sync + straggler util
  §3.3 round_fused       fused Pallas round path vs per-op jnp, rounds/s
  (g)  roofline          per arch x shape terms from the dry-run artifacts
  (g)  campaign_scaling  mesh-sharded campaign weak scaling (lanes/s vs
                         the single-device engine, fake-device host mesh)
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import emit

MODULES = [
    "bench_capacity",
    "bench_compression",
    "bench_gossip",
    "bench_pipeline_scaling",
    "bench_byzantine",
    "bench_verification",
    "bench_custody",
    "bench_serving",
    "bench_derailment",
    "bench_economy",
    "bench_async",
    "bench_round_fused",
    "bench_roofline",
    "bench_campaign_scaling",
]


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    selected = argv or MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        mod_name = name if name.startswith("bench_") else f"bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            emit(mod.run())
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
