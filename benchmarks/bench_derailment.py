"""Paper §5.5: the No-Off problem, quantified.  Sweeps the attacker
fraction across aggregation/verification regimes and prices the derailment
attack (the only digital emergency brake the paper identifies)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.derailment import attack_cost, simulate_derailment
from repro.core.scenarios import get_scenario
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from benchmarks.bench_byzantine import _problem


def run() -> list:
    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    opt = SGD(lr=0.1, momentum=0.0)

    n_honest = 10
    for agg in ["mean", "centered_clip"]:
        for n_attack in [1, 3, 6, 12]:
            res = simulate_derailment(
                loss_fn, params0, opt, data_fn, eval_fn,
                n_honest=n_honest, n_attack=n_attack, rounds=25,
                aggregator=agg, attack="inner_product", scale=50.0)
            rows.append((
                f"nooff.{agg}.frac{res.attacker_fraction:.2f}", 0.0,
                f"derailed={res.derailed} "
                f"final/base={res.final_loss / max(res.baseline_loss, 1e-9):.1f}"))

    # with near-perfect verification the off-switch stops working (§5.5)
    v = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3)
    res = simulate_derailment(
        loss_fn, params0, opt, data_fn, eval_fn,
        n_honest=n_honest, n_attack=6, rounds=25,
        aggregator="mean", verification=v, attack="inner_product")
    rows.append(("nooff.verified.frac0.38", 0.0,
                 f"derailed={res.derailed} slashed={res.attackers_slashed}/6 "
                 "(derailment neutralized => only physical off remains)"))

    # the registry's worst-case regime: 40% collusion vs CC + audits (§5.5)
    scn = get_scenario("derailment_stress")
    swarm = scn.build_swarm(loss_fn, params0, opt, data_fn, n_nodes=15)
    losses = swarm.run(25, eval_fn=eval_fn, eval_every=24)
    rows.append(("nooff.scenario.derailment_stress", 0.0,
                 f"final_loss={losses[-1]:.3f} "
                 f"slashed={len(swarm.slashed)}/{sum(1 for n in swarm.nodes if n.byzantine)}"))

    # attack economics
    for n_attack, ver in [(6, None), (6, v)]:
        cost = attack_cost(n_attack, rounds=25, compute_cost_per_round=1.0,
                           verification=ver)
        rows.append((
            f"nooff.attack_cost.{'verified' if ver else 'unverified'}", 0.0,
            f"{cost:.0f} units (compute{'+stakes' if ver else ' only'})"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
