"""Paper §5.5: the No-Off problem, quantified — as a *phase diagram*.

Runs a ``scenarios.SweepGrid`` (attacker fractions × seeds per regime)
through ``derailment.sweep``: the campaign engine compiles the whole grid
into ONE device program (``lax.scan`` over rounds, ``vmap`` over runs,
regimes fused by per-lane aggregator id, honest baselines riding along as
count=0 lanes), then times the same grid as sequential
``simulate_derailment`` calls and reports both as **runs/s** next to the
engine-level rounds/s in bench_byzantine.  Also prices the attack
(compute + slashed stakes).

CLI:  ``python benchmarks/bench_derailment.py [--grid G] [--tiny] [--json F]``
``--tiny`` runs the 4-point ``no_off_smoke`` grid with no sequential
comparison (the CI smoke job); ``--json`` dumps rows + sweep metadata.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import Row
from repro.core.derailment import attack_cost, simulate_derailment, sweep
from repro.core.scenarios import get_scenario, get_sweep_grid
from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD

from benchmarks.bench_byzantine import _problem

#: filled by run() for the --json artifact
LAST_SWEEP_META: dict = {}


def _phase_rows(res) -> list:
    rows: list[Row] = []
    for reg in res.grid.regimes:
        fracs = sorted({r.attacker_fraction for r in res.results
                        if r.regime == reg.name})
        for frac in fracs:
            cell = [r for r in res.results if r.regime == reg.name
                    and abs(r.attacker_fraction - frac) < 1e-9]
            der = sum(r.derailed for r in cell)
            slashed = sum(r.attackers_slashed for r in cell)
            n_att = sum(r.n_attackers for r in cell)
            ratios = sorted(r.final_loss / max(r.baseline_loss, 1e-9)
                            for r in cell)
            rows.append((
                f"nooff.{reg.name}.frac{frac:.2f}", 0.0,
                f"derailed={der}/{len(cell)} slashed={slashed}/{n_att} "
                f"median final/base={ratios[len(ratios) // 2]:.1f}"))
    return rows


def run(grid_name: str = "no_off_quick", compare_sequential: bool = True) -> list:
    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    opt = SGD(lr=0.1, momentum=0.0)
    grid = get_sweep_grid(grid_name)
    # warm jax's one-time process machinery (eager dispatch, stack/transfer
    # paths) out of both measurements — the sequential loop runs second and
    # would otherwise inherit this for free
    import jax
    import jax.numpy as jnp
    float(eval_fn(params0))
    jax.block_until_ready(jnp.stack([jnp.zeros(4, jnp.float32)] * 2))

    # the whole phase diagram: one compiled program
    res = sweep(loss_fn, params0, opt, data_fn, eval_fn, grid)
    rows += _phase_rows(res)
    n_points = len(res.results)
    rows.append((
        "nooff.sweep.runs_per_s", 1e6 / res.runs_per_s,
        f"{res.runs_per_s:.1f} runs/s ({res.n_runs} runs incl baselines, "
        f"{n_points} grid points, {res.n_programs} programs, "
        f"{res.wall_s:.2f}s end-to-end)"))
    LAST_SWEEP_META.update(
        grid=grid_name, n_points=n_points, n_runs=res.n_runs,
        n_programs=res.n_programs, sweep_wall_s=res.wall_s,
        sweep_runs_per_s=res.runs_per_s)

    if compare_sequential:
        # the same grid as one simulate_derailment call per point, honest
        # baseline trained once per seed and passed in (it used to be
        # recomputed inside every call — 9 redundant training runs)
        t0 = time.perf_counter()
        baselines = {}
        for seed in grid.seeds:
            base = make_swarm(loss_fn, params0, opt,
                              [NodeSpec(f"h{i}") for i in range(grid.n_honest)],
                              SwarmConfig(aggregator="mean", seed=seed), data_fn)
            baselines[seed] = base.run(grid.rounds, eval_fn=eval_fn,
                                       eval_every=grid.rounds)[-1]
        n_seq = 0
        for reg in grid.regimes:
            for count in grid.attacker_counts:
                for scale in grid.scales:
                    for seed in grid.seeds:
                        simulate_derailment(
                            loss_fn, params0, opt, data_fn, eval_fn,
                            n_honest=grid.n_honest, n_attack=count,
                            rounds=grid.rounds, aggregator=reg.aggregator,
                            verification=reg.verification, attack=grid.attack,
                            scale=scale, seed=seed,
                            baseline_loss=baselines[seed])
                        n_seq += 1
        dt_seq = time.perf_counter() - t0
        seq_rps = n_seq / dt_seq
        speedup = dt_seq / res.wall_s
        rows.append(("nooff.sequential.runs_per_s", 1e6 / seq_rps,
                     f"{seq_rps:.1f} runs/s ({n_seq} simulate_derailment "
                     f"calls + {len(grid.seeds)} shared baselines, "
                     f"{dt_seq:.2f}s)"))
        rows.append(("nooff.sweep.speedup", 0.0,
                     f"{speedup:.1f}x end-to-end vs sequential for "
                     f"{n_points} points (target >=10x)"))
        LAST_SWEEP_META.update(sequential_wall_s=dt_seq,
                               sequential_runs_per_s=seq_rps,
                               speedup=speedup)

        # near-perfect verification neutralizes the off-switch (§5.5) —
        # the single-point path, reusing the shared baseline
        v = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3)
        r = simulate_derailment(
            loss_fn, params0, opt, data_fn, eval_fn,
            n_honest=grid.n_honest, n_attack=6, rounds=grid.rounds,
            aggregator="mean", verification=v, attack=grid.attack,
            baseline_loss=baselines[grid.seeds[0]])
        rows.append((f"nooff.verified.frac{r.attacker_fraction:.2f}", 0.0,
                     f"derailed={r.derailed} slashed={r.attackers_slashed}/6 "
                     "(derailment neutralized => only physical off remains)"))

        # the registry's worst-case regime: 40% collusion vs CC + audits
        scn = get_scenario("derailment_stress")
        swarm = scn.build_swarm(loss_fn, params0, opt, data_fn, n_nodes=15)
        losses = swarm.run(grid.rounds, eval_fn=eval_fn,
                           eval_every=grid.rounds - 1)
        rows.append(("nooff.scenario.derailment_stress", 0.0,
                     f"final_loss={losses[-1]:.3f} "
                     f"slashed={len(swarm.slashed)}/"
                     f"{sum(1 for n in swarm.nodes if n.byzantine)}"))

        # attack economics
        for ver in [None, v]:
            cost = attack_cost(6, rounds=grid.rounds,
                               compute_cost_per_round=1.0, verification=ver)
            rows.append((
                f"nooff.attack_cost.{'verified' if ver else 'unverified'}", 0.0,
                f"{cost:.0f} units (compute{'+stakes' if ver else ' only'})"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="no_off_quick")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: no_off_smoke grid, sweep only")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + sweep metadata as JSON")
    args = ap.parse_args()

    grid_name = "no_off_smoke" if args.tiny else args.grid
    rows = run(grid_name=grid_name, compare_sequential=not args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                               for n, us, d in rows],
                       "sweep": LAST_SWEEP_META}, f, indent=2)
