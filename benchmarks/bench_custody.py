"""Paper §4.1: unextractability, quantified — as an *extractability frontier*.

Two layers:

1. raw custody analysis — the vectorized (N, S) coalition reductions
   evaluated over a stacked batch of coalitions in one vmapped call, timed
   against the per-coalition python-set loop, plus greedy-vs-exact minimum
   extraction coalitions;
2. the **custody axis of the campaign engine** — a (redundancy × coalition
   fraction × churn seed) sweep (``custody_frontier`` grid) through
   ``derailment.sweep``: every lane traces the live coverage frontier and
   runs the reconstruct-attack eval, all in ONE compiled program, reported
   as runs/s next to ``bench_derailment``/``bench_gossip``.

CLI:  ``python benchmarks/bench_custody.py [--tiny] [--json F]``
``--tiny`` runs the 4-point ``custody_smoke`` grid and small coalition
batches (the CI smoke job); ``--json`` dumps rows + sweep metadata.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core import unextractable as unext

#: filled by run() for the --json artifact
LAST_SWEEP_META: dict = {}


def _coalition_rows(tiny: bool) -> list:
    """The vectorized custody layer: one vmapped reduction over a stacked
    batch of coalitions vs the per-coalition python-set loop."""
    rows: list[Row] = []
    n, shards, batch = (16, 32, 256) if tiny else (64, 128, 4096)
    nodes = [f"n{i}" for i in range(n)]
    c = unext.ShardCustody.assign(nodes, shards, redundancy=2,
                                  max_fraction=0.4)
    rng = np.random.default_rng(0)
    masks_np = rng.random((batch, n)) < 0.3
    masks = jnp.asarray(masks_np)

    batched = jax.jit(lambda m: unext.coverage_frac(c.holds, m))
    us_mat = timeit(batched, masks)
    node_shards = c.node_shards          # build the dict view once

    def loop():
        # pure host-side baseline: numpy masks + python set unions (no jnp
        # slicing/transfers in the loop, so the ratio measures the math)
        out = []
        for k in range(batch):
            covered = set()
            for i in np.flatnonzero(masks_np[k]):
                covered |= node_shards[nodes[i]]
            out.append(len(covered) / shards)
        return out

    us_loop = timeit(loop, repeats=1)
    rows.append((
        f"custody.coverage.batch{batch}", us_mat,
        f"{batch} coalitions/{n} nodes/{shards} shards in one vmapped "
        f"reduction vs python set loop {us_loop:.0f}us "
        f"({us_loop / max(us_mat, 1e-9):.1f}x host-side; the structural "
        "win is tracing into the campaign program)"))

    greedy = c.min_extraction_coalition()
    small = unext.ShardCustody.assign(nodes[:10], 16, redundancy=2,
                                      max_fraction=0.4)
    rows.append((
        "custody.min_coalition", 0.0,
        f"greedy={greedy} of {n} (upper bound); exact@10 nodes: "
        f"{small.min_extraction_coalition(exact=True)} vs greedy "
        f"{small.min_extraction_coalition()}"))
    return rows


def _frontier_rows(grid_name: str) -> list:
    """The custody axis end-to-end: one (redundancy × coalition × seed)
    sweep with the reconstruct-attack eval."""
    from benchmarks.bench_byzantine import _problem
    from repro.core.derailment import sweep
    from repro.core.scenarios import get_sweep_grid
    from repro.optim.optimizer import SGD

    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    grid = get_sweep_grid(grid_name)
    res = sweep(loss_fn, params0, SGD(lr=0.1, momentum=0.0), data_fn,
                eval_fn, grid)

    for red in grid.redundancies:
        for frac in grid.coalition_fractions:
            cell = [r for r in res.results
                    if r.redundancy == red
                    and abs(r.coalition_fraction - frac) < 1e-9]
            regimes = {r.extractability for r in cell}
            cov = sum(r.coalition_coverage for r in cell) / len(cell)
            ratio = sorted(r.extracted_loss / max(r.final_loss, 1e-9)
                           for r in cell)[len(cell) // 2]
            rows.append((
                f"custody.frontier.r{red}.coal{frac:.2f}", 0.0,
                f"{'/'.join(sorted(regimes))} cov={cov:.2f} "
                f"median extracted/honest={ratio:.1f}"))
    rows.append((
        "custody.sweep.runs_per_s", 1e6 / res.runs_per_s,
        f"{res.runs_per_s:.1f} runs/s ({res.n_runs} runs incl baselines, "
        f"{len(res.results)} grid points, {res.n_programs} program, "
        f"{res.wall_s:.2f}s end-to-end, reconstruct-attack eval in-program)"))
    LAST_SWEEP_META.update(
        grid=grid_name, n_points=len(res.results), n_runs=res.n_runs,
        n_programs=res.n_programs, sweep_wall_s=res.wall_s,
        sweep_runs_per_s=res.runs_per_s,
        redundancies=list(grid.redundancies),
        coalition_fractions=list(grid.coalition_fractions),
        extractability_table=res.extractability_table())
    return rows


def run(tiny: bool = False) -> list:
    rows = _coalition_rows(tiny)
    rows += _frontier_rows("custody_smoke" if tiny else "custody_frontier")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small coalition batches + custody_smoke")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + sweep metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "sweep": LAST_SWEEP_META}, f, indent=2)
