"""Shared benchmark plumbing: each bench module exposes ``run() -> rows``
where a row is (name, us_per_call, derived) — printed as CSV by run.py."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (CPU; jit-warmed)."""
    for _ in range(warmup):
        out = fn(*args)
    _block(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
