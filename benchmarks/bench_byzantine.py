"""Paper §3.3 table: attack types × aggregators.  Reproduces the claims
that (a) linear aggregation has breakdown point 0 [6], (b) attacks defeat
naive defenses [3, 57, 87], (c) CenteredClip holds within its breakdown
point [27, 40].  Runs real short training on a convex problem + an LM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core.derailment import simulate_derailment
from repro.optim.optimizer import SGD


def _problem():
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    target = jax.random.normal(k1, (16,))

    def loss_fn(params, batch):
        return jnp.mean(jnp.square((batch["x"] @ (params["w"] - target))))

    def data_fn(node_idx, rnd):
        k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
        return {"x": jax.random.normal(k, (16, 16))}

    return loss_fn, {"w": jnp.zeros((16,))}, data_fn


def run() -> list:
    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    opt = SGD(lr=0.1, momentum=0.0)

    for attack in ["sign_flip", "inner_product", "noise"]:
        for agg in ["mean", "krum", "median", "centered_clip"]:
            res = simulate_derailment(
                loss_fn, params0, opt, data_fn, eval_fn,
                n_honest=8, n_attack=2, rounds=25,
                aggregator=agg, attack=attack, scale=50.0)
            rows.append((
                f"byzantine.{attack}.{agg}", 0.0,
                f"derailed={res.derailed} "
                f"final/base={res.final_loss / max(res.baseline_loss, 1e-9):.1f}"))

    # kernel vs oracle timing for the aggregation hot loop
    from repro.core.aggregation import centered_clip as cc_ref
    from repro.kernels.centered_clip.ops import centered_clip as cc_kernel
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 1 << 14))
    us_k = timeit(lambda: cc_kernel(x, clip_tau=1.0, iters=3, interpret=True))
    us_r = timeit(lambda: jax.jit(
        lambda u: cc_ref(u, clip_tau=1.0, iters=3))(x))
    rows.append(("byzantine.cc_kernel_interpret", us_k, "16x16k"))
    rows.append(("byzantine.cc_oracle_jnp", us_r, "16x16k"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
