"""Paper §3.3 table: attack types × aggregators.  Reproduces the claims
that (a) linear aggregation has breakdown point 0 [6], (b) attacks defeat
naive defenses [3, 57, 87], (c) CenteredClip holds within its breakdown
point [27, 40].  Runs real short training on a convex problem, drives the
named scenarios from core.scenarios, and times the batched swarm engine
against the sequential reference (rounds/sec at 16+ nodes)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core.derailment import simulate_derailment
from repro.core.scenarios import batched_data_fn_for, get_scenario
from repro.optim.optimizer import SGD


def _problem(n_params: int = 16):
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    target = jax.random.normal(k1, (n_params,))

    def loss_fn(params, batch):
        return jnp.mean(jnp.square((batch["x"] @ (params["w"] - target))))

    def data_fn(node_idx, rnd):
        k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
        return {"x": jax.random.normal(k, (16, n_params))}

    return loss_fn, {"w": jnp.zeros((n_params,))}, data_fn


def _engine_rounds_per_sec(scenario_name: str, n_nodes: int, engine: str,
                           rounds: int = 20) -> float:
    loss_fn, params0, data_fn = _problem(64)
    scn = get_scenario(scenario_name)
    bdf = batched_data_fn_for(data_fn, n_nodes) if engine == "batched" else None
    swarm = scn.build_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                            data_fn, n_nodes=n_nodes, engine=engine,
                            batched_data_fn=bdf)
    swarm.step(0)                                   # warm the jit caches
    t0 = time.perf_counter()
    for r in range(1, rounds + 1):
        swarm.step(r)
    return rounds / (time.perf_counter() - t0)


def run() -> list:
    rows: list[Row] = []
    loss_fn, params0, data_fn = _problem()
    eval_fn = lambda p: loss_fn(p, data_fn(0, 10_000))
    opt = SGD(lr=0.1, momentum=0.0)

    # attack x aggregator grid (batched engine throughout)
    for attack in ["sign_flip", "inner_product", "noise"]:
        for agg in ["mean", "krum", "median", "centered_clip"]:
            res = simulate_derailment(
                loss_fn, params0, opt, data_fn, eval_fn,
                n_honest=8, n_attack=2, rounds=25,
                aggregator=agg, attack=attack, scale=50.0)
            rows.append((
                f"byzantine.{attack}.{agg}", 0.0,
                f"derailed={res.derailed} "
                f"final/base={res.final_loss / max(res.baseline_loss, 1e-9):.1f}"))

    # named scenarios: short convergence check per regime
    for name in ["honest_baseline", "sign_flip_minority",
                 "inner_product_collusion", "compressed_wire"]:
        scn = get_scenario(name)
        swarm = scn.build_swarm(loss_fn, params0, opt, data_fn, n_nodes=12)
        losses = swarm.run(25, eval_fn=eval_fn, eval_every=24)
        rows.append((f"byzantine.scenario.{name}", 0.0,
                     f"final_loss={losses[-1]:.4f} "
                     f"slashed={len(swarm.slashed)}"))

    # engine throughput: batched vmap/jit round vs sequential python loop
    for n in [16, 32]:
        rps_seq = _engine_rounds_per_sec("sign_flip_minority", n, "sequential")
        rps_bat = _engine_rounds_per_sec("sign_flip_minority", n, "batched")
        rows.append((f"byzantine.engine.n{n}.sequential", 1e6 / rps_seq,
                     f"{rps_seq:.1f} rounds/s"))
        rows.append((f"byzantine.engine.n{n}.batched", 1e6 / rps_bat,
                     f"{rps_bat:.1f} rounds/s (speedup {rps_bat / rps_seq:.1f}x)"))

    # kernel vs oracle timing for the aggregation hot loop
    from repro.core.aggregation import centered_clip as cc_ref
    from repro.kernels.centered_clip.ops import centered_clip as cc_kernel
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 1 << 14))
    us_k = timeit(lambda: cc_kernel(x, clip_tau=1.0, iters=3, interpret=True))
    us_r = timeit(lambda: jax.jit(
        lambda u: cc_ref(u, clip_tau=1.0, iters=3))(x))
    rows.append(("byzantine.cc_kernel_interpret", us_k, "16x16k"))
    rows.append(("byzantine.cc_oracle_jnp", us_r, "16x16k"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
