"""Fused Pallas round path (kernels/): rounds/s, fused vs unfused.

Times the identical swarm round — qsgd wire + masked centered_clip over an
(N, D) stack — built with ``make_round_fn(fused=False)`` (the historical
per-op jnp path) and ``fused=True`` (payload-native decode-accumulate +
network-sort median warm start + flash-style CC, conformance-pinned
bit-equal by tests/test_kernel_conformance.py).  Two settings:

  tiny    N=8,  D=8 192       (CI smoke — below FUSED_MIN_BYTES, forced on)
  large   N=16, D=1 048 576   (64 MiB stack — the acceptance setting:
                               fused must be >= 2x unfused rounds/s)

The model/data term is a thin quadratic (batch (8, 64) @ w (64, D/64)) so
the round is dominated by the wire + aggregation phases the kernels own.
Alongside wall time, the compiled HLO is priced with the trip-count-aware
cost model (launch/hlo_cost.py) and held against the TPU v5e roofline
peaks (launch/roofline.py): bytes/round vs the raw stack, achieved host
bytes/s, and what the same program would be bound by at peak.

CLI:  ``python benchmarks/bench_round_fused.py [--tiny] [--json F]``
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core.swarm import (_FAR, LaneParams, init_state, make_round_fn,
                              scan_rounds)
from repro.launch import roofline
from repro.launch.hlo_cost import analyze_hlo
from repro.optim.optimizer import SGD

#: filled by run() for the --json artifact
LAST_META: dict = {}

_WIRE = {"levels": 16, "bucket_size": 1024}


def _problem(n: int, d_cols: int):
    """loss = ||x @ w − x @ t||², w (64, d_cols) → D = 64·d_cols params with
    an O(1)-sized data stream (x is (8, 64) per node per round)."""
    target = jax.random.normal(jax.random.PRNGKey(0), (64, d_cols)) * 0.1

    def loss_fn(params, batch):
        return jnp.mean(jnp.square(batch["x"] @ params["w"]
                                   - batch["x"] @ target))

    def batch_fn(rnd):
        k = jax.random.fold_in(jax.random.PRNGKey(7), rnd)
        return {"x": jax.random.normal(k, (n, 8, 64))}

    return loss_fn, {"w": jnp.zeros((64, d_cols))}, batch_fn


def _lane(n: int) -> LaneParams:
    return LaneParams(
        codes=jnp.zeros((n,), jnp.int32), scales=jnp.ones((n,)),
        speeds=jnp.ones((n,)), joins=jnp.zeros((n,), jnp.int32),
        leaves=jnp.full((n,), _FAR, jnp.int32),
        base_key=jax.random.PRNGKey(11), p_check=jnp.asarray(0.0),
        tolerance=jnp.asarray(1e-3), numeric_noise=jnp.asarray(0.0),
        agg_id=jnp.asarray(0, jnp.int32), agg_kwargs={})


def _compile(n: int, d_cols: int, rounds: int, fused: bool):
    loss_fn, params0, batch_fn = _problem(n, d_cols)
    opt = SGD(lr=0.05, momentum=0.0)
    rf = make_round_fn(loss_fn, opt, params0, n, aggregator="centered_clip",
                       compression_kind="qsgd", compression_kwargs=_WIRE,
                       fused=fused)

    def prog(lane):
        return scan_rounds(rf, lane, init_state(params0, opt, n),
                           rounds, batch_fn)

    compiled = jax.jit(prog).lower(_lane(n)).compile()
    return compiled, rf


def _time_per_round(compiled, lane, rounds: int, repeats: int) -> float:
    out = compiled(lane)                      # warm (allocs, transfers)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(lane))
        best = min(best, time.perf_counter() - t0)
    return best / rounds


def _bench_setting(name: str, n: int, d_cols: int, rounds: int,
                   repeats: int) -> list:
    rows: list[Row] = []
    lane = _lane(n)
    d = 64 * d_cols
    per_round = {}
    hlo_by_mode = {}
    for fused in (False, True):
        compiled, rf = _compile(n, d_cols, rounds, fused)
        sec = _time_per_round(compiled, lane, rounds, repeats)
        per_round[fused] = sec
        hlo_by_mode[fused] = analyze_hlo(compiled.as_text(), total_devices=1)
        mode = "fused" if fused else "unfused"
        rows.append((
            f"round_fused.{name}.{mode}", sec * 1e6,
            f"{1.0 / sec:.2f} rounds/s (N={n} D={d} "
            f"stack={rf.stack_bytes / 2**20:.2f}MiB qsgd+centered_clip)"))

    speedup = per_round[False] / per_round[True]
    target = " (target >=2x)" if name == "large" else ""
    rows.append((f"round_fused.{name}.speedup", 0.0,
                 f"{speedup:.2f}x fused over unfused rounds/s{target}"))

    # model-priced traffic for the fused program, against v5e peaks
    cost = hlo_by_mode[True]
    stack = n * d * 4
    bpr = cost.bytes_accessed / rounds
    fpr = cost.flops / rounds
    achieved = bpr / per_round[True]
    r = roofline.Roofline(flops_per_device=fpr, bytes_per_device=bpr,
                          wire_bytes_per_device=0.0,
                          model_flops_global=fpr, num_chips=1)
    rows.append((
        f"round_fused.{name}.fused.traffic", 0.0,
        f"hlo={bpr / 2**20:.1f}MiB/round ({bpr / max(stack, 1):.1f}x stack) "
        f"flops={fpr:.2e} host={achieved / 1e9:.2f}GB/s="
        f"{achieved / roofline.HBM_BW:.1%} of v5e HBM; at peak "
        f"{r.dominant}-bound {roofline.fmt_seconds(r.bound_s).strip()}/round"))

    LAST_META[name] = {
        "n": n, "d": d, "rounds": rounds,
        "unfused_s_per_round": per_round[False],
        "fused_s_per_round": per_round[True],
        "speedup": speedup,
        "fused_hlo_bytes_per_round": bpr,
        "fused_hlo_flops_per_round": fpr,
        "unfused_hlo_bytes_per_round":
            hlo_by_mode[False].bytes_accessed / rounds,
        "achieved_host_bytes_per_s": achieved,
    }
    return rows


def run(tiny_only: bool = False) -> list:
    rows = _bench_setting("tiny", n=8, d_cols=128, rounds=3, repeats=3)
    if not tiny_only:
        rows += _bench_setting("large", n=16, d_cols=16384, rounds=2,
                               repeats=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny setting only")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny_only=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                               for n, us, d in rows],
                       "settings": LAST_META}, f, indent=2)
