"""Paper §4.2: game-theoretic compute verification.  The stake/audit grid
(cheating EV must be negative), measured catch rates, and the audit
overhead relative to the gradient computation it checks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

import dataclasses

from benchmarks.common import Row, timeit
from repro.core.scenarios import get_scenario
from repro.core.swarm import make_swarm
from repro.core.verification import (
    VerificationConfig,
    cheating_irrational,
    expected_cheat_value,
    min_p_check,
)
from repro.optim.optimizer import SGD

from benchmarks.bench_byzantine import _problem


def run() -> list:
    rows: list[Row] = []

    # EV grid (the paper's inequality p_check·stake > gain)
    gain = 1.0
    for p in [0.01, 0.1, 0.5]:
        for stake in [1.0, 10.0, 100.0]:
            cfg = VerificationConfig(p_check=p, stake=stake)
            ev = expected_cheat_value(gain, cfg)
            rows.append((f"verify.ev.p{p}_s{stake:g}", 0.0,
                         f"EV={ev:+.2f} irrational={cheating_irrational(gain, cfg)}"))
    rows.append(("verify.min_p_check_gain1_stake10", 0.0,
                 f"{min_p_check(1.0, 10.0):.2f}"))

    # measured catch rate over a real run (audit_heavy scenario: 25%
    # zero-gradient freeloaders, batched engine, swept over p_check)
    loss_fn, params0, data_fn = _problem()
    scn = get_scenario("audit_heavy")
    for p_check in [0.2, 0.5]:
        nodes, cfg = scn.build(n_nodes=8)
        cfg = dataclasses.replace(
            cfg, verification=dataclasses.replace(cfg.verification,
                                                  p_check=p_check))
        swarm = make_swarm(loss_fn, params0, SGD(lr=0.1, momentum=0.0),
                           nodes, cfg, data_fn)
        rounds = 20
        swarm.run(rounds)
        n_cheat = sum(1 for n in nodes if n.byzantine)
        caught = len([s for s in swarm.slashed if s.startswith("adv")])
        rows.append((f"verify.catch_rate.p{p_check}", 0.0,
                     f"{caught}/{n_cheat} cheaters slashed in <= {rounds} rounds; "
                     f"stake burned={swarm.ledger.burned_stake:g}"))

    # audit overhead: one recompute per audited update
    x = {"x": jax.random.normal(jax.random.PRNGKey(0), (64, 16))}
    grad = jax.jit(jax.grad(lambda p: loss_fn(p, x)))
    us_grad = timeit(grad, {"w": jnp.zeros((16,))})
    rows.append(("verify.audit_overhead", us_grad,
                 "1 recompute per audit => overhead = p_check x grad cost"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
