"""Paper §4.1 meets §5: the protocol serving engine, quantified.

Three layers:

1. **scanned greedy decoding** — the jitted scanned decoder
   (``serving.greedy_decode``) against the replaced per-token python loop
   (``serving.greedy_decode_loop``), same batch, bit-identical tokens;
2. **continuous batching end-to-end** — the headline row: the slot-pool
   engine serving a mixed-length/mixed-budget request queue vs the replaced
   loop driver serving the same queue in padded fixed batches (its only
   mode — every batch runs to its longest prompt AND largest decode budget,
   the head-of-line blocking continuous batching exists to remove).  Both
   report delivered tokens/s; the loop baseline is steady-state (its jitted
   step is cache-shared, so the ratio contains no tracing time);
3. **the serving campaign** — a (load × churn × redundancy) availability
   sweep (``scenarios.ServingGrid`` through ``serving.sweep``) compiled to
   ONE program, reported as runs/s + the served/degraded/halted table.

CLI:  ``python benchmarks/bench_serving.py [--tiny] [--json F]``
``--tiny`` uses the micro LM and the 8-lane ``serving_smoke`` grid (the CI
smoke job); ``--json`` dumps rows + sweep metadata incl. the availability
table.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import Row

#: filled by run() for the --json artifact
LAST_SWEEP_META: dict = {}


def _model(tiny: bool):
    from repro.configs import get_config
    from repro.models.model import build_model
    if tiny:
        cfg = get_config("protocol-125m").reduced(
            num_layers=1, d_model=16, num_heads=2, head_dim=8, d_ff=32,
            vocab_size=32)
    else:
        cfg = get_config("protocol-125m").reduced(
            num_layers=2, d_model=64, num_heads=4, head_dim=16, d_ff=256,
            vocab_size=256)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _median(fn, repeats: int = 5) -> float:
    xs = [fn() for _ in range(repeats)]
    return sorted(xs)[len(xs) // 2]


def _greedy_rows(model, params, batch: int, max_new: int) -> list:
    """Scanned decoder vs the replaced python loop, same batch."""
    from repro.core import serving
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 8), 0,
                                 model.cfg.vocab_size)
    g_scan, _ = serving.greedy_decode(model, params, prompts, max_new)
    g_loop, _ = serving.greedy_decode_loop(model, params, prompts, max_new)
    assert np.array_equal(np.asarray(g_scan), np.asarray(g_loop)), \
        "scanned decoder diverged from the loop oracle"
    scan = _median(lambda: serving.greedy_decode(
        model, params, prompts, max_new)[1].tok_per_s)
    loop = _median(lambda: serving.greedy_decode_loop(
        model, params, prompts, max_new)[1].tok_per_s)
    return [(
        f"serving.greedy.batch{batch}", 1e6 / scan,
        f"scanned {scan:.0f} tok/s vs python loop {loop:.0f} tok/s "
        f"({scan / loop:.1f}x, bit-identical tokens, batch {batch})")]


def _engine_rows(model, params, *, batch: int, n_requests: int) -> list:
    """The headline comparison: continuous batching vs the replaced driver
    on a mixed queue (skewed decode budgets: the loop driver pads every
    batch to its longest request; the engine retires slots early)."""
    from repro.core import serving
    p_max, budget_max, budget_typ = 12, 24, 6
    rng = np.random.default_rng(0)
    plens = rng.integers(4, p_max + 1, n_requests).astype(np.int32)
    budgets = np.where(np.arange(n_requests) % batch == 0,
                       budget_max, budget_typ).astype(np.int32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (n_requests, p_max), 0,
                                 model.cfg.vocab_size)
    tokens = int(budgets.sum())

    def loop_driver():
        for b0 in range(0, n_requests, batch):
            sl = slice(b0, b0 + batch)
            serving.greedy_decode_loop(
                model, params, prompts[sl, :int(plens[sl].max())],
                int(budgets[sl].max()))

    lane_kw = dict(n_requests=n_requests, prompt_lens=plens,
                   max_new=budgets, n_nodes=8, balances=[float(tokens)],
                   fee=1.0, load=float(n_requests))
    # size the horizon from a generous probe run (capacity planning), then
    # measure at the snug horizon — the engine scan always runs all steps
    probe_cfg = serving.ServingConfig(
        slots=batch, max_new=budget_max,
        steps=2 * n_requests * (p_max + budget_max) // batch)
    probe = serving.ServingEngine(model, probe_cfg, prompts)
    pres = probe.run(params, serving.build_lane(steps=probe_cfg.steps,
                                                **lane_kw))
    assert pres.done.all()
    steps = int(np.flatnonzero(pres.new_tokens)[-1]) + 1
    cfg = serving.ServingConfig(slots=batch, max_new=budget_max, steps=steps)
    engine = serving.ServingEngine(model, cfg, prompts)
    lane = serving.build_lane(steps=steps, **lane_kw)

    loop_driver()                                        # warm both
    assert engine.run(params, lane).done.all()

    def timed_loop():
        t0 = time.perf_counter()
        loop_driver()
        return time.perf_counter() - t0

    t_loop = _median(timed_loop)
    t_eng = _median(lambda: engine.run(params, lane).wall_s)
    return [(
        f"serving.engine.batch{batch}", 1e6 * t_eng / tokens,
        f"{tokens / t_eng:.0f} tok/s continuous batching vs "
        f"{tokens / t_loop:.0f} tok/s loop driver = "
        f"{t_loop / t_eng:.1f}x ({n_requests} mixed requests, "
        f"{batch} slots, engine horizon {steps} steps)")]


def _sweep_rows(model, params, grid_name: str) -> list:
    """The serving campaign: one (load × churn × redundancy) program."""
    from repro.core import serving
    from repro.core.scenarios import get_serving_grid

    grid = get_serving_grid(grid_name)
    res = serving.sweep(model, params, grid)
    rows: list[Row] = []
    for red in grid.redundancies:
        for churn in grid.churn_rates:
            cell = [c for c in res.cells
                    if c.redundancy == red and c.churn_rate == churn]
            regimes = sorted({c.regime for c in cell})
            avail = sum(c.availability for c in cell) / len(cell)
            rows.append((
                f"serving.sweep.r{red}.churn{churn:.2f}", 0.0,
                f"{'/'.join(regimes)} avail={avail:.2f} over "
                f"{len(cell)} lanes"))
    rows.append((
        "serving.sweep.runs_per_s", 1e6 / res.runs_per_s,
        f"{res.runs_per_s:.1f} lanes/s ({res.n_runs} lanes, "
        f"{res.n_programs} program, {res.wall_s:.2f}s end-to-end, "
        f"{res.tok_per_s:.0f} tok/s aggregate)"))
    LAST_SWEEP_META.update(
        grid=grid_name, n_runs=res.n_runs, n_programs=res.n_programs,
        sweep_wall_s=res.wall_s, sweep_runs_per_s=res.runs_per_s,
        loads=list(grid.loads), churn_rates=list(grid.churn_rates),
        redundancies=list(grid.redundancies),
        availability_table=res.availability_table())
    return rows


def run(tiny: bool = False) -> list:
    model, params = _model(tiny)
    rows = _greedy_rows(model, params, batch=8, max_new=48 if tiny else 32)
    rows += _engine_rows(model, params, batch=8,
                         n_requests=32 if tiny else 48)
    rows += _sweep_rows(model, params,
                        "serving_smoke" if tiny else "serving_frontier")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: micro LM + the serving_smoke grid")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + sweep metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "sweep": LAST_SWEEP_META}, f, indent=2)
