"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.1f}us"


def roofline_table(recs, mesh):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful | temp/dev | peak/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    sel = [r for r in recs if r.get("status") == "ok" and r["mesh"] == mesh
           and r["pod_sync"] == "dense" and r.get("microbatches", 1) == 1]
    for r in sorted(sel, key=lambda r: (r["arch"], r["shape"])):
        roof, mem = r["roofline"], r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(roof['compute_s'])} | "
            f"{fmt_s(roof['memory_s'])} | {fmt_s(roof['collective_s'])} | "
            f"{roof['dominant']} | {roof['useful_flops_ratio']:.3f} | "
            f"{mem.get('temp_size_in_bytes', 0) / 1e9:.1f}GB | "
            f"{mem.get('peak_memory_in_bytes', 0) / 1e9:.2f}GB |")
    return "\n".join(rows)


def collective_detail(recs, arch, shape, mesh="single_pod", pod_sync="dense"):
    for r in recs:
        if (r.get("arch"), r.get("shape"), r.get("mesh"),
                r.get("pod_sync")) == (arch, shape, mesh, pod_sync):
            out = []
            for op, d in sorted(r["roofline"]["collectives"].items()):
                out.append(f"  {op:24s} count={d['count']:8.0f} "
                           f"wire={d['wire_bytes'] / 1e9:10.2f} GB")
            return "\n".join(out)
    return "(missing)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--detail", nargs=2, metavar=("ARCH", "SHAPE"))
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.detail:
        print(collective_detail(recs, args.detail[0], args.detail[1],
                                args.mesh))
        return
    print("## single-pod (16x16 = 256 chips)\n")
    print(roofline_table(recs, "single_pod"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(roofline_table(recs, "multi_pod"))
    ok = [r for r in recs if r.get("status") == "ok"]
    bad = [r for r in recs if r.get("status") != "ok"]
    print(f"\n{len(ok)} ok, {len(bad)} failed")
    for r in bad:
        print("FAILED:", r.get("arch"), r.get("shape"), r.get("mesh"),
              r.get("error", "")[:200])


if __name__ == "__main__":
    main()
