"""Paper §2 / Table-equivalent: centralized vs volunteer vs incentivized
compute capacity.  Reproduces the paper's arithmetic from its cited
constants and checks the two-orders-of-magnitude claims."""
from __future__ import annotations

from benchmarks.common import Row

# paper-cited constants
H100_COUNT = 350_000                 # Meta 2024 purchase [80]
H100_TFLOPS_TF32 = 989e12            # peak TF32 with sparsity off ~989; paper
                                     # rounds to ~1 exaFLOP/kGPU ("350 exaFLOPS")
H100_POWER_W = 700.0                 # SXM board power [60]
VOLUNTEER_PEAK_FLOPS = 1.2e18        # Folding@Home 2020 [44]
BITCOIN_TWH_YR = 150.0               # ±50 [56]
HOURS_PER_YEAR = 8760.0
WORLD_POWER_GW = 3_400.0             # ~0.5% claim check


def run() -> list:
    rows: list[Row] = []

    meta_flops = H100_COUNT * H100_TFLOPS_TF32
    rows.append(("capacity.meta_2024_exaflops", 0.0,
                 f"{meta_flops / 1e18:.0f} exaFLOPS (paper: ~350)"))

    meta_gw = H100_COUNT * H100_POWER_W / 1e9
    rows.append(("capacity.meta_2024_gw", 0.0,
                 f"{meta_gw:.2f} GW (paper: 0.24)"))

    btc_gw = BITCOIN_TWH_YR * 1e12 / HOURS_PER_YEAR / 1e9
    rows.append(("capacity.bitcoin_gw", 0.0,
                 f"{btc_gw:.2f} GW (paper: 17.12)"))

    rows.append(("capacity.btc_over_meta", 0.0,
                 f"{btc_gw / meta_gw:.0f}x (paper: ~2 orders of magnitude)"))

    vol_vs_meta = meta_flops / VOLUNTEER_PEAK_FLOPS
    rows.append(("capacity.meta_over_volunteer", 0.0,
                 f"{vol_vs_meta:.0f}x (paper: ~2 orders of magnitude)"))

    rows.append(("capacity.btc_world_fraction", 0.0,
                 f"{btc_gw / WORLD_POWER_GW * 100:.2f}% (paper: ~0.5%)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
