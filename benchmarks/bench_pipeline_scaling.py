"""Paper §3.2 / SWARM [71] square-cube claim: pipeline communication per
unit compute SHRINKS as the model grows — large models are *more* amenable
to internet-scale pipeline training, not less.

comm per microbatch per boundary ∝ mb·d (activations);
compute per layer per microbatch ∝ mb·d² (matmuls) ⇒ ratio ∝ 1/d.

Validated with the assigned architectures' real dims + a wall-time
microbench of one transformer layer vs its boundary transfer size."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.pipeline.pipeline import (
    bubble_fraction,
    pipeline_comm_bytes,
    pipeline_compute_flops,
)


def run() -> list:
    rows: list[Row] = []
    seq, mb = 2048, 8

    for arch in ["tinyllama-1.1b", "stablelm-3b", "mixtral-8x7b",
                 "granite-20b"]:
        cfg = get_config(arch)
        d = cfg.d_model
        act_bytes = mb * seq * d * 2                       # bf16 boundary
        flops_layer_mb = 2 * (mb * seq) * (
            3 * d * cfg.d_ff + 4 * d * cfg.resolved_head_dim * cfg.num_heads)
        ratio = act_bytes / flops_layer_mb
        rows.append((f"pipeline.comm_per_flop.{arch}", 0.0,
                     f"d={d} bytes/flop={ratio:.2e} (shrinks with d)"))

    # wall-time microbench: one dense layer fwd vs copying its activations
    for d in [256, 512, 1024]:
        w1 = jax.random.normal(jax.random.PRNGKey(0), (d, 4 * d), jnp.float32)
        w2 = jax.random.normal(jax.random.PRNGKey(1), (4 * d, d), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (mb * 128, d))

        layer = jax.jit(lambda x: jnp.tanh(x @ w1) @ w2)
        us_compute = timeit(layer, x)
        copy = jax.jit(lambda x: x + 0.0)
        us_copy = timeit(copy, x)
        rows.append((f"pipeline.layer_vs_boundary.d{d}", us_compute,
                     f"copy={us_copy:.0f}us ratio={us_copy / us_compute:.3f}"))

    rows.append(("pipeline.bubble_m8_p4", 0.0,
                 f"{bubble_fraction(8, 4):.3f} (GPipe fill/drain)"))
    rows.append(("pipeline.comm_bytes_m8_p4_1mb", 0.0,
                 f"{pipeline_comm_bytes(8, 4, 1 << 20)} bytes/fwd"))
    rows.append(("pipeline.flops_m8_p4", 0.0,
                 f"{pipeline_compute_flops(8, 2, 10**9):.1e} per stage"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
