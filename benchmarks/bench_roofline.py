"""Deliverable (g): the roofline table, read from the dry-run artifacts in
experiments/dryrun/ (produced by `python -m repro.launch.dryrun --all`).
No compilation happens here — run the dry-run first."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> list:
    rows: list[Row] = []
    recs = [r for r in load_records() if r.get("status") == "ok"]
    if not recs:
        rows.append(("roofline.missing", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
        return rows

    base = [r for r in recs if r["mesh"] == "single_pod"
            and r["pod_sync"] == "dense" and r.get("microbatches", 1) == 1
            and r.get("param_gather", "fsdp") == "fsdp"]
    for r in sorted(base, key=lambda r: (r["arch"], r["shape"])):
        roof = r["roofline"]
        rows.append((
            f"roofline.{r['arch']}.{r['shape']}", 0.0,
            f"compute={roof['compute_s']:.3g}s memory={roof['memory_s']:.3g}s "
            f"collective={roof['collective_s']:.3g}s dom={roof['dominant']} "
            f"useful={roof['useful_flops_ratio']:.3f}"))

    n_multi = len([r for r in recs if r["mesh"] == "multi_pod"])
    rows.append(("roofline.multi_pod_compiled", 0.0,
                 f"{n_multi} combinations on the 512-chip mesh"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
