"""Weak scaling of the mesh-sharded campaign engine (``core/placement.py``).

A §5.5 phase diagram is ONE compiled program — so the cost that matters is
the end-to-end campaign wall (compile + execute, the same clock
``derailment.sweep`` reports as runs/s).  This bench holds the per-device
lane count fixed and grows the device count: the single-device engine runs
L lanes, the 8-fake-device mesh (``--xla_force_host_platform_device_count``,
the ``launch/dryrun.py`` pattern) runs 8·L lanes under a
``MeshPlan`` — same program, lane axis sharded, bit-exact (pinned in
``tests/test_campaign_sharded.py``).  **Weak scaling** = total lanes/s vs
the single-device engine; the acceptance floor is ≥ 4x at 8 devices.

Every measurement runs in a fresh subprocess: XLA_FLAGS must be set before
jax imports, timings must include compile (a sweep is a one-shot program),
and the parent may already hold a single-device jax (benchmarks/run.py).

CLI:  ``python benchmarks/bench_campaign_scaling.py [--tiny] [--json F]``
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import Row

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

#: filled by run() for the --json artifact
LAST_SCALING_META: dict = {}

_WORKER = r"""
import json, os, sys, time
cfg = json.loads(sys.argv[1])
flags = "--xla_force_host_platform_device_count=%d" % cfg["devices"]
inherited = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
os.environ["XLA_FLAGS"] = " ".join([flags] + inherited)
import jax
import jax.numpy as jnp
from repro.core.placement import MeshPlan
from repro.core.swarm import (NodeSpec, SwarmConfig, lane_for_nodes,
                              run_campaign, stack_lanes)
from repro.optim.optimizer import SGD

n_params = cfg["n_params"]
key = jax.random.PRNGKey(42)
k1, k2 = jax.random.split(key)
target = jax.random.normal(k1, (n_params,))

def loss_fn(params, batch):
    return jnp.mean(jnp.square((batch["x"] @ (params["w"] - target))))

def data_fn(node_idx, rnd):
    k = jax.random.fold_in(jax.random.fold_in(k2, rnd), node_idx)
    return {"x": jax.random.normal(k, (16, n_params))}

params0 = {"w": jnp.zeros((n_params,))}
opt = SGD(lr=0.1, momentum=0.0)
nodes = [NodeSpec("h%d" % i) for i in range(cfg["nodes"])]
lanes = stack_lanes([lane_for_nodes(nodes, SwarmConfig(seed=s))
                     for s in range(cfg["lanes"])])
plan = (MeshPlan.for_lanes(cfg["lanes"], model=cfg["model"])
        if cfg["devices"] > 1 else None)

def campaign():
    out = run_campaign(loss_fn, params0, opt, data_fn, lanes,
                       rounds=cfg["rounds"], aggregator="centered_clip",
                       plan=plan)
    jax.block_until_ready(out)

t0 = time.perf_counter()
campaign()
cold_s = time.perf_counter() - t0          # compile + run: the sweep cost
t0 = time.perf_counter()
campaign()
warm_s = time.perf_counter() - t0          # program-cache hit: trace + run
print(json.dumps({"cold_s": cold_s, "warm_s": warm_s,
                  "devices": len(jax.devices()),
                  "mesh": str(plan.mesh) if plan else "none"}))
"""


def _measure(devices: int, lanes: int, *, rounds: int, n_params: int,
             nodes: int, model: int = 1) -> dict:
    cfg = {"devices": devices, "lanes": lanes, "rounds": rounds,
           "n_params": n_params, "nodes": nodes, "model": model}
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _WORKER, json.dumps(cfg)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"scaling worker failed for {cfg}:\n{proc.stderr}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    out.update(cfg)
    return out


def run(tiny: bool = False) -> list:
    per_dev = 4 if tiny else 8              # lanes per device (weak scaling)
    rounds = 6 if tiny else 10
    n_params = 64 if tiny else 256
    nodes = 6
    n_dev = 8

    single = _measure(1, per_dev, rounds=rounds, n_params=n_params,
                      nodes=nodes)
    meshed = _measure(n_dev, n_dev * per_dev, rounds=rounds,
                      n_params=n_params, nodes=nodes)
    # within-lane model axis: (4, 1, 2) mesh — lowers + runs on old jax
    model2 = _measure(n_dev, (n_dev // 2) * per_dev, rounds=rounds,
                      n_params=n_params, nodes=nodes, model=2)

    def lanes_per_s(m, clock="cold_s"):
        return m["lanes"] / max(m[clock], 1e-9)

    ratio = lanes_per_s(meshed) / max(lanes_per_s(single), 1e-9)
    warm_ratio = lanes_per_s(meshed, "warm_s") / max(
        lanes_per_s(single, "warm_s"), 1e-9)

    global LAST_SCALING_META
    LAST_SCALING_META = {"single": single, "meshed": meshed, "model2": model2,
                         "weak_scaling": ratio, "warm_scaling": warm_ratio,
                         "per_device_lanes": per_dev, "rounds": rounds}

    rows: list[Row] = [
        (f"campaign_scaling.1dev.L{single['lanes']}",
         single["cold_s"] * 1e6,
         f"{lanes_per_s(single):.1f} lanes/s end-to-end "
         f"(warm {lanes_per_s(single, 'warm_s'):.1f})"),
        (f"campaign_scaling.{n_dev}dev.L{meshed['lanes']}",
         meshed["cold_s"] * 1e6,
         f"{lanes_per_s(meshed):.1f} lanes/s end-to-end "
         f"(warm {lanes_per_s(meshed, 'warm_s'):.1f}) mesh={meshed['mesh']}"),
        (f"campaign_scaling.{n_dev}dev.model2.L{model2['lanes']}",
         model2["cold_s"] * 1e6,
         f"{lanes_per_s(model2):.1f} lanes/s end-to-end "
         f"mesh={model2['mesh']}"),
        ("campaign_scaling.weak_scaling", 0.0,
         f"x{ratio:.2f} total lanes/s vs 1dev at {per_dev} lanes/device "
         f"(>=4x target; warm-program x{warm_ratio:.2f})"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 4 lanes/device, 6 rounds")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="dump rows + scaling metadata as JSON")
    args = ap.parse_args()

    rows = run(tiny=args.tiny)
    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": us, "derived": d}
                                for n, us, d in rows],
                       "scaling": LAST_SCALING_META}, f, indent=2)
