"""Sharded checkpointing with a custody manifest.

Checkpoints are directories of .npz chunks plus a JSON manifest.  Two modes:

- ``save`` / ``restore``      — standard full-tree checkpoints (train loop).
- ``save_custody`` / ``restore_custody`` — Protocol-Model checkpoints: the
  flat parameter stream is cut into custody shards (core.unextractable) and
  each shard is written as a separate file keyed by holder, so "a checkpoint"
  in Protocol Learning is *a set of files no single node ever holds all of*.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.unextractable import ShardCustody, reconstruct_params, shard_params


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, *, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, template):
    """Restore into the structure of ``template``.

    Shapes AND dtypes are validated against the template — the manifest
    records both at save time, and silently coercing a checkpoint's dtype
    (the old ``jnp.asarray(arr, dtype=leaf.dtype)`` behaviour) would hide
    e.g. an fp32 checkpoint restored into a bf16 training run as a quiet
    precision change.  Errors name the offending key."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    saved_dtypes = manifest.get("dtypes", {})
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_e, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path_e)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        saved = saved_dtypes.get(key, str(arr.dtype))
        if saved != str(jnp.dtype(leaf.dtype)):
            raise ValueError(f"dtype mismatch for {key}: checkpoint has "
                             f"{saved}, template wants {jnp.dtype(leaf.dtype)}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, leaves)


def load_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


# -- custody checkpoints (Protocol Models) -----------------------------------
def save_custody(path: str, params, custody: ShardCustody, *, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    shards, true_size = shard_params(params, custody.num_shards)
    for sid, holders in custody.assignment.items():
        for holder in holders:
            np.savez(os.path.join(path, f"shard_{sid}_{holder}.npz"),
                     data=np.asarray(shards[sid]))
    manifest = {
        "step": step,
        "num_shards": custody.num_shards,
        "redundancy": custody.redundancy,
        "true_size": true_size,
        "assignment": {str(k): v for k, v in custody.assignment.items()},
    }
    with open(os.path.join(path, "custody.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore_custody(path: str, template, *, holders: List[str]):
    """Reassemble from the shards the given holders possess.  Raises if the
    coalition doesn't cover the model (the unextractability property)."""
    with open(os.path.join(path, "custody.json")) as f:
        manifest = json.load(f)
    num_shards = manifest["num_shards"]
    gathered: Dict[int, jnp.ndarray] = {}
    for sid_s, shard_holders in manifest["assignment"].items():
        sid = int(sid_s)
        for h in shard_holders:
            if h in holders:
                fn = os.path.join(path, f"shard_{sid}_{h}.npz")
                with np.load(fn) as z:
                    gathered[sid] = jnp.asarray(z["data"])
                break
    if len(gathered) < num_shards:
        raise PermissionError(
            f"coalition holds {len(gathered)}/{num_shards} shards — cannot restore")
    return reconstruct_params(gathered, template, num_shards, manifest["true_size"])
