"""SWARM-style pipeline parallelism (paper §3.2, Ryabinin et al. [71]).

The paper's preferred internet-scale sharding: the model is split layerwise
into P stages; activations flow stage-to-stage (point-to-point, cheap),
never all-to-all.  Expressed natively with shard_map + lax.ppermute:

- stage s holds layers [s·L/P, (s+1)·L/P) — params sharded over the
  ``pipe`` mesh axis on their stacked layer dim;
- GPipe-style fill/drain schedule over M microbatches: M + P − 1 ticks,
  activation hand-off by collective_permute each tick;
- jax.grad differentiates straight through the ppermute schedule, so the
  same code trains (the backward permutes run in reverse) — no hand-written
  backward pipeline.

The square-cube claim the paper cites from [71] — per-stage comm/compute
ratio shrinks as d_model grows — is measured in benchmarks/bench_pipeline_scaling.py
with this exact implementation.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

Array = jax.Array


def num_ticks(num_micro: int, num_stages: int) -> int:
    return num_micro + num_stages - 1


def spmd_pipeline(stage_fn: Callable, stage_params, xs: Array, *, axis: str = "pipe"):
    """Run inside shard_map over ``axis``.

    stage_fn(local_params, x) -> x : applies this stage's layers.
    stage_params: this stage's shard (leading layer axis already local).
    xs: (M, mb, ...) microbatches (same on every stage).
    Returns ys: (M, mb, ...) — valid on the LAST stage, zeros elsewhere.
    """
    p = compat.axis_size(axis)
    stage = jax.lax.axis_index(axis)
    m = xs.shape[0]
    ticks = num_ticks(m, p)
    perm = [(i, i + 1) for i in range(p - 1)]

    def tick_fn(carry, t):
        recv, ys = carry
        mb_idx = jnp.clip(t, 0, m - 1)
        first_in = jnp.where(t < m, 1.0, 0.0) * xs[mb_idx]
        x = jnp.where(stage == 0, first_in, recv)
        out = stage_fn(stage_params, x)
        # last stage: commit the microbatch that finished at this tick
        done_idx = jnp.clip(t - (p - 1), 0, m - 1)
        commit = (stage == p - 1) & (t >= p - 1)
        ys = jax.lax.dynamic_update_index_in_dim(
            ys, jnp.where(commit, out, ys[done_idx]), done_idx, 0)
        recv = jax.lax.ppermute(out, axis, perm)
        return (recv, ys), None

    recv0 = compat.pvary(jnp.zeros_like(xs[0]), (axis,))
    ys0 = compat.pvary(jnp.zeros_like(xs), (axis,))
    (recv, ys), _ = jax.lax.scan(tick_fn, (recv0, ys0), jnp.arange(ticks))
    # broadcast final outputs from the last stage to everyone
    mask = (stage == p - 1).astype(ys.dtype)
    return jax.lax.psum(ys * mask, axis)


def make_pipeline_apply(layer_fn: Callable, mesh: Mesh, *, axis: str = "pipe"):
    """Build jit-ready pipelined apply: (stacked_params, xs) -> ys.

    layer_fn(layer_params, x) -> x for ONE layer; layers are scanned within
    a stage.  stacked_params leaves have leading dim L (L % P == 0).
    """

    def stage_fn(local_params, x):
        def body(x, lp):
            return layer_fn(lp, x), None
        x, _ = jax.lax.scan(body, x, local_params)
        return x

    def apply(stacked_params, xs):
        fn = functools.partial(spmd_pipeline, stage_fn, axis=axis)
        spec_params = jax.tree.map(lambda _: P(axis), stacked_params)
        return compat.shard_map(
            fn, mesh=mesh,
            in_specs=(spec_params, P()),
            out_specs=P(),
        )(stacked_params, xs)

    return apply


def pipeline_comm_bytes(num_micro: int, num_stages: int, act_bytes: int) -> int:
    """Activation bytes crossing stage boundaries per forward pass."""
    return num_ticks(num_micro, num_stages) * (num_stages - 1) * act_bytes


def pipeline_compute_flops(num_micro: int, layers_per_stage: int,
                           flops_per_layer_mb: int) -> int:
    """Useful FLOPs per stage per forward pass."""
    return num_micro * layers_per_stage * flops_per_layer_mb


def bubble_fraction(num_micro: int, num_stages: int) -> float:
    """GPipe bubble: (P-1)/(M+P-1) of ticks are fill/drain idle."""
    return (num_stages - 1) / num_ticks(num_micro, num_stages)
