"""Deterministic synthetic LM data pipeline.

No external corpora ship in this container, so the pipeline synthesizes
token streams with learnable structure (a tiny mixture of Markov chains —
models actually reduce loss on it, which the examples and EXPERIMENTS.md
rely on).  Properties:

- deterministic: (seed, step, shard) fully determines a batch — restart-safe
  and verifiable (a validator can recompute any contributor's batch, which
  the §4.2 audit path depends on);
- shardable: ``shard`` / ``num_shards`` slice the global batch without
  materializing it (per-node data assignment in the swarm, per-host in
  multi-pod training);
- family-aware: builds the right batch dict for LM / VLM / audio models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AUDIO, VLM, ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_states: int = 32          # markov states; structure the model can learn
    branch: int = 4               # out-degree per state


def _transition_table(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    table = rng.integers(0, cfg.vocab_size, size=(cfg.num_states, cfg.branch))
    return table.astype(np.int32)


def _batch_key(cfg: DataConfig, step: int, shard: int) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)


def sample_tokens(cfg: DataConfig, step: int, *, shard: int = 0,
                  num_shards: int = 1) -> jax.Array:
    """(local_batch, seq_len+1) tokens — deterministic in (seed, step, shard)."""
    assert cfg.global_batch % num_shards == 0
    local = cfg.global_batch // num_shards
    key = _batch_key(cfg, step, shard)
    table = jnp.asarray(_transition_table(cfg))

    k1, k2 = jax.random.split(key)
    state0 = jax.random.randint(k1, (local,), 0, cfg.num_states)
    choices = jax.random.randint(k2, (local, cfg.seq_len + 1), 0, cfg.branch)

    def step_fn(state, choice):
        tok = table[state, choice]
        return tok % cfg.num_states, tok

    _, toks = jax.lax.scan(step_fn, state0, choices.T)
    return toks.T                                            # (local, seq+1)


def lm_batch(cfg: DataConfig, step: int, *, shard: int = 0,
             num_shards: int = 1) -> Dict[str, jax.Array]:
    toks = sample_tokens(cfg, step, shard=shard, num_shards=num_shards)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def model_batch(mcfg: ModelConfig, cfg: DataConfig, step: int, *, shard: int = 0,
                num_shards: int = 1) -> Dict[str, jax.Array]:
    """Family-aware batch (VLM media stubs / audio frame stubs included)."""
    base = lm_batch(cfg, step, shard=shard, num_shards=num_shards)
    b = base["tokens"].shape[0]
    s = cfg.seq_len
    key = _batch_key(cfg, step, shard + 10_000)
    if mcfg.family == VLM:
        m = mcfg.num_media_tokens
        base["tokens"] = base["tokens"][:, : s - m]
        base["media"] = jax.random.normal(key, (b, m, mcfg.d_model),
                                          jnp.dtype(mcfg.dtype))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        base["positions"] = jnp.stack([pos, pos // 4, pos % 4])
    elif mcfg.family == AUDIO:
        base["frames"] = jax.random.normal(key, (b, s, mcfg.d_model),
                                           jnp.dtype(mcfg.dtype))
    return base


def data_fn_for_swarm(mcfg: ModelConfig, cfg: DataConfig, num_nodes: int):
    """Adapter for core.swarm: node i reads shard (i mod num_nodes)."""
    assert cfg.global_batch % num_nodes == 0, "global batch must split across nodes"

    def fn(node_idx: int, rnd: int):
        return model_batch(mcfg, cfg, rnd, shard=node_idx % num_nodes,
                           num_shards=num_nodes)
    return fn
