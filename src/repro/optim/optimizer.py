"""Optimizers + schedules (pure JAX; no optax dependency).

AdamW with decoupled weight decay and global-norm clipping; SGD(+momentum)
for the swarm demos.  Optimizer state is a pytree mirroring params, so the
same sharding rules apply (m/v shard exactly like their parameter).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamState(NamedTuple):
    step: Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * g * g, state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mu, nu):
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamState(step=step, m=m, v=v)


class SGDState(NamedTuple):
    step: Array
    momentum: Any


@dataclass(frozen=True)
class SGD:
    lr: Callable[[Array], Array] | float = 0.1
    momentum: float = 0.9
    clip_norm: Optional[float] = None

    def init(self, params) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        )

    def update(self, grads, state: SGDState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mom = jax.tree.map(lambda m, g: self.momentum * m + g, state.momentum, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mom)
        return new_params, SGDState(step=step, momentum=mom)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, cos)
    return lr
