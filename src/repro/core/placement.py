"""Device placement as a first-class campaign property (DESIGN.md §4).

Every §5.5 phase diagram used to compile to one ``jit(vmap(scan))`` program
on whatever device jax picked — lanes × model size capped by a single HBM.
:class:`MeshPlan` makes placement explicit: it maps a campaign's lane count
onto a ``("lanes", "data", "model")`` mesh and the engines
(``swarm.run_campaign``, ``derailment.sweep``, ``serving.ServingEngine``)
accept it as an optional argument.

Two sharding levels, with different exactness contracts:

- **lane axis** — the stacked :class:`~repro.core.swarm.LaneParams` /
  :class:`~repro.core.serving.ServeLane` leaves shard their leading run
  axis over ``lanes`` (``place_lanes``), and the engine's ``vmap`` carries
  ``spmd_axis_name`` so internal sharding constraints stay lane-local.
  Lanes are embarrassingly parallel, so this is **bit-exact** against the
  unsharded engine for the centralized, fused-kernel, and serving rounds
  (pinned in ``tests/test_campaign_sharded.py``): every params/opt-state
  leaf and every per-round counter.  Two ULP-level exceptions, both from
  XLA making different fusion decisions under a mesh (which reorders float
  reductions): the final *eval* matmul, and the decentralized round's
  gossip mixing matmul — those are allclose, not bit-equal.
- **within-lane axes** — ``place_params`` shards a lane's *shared* params
  over ``model`` (and ``data``): via the symbolic rules in
  ``models.sharding.param_pspecs`` when the plan carries a
  :class:`~repro.configs.base.ModelConfig`, else a generic
  largest-divisible-dim rule for toy pytrees.  Resharding changes
  reduction order, so this level is **allclose-pinned** only.

Old-jax caveat: this container's jax (0.4.x) emulates collectives
(``compat.collectives_emulated()``) — plain GSPMD propagation, which is all
a MeshPlan needs, lowers fine, but any program whose partitioning requires
gather/permute collectives inside a partial-manual region hard-aborts.
``reraise_lowering`` converts that abort into a clear error naming the
predicate instead of an XLA stack trace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

LANES_AXIS = "lanes"


def lane_axis_size(n_lanes: int, max_devices: int) -> int:
    """Largest divisor of ``n_lanes`` that fits in ``max_devices`` — the
    lane-axis extent :meth:`MeshPlan.for_lanes` picks so the stacked run
    axis always shards evenly (30 lanes on 8 devices -> 6)."""
    if n_lanes < 1 or max_devices < 1:
        return 1
    for d in range(min(n_lanes, max_devices), 0, -1):
        if n_lanes % d == 0:
            return d
    return 1


@dataclass(frozen=True)
class MeshPlan:
    """A placement: the mesh plus which of its axes mean what.

    ``cfg`` (optional) is the :class:`~repro.configs.base.ModelConfig` of
    the params being swept — it switches ``param_specs`` from the generic
    toy rule to the real ``models.sharding.param_pspecs`` rules."""
    mesh: Mesh
    lanes_axis: str = LANES_AXIS
    data_axis: str = "data"
    model_axis: str = "model"
    cfg: Optional[object] = None

    # -- axis sizes ---------------------------------------------------------
    def axis_size(self, name: str) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return int(sizes.get(name, 1))

    @property
    def lane_devices(self) -> int:
        return self.axis_size(self.lanes_axis)

    @property
    def data_devices(self) -> int:
        return self.axis_size(self.data_axis)

    @property
    def model_devices(self) -> int:
        return self.axis_size(self.model_axis)

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    # -- construction -------------------------------------------------------
    @classmethod
    def for_lanes(cls, n_lanes: int, *, data: int = 1, model: int = 1,
                  max_devices: Optional[int] = None,
                  cfg: Optional[object] = None) -> "MeshPlan":
        """Plan for a campaign of ``n_lanes`` runs: the lane axis takes the
        largest divisor of ``n_lanes`` that fits in the available devices
        after the within-lane ``data``/``model`` factors."""
        from repro.launch.mesh import make_campaign_mesh  # avoid cycle
        avail = len(jax.devices()) if max_devices is None else max_devices
        if data < 1 or model < 1:
            raise ValueError(f"data/model factors must be >= 1, got "
                             f"data={data} model={model}")
        if avail < data * model:
            raise ValueError(
                f"within-lane factors data={data} x model={model} need "
                f"{data * model} devices, have {avail}")
        lanes = lane_axis_size(n_lanes, avail // (data * model))
        mesh = make_campaign_mesh(lanes=lanes, data=data, model=model)
        return cls(mesh=mesh, cfg=cfg)

    @classmethod
    def from_grid(cls, grid, **kwargs) -> "MeshPlan":
        """Plan for a ``scenarios.SweepGrid`` / ``ServingGrid`` — the lane
        count is the grid's total lane count (baseline lanes included)."""
        return cls.for_lanes(grid.n_lanes, **kwargs)

    # -- lane-axis placement (bit-exact level) -------------------------------
    def validate_lanes(self, n_lanes: int) -> None:
        d = self.lane_devices
        if n_lanes % d:
            raise ValueError(
                f"{n_lanes} lanes do not shard evenly over the "
                f"{d}-device '{self.lanes_axis}' axis of {self.mesh}; pad "
                f"the grid or build the plan with MeshPlan.for_lanes "
                f"({n_lanes} lanes -> lane axis "
                f"{lane_axis_size(n_lanes, self.n_devices)})")

    def lane_sharding(self, leaf) -> NamedSharding:
        spec = P(*((self.lanes_axis,) + (None,) * (leaf.ndim - 1)))
        return NamedSharding(self.mesh, spec)

    def place_lanes(self, stacked):
        """device_put every stacked-lane leaf with its leading run axis
        sharded over ``lanes`` (None leaves — e.g. an absent custody or
        mixing field — pass through)."""
        leaves = [l for l in jax.tree.leaves(stacked) if l is not None]
        if leaves:
            self.validate_lanes(int(leaves[0].shape[0]))
        return jax.tree.map(
            lambda x: jax.device_put(x, self.lane_sharding(x)), stacked)

    # -- within-lane placement (allclose level) -------------------------------
    def param_specs(self, params):
        """PartitionSpecs for a lane's shared params: the real
        ``models.sharding`` rules when ``cfg`` is given, else a generic
        rule sharding each leaf's largest ``model``-divisible dim."""
        m = self.model_devices
        if self.cfg is not None:
            from repro.models.sharding import param_pspecs
            sizes = {self.data_axis: self.data_devices, self.model_axis: m}
            return param_pspecs(params, self.cfg, sizes,
                                data_axis=self.data_axis,
                                model_axis=self.model_axis)

        def generic(leaf):
            if m <= 1 or leaf.ndim == 0:
                return P()
            dims = [(size, i) for i, size in enumerate(leaf.shape)
                    if size % m == 0]
            if not dims:
                return P()
            _, best = max(dims)
            spec = [None] * leaf.ndim
            spec[best] = self.model_axis
            return P(*spec)

        return jax.tree.map(generic, params)

    def place_params(self, params):
        """device_put a lane's shared params per :meth:`param_specs` —
        replicated leaves stay replicated; the identity when the plan has
        no within-lane axes (nothing to reshard, nothing to pay)."""
        if self.model_devices <= 1 and self.data_devices <= 1:
            return params
        specs = self.param_specs(params)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            params, specs)

    # -- the collectives_emulated gate ---------------------------------------
    def reraise_lowering(self, exc: Exception):
        """Called when a program under this plan fails to lower/compile.
        Old jax (``compat.collectives_emulated()``) cannot lower
        gather/permute collectives in partial-manual regions — the 0.4.x
        SPMD partitioner hard-aborts — so name the predicate instead of
        leaking an XLA stack trace; on new jax re-raise untouched."""
        if compat.collectives_emulated():
            raise RuntimeError(
                f"mesh plan {self.mesh} failed to lower on jax "
                f"{jax.__version__}: this jax emulates collectives "
                "(compat.collectives_emulated() — no jax.shard_map; the "
                "0.4.x SPMD partitioner cannot lower gather/permute "
                "collectives). Use a lanes-only plan (data=1, model=1) or "
                "upgrade jax.") from exc
        raise exc
