"""Hierarchy-aware gradient sync across the pod axis (DESIGN.md §2).

The TPU-native adaptation of the paper's internet-scale techniques: inside a
pod, gradients are exact (pjit handles it); ACROSS pods — the slow axis —
the Protocol Learning toolbox applies.  All methods are written with
jax.lax collectives and are called inside shard_map over the ``pod`` axis.

Methods (selectable via TrainOptions.pod_sync):
- dense      : pmean — the exact baseline.
- qsgd       : int8-quantized all-gather + local dequant/mean.  The wire
               tensor is int8, so the roofline collective term drops ~4x —
               visible directly in the dry-run HLO (§Perf).
- centered_clip : all-gather full updates, robust-aggregate (byzantine-
               tolerant across pods; [27, 40]).
- gossip     : ring ppermute rounds — O(rounds) neighbour exchanges instead
               of a global all-reduce; converges geometrically ([7, 10]).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import aggregation

Array = jax.Array


def dense_sync(grads, axis: str, *, pod_index=None):
    del pod_index                                # pmean needs no emulation
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)


def qsgd_sync(grads, axis: str, *, bits: int = 8, pod_index=None):
    """Quantize-then-all-gather: int8 on the wire, fp32 result."""
    qmax = 2 ** (bits - 1) - 1

    def per_leaf(g):
        gf = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(gf)) / qmax + 1e-30
        q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
        qs = compat.all_gather(q, axis, index=pod_index)     # int8 on the wire
        ss = compat.all_gather(scale.reshape(1), axis, index=pod_index)
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * gf.ndim)
        return jnp.mean(deq, axis=0).astype(g.dtype)

    return jax.tree.map(per_leaf, grads)


def centered_clip_sync(grads, axis: str, *, clip_tau: float | None = None,
                       iters: int = 3, pod_index=None):
    """Byzantine-robust cross-pod aggregation: every pod is a 'node'."""
    return robust_sync(grads, axis, aggregator="centered_clip",
                       clip_tau=clip_tau, iters=iters, pod_index=pod_index)


def robust_sync(grads, axis: str, *, aggregator: str = "centered_clip",
                pod_index=None, **kw):
    """All-gather per-pod updates over ``axis`` and apply ANY robust
    aggregator from core.aggregation (median / trimmed_mean / krum / CC).
    The gather is the measured 'price of byzantine tolerance' on the pod
    axis (EXPERIMENTS.md §Perf pair C)."""
    stacked = jax.tree.map(
        lambda g: compat.all_gather(g.astype(jnp.float32), axis,
                                    index=pod_index), grads)
    agg = aggregation.get_aggregator(aggregator, **kw)(stacked)
    return jax.tree.map(lambda a, g: a.astype(g.dtype), agg, grads)


def median_sync(grads, axis: str, *, pod_index=None):
    return robust_sync(grads, axis, aggregator="median", pod_index=pod_index)


def gossip_sync(grads, axis: str, *, rounds: int = 1, pod_index=None):
    """Ring gossip: each round averages with both ring neighbours."""
    n = compat.axis_size(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    def one_round(g):
        def per_leaf(x):
            xf = x.astype(jnp.float32)
            right = compat.ppermute(xf, axis, fwd, index=pod_index)
            if n == 2:
                return ((xf + right) / 2).astype(x.dtype)
            left = compat.ppermute(xf, axis, bwd, index=pod_index)
            return ((xf + left + right) / 3).astype(x.dtype)
        return jax.tree.map(per_leaf, g)

    for _ in range(rounds):
        grads = one_round(grads)
    return grads


POD_SYNC = {
    "dense": dense_sync,
    "qsgd": qsgd_sync,
    "centered_clip": centered_clip_sync,
    "median": median_sync,
    "gossip": gossip_sync,
}


def get_pod_sync(name: str, **kw):
    fn = POD_SYNC[name]
    return functools.partial(fn, **kw) if kw else fn
