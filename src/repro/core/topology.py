"""Communication topologies for decentralized training (paper §3.2, §5.5).

Grown out of ``core.gossip``: this module is the canonical home of the
communication *graph* layer — adjacency builders, the Metropolis mixing
matrix, spectral-gap utilities, and a registry of named topologies that the
decentralized swarm round (``core.swarm`` with ``SwarmConfig.topology`` /
``LaneParams.mixing``), the scenario registry, and the §5.5 topology-axis
derailment sweeps all consume.  ``core.gossip`` keeps the mixing *runtime*
(``gossip_round`` / ``gossip_average`` / traffic accounting) and re-exports
the builders for backward compatibility.

A topology produces an undirected boolean adjacency; :func:`metropolis_weights`
turns it into the doubly-stochastic mixing matrix ``W`` with
``W_ij = 1/(1+max(deg_i, deg_j))`` on edges and the leftover mass on the
diagonal.  Gossip converges to the exact mean geometrically at rate
``1 - spectral_gap(W)`` [7, 10, 42, 51, 52, 77] — the spectral gap is the
*one* number that decides whether local robust aggregation can still resist
derailment (see ``docs/topology.md``).

Time-varying graphs are first-class: :func:`time_varying_mixing` stacks a
fresh graph per round (T, N, N) and :func:`churn_coupled_mixing` couples the
mixing matrix to a join/leave schedule (departed nodes become isolated
self-loops, so their replicas freeze).  The decentralized swarm round
indexes a 3-D mixing stack by ``round % T``, so both ride through
``lax.scan`` unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

import numpy as np

__all__ = [
    "Topology", "TOPOLOGIES", "register_topology", "get_topology",
    "list_topologies", "ring_adjacency", "torus_adjacency",
    "random_regular_adjacency", "fully_connected_adjacency",
    "clustered_adjacency", "is_connected", "metropolis_weights",
    "spectral_gap", "mixing_matrix", "time_varying_mixing",
    "churn_coupled_mixing",
]


# -- adjacency builders ---------------------------------------------------------
def ring_adjacency(n: int) -> np.ndarray:
    a = np.zeros((n, n), bool)
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = True
    a[idx, (idx - 1) % n] = True
    return a


def torus_adjacency(n: int) -> np.ndarray:
    """2-D wraparound grid on the most-square ``r x c = n`` factorization.

    Degree 4 away from degenerate shapes; a prime ``n`` factors as ``1 x n``
    and degenerates to the ring.  (Duplicate wrap edges on 1- or 2-wide
    grids collapse in the boolean adjacency — degree just drops.)
    """
    r = max(d for d in range(1, int(np.sqrt(n)) + 1) if n % d == 0)
    c = n // r
    a = np.zeros((n, n), bool)
    for i in range(r):
        for j in range(c):
            u = i * c + j
            for v in (i * c + (j + 1) % c, ((i + 1) % r) * c + j):
                if u != v:
                    a[u, v] = a[v, u] = True
    return a


def fully_connected_adjacency(n: int) -> np.ndarray:
    a = np.ones((n, n), bool)
    np.fill_diagonal(a, False)
    return a


def is_connected(adj: np.ndarray) -> bool:
    """BFS reachability from node 0 over an undirected adjacency."""
    n = adj.shape[0]
    if n == 0:
        return True
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = np.array([0])
    while frontier.size:
        nxt = adj[frontier].any(axis=0) & ~seen
        seen |= nxt
        frontier = np.flatnonzero(nxt)
    return bool(seen.all())


def random_regular_adjacency(n: int, degree: int = 4, seed: int = 0, *,
                             max_tries: int = 64) -> np.ndarray:
    """Random degree-regular-ish graph: the union of ``max(1, degree//2)``
    random ring permutations.

    Degree is a *ceiling*, not a guarantee — two permutations can land the
    same edge (or a ring perm of length 2 double-counts one), so individual
    nodes may come up short.  What IS guaranteed: the graph is symmetric,
    self-loop-free, every node has degree >= 2, and it is **connected** —
    a draw whose perm edges collide into a disconnected or under-degree
    graph is discarded and redrawn with fresh permutations (previously such
    draws were returned silently, poisoning every spectral-gap consumer
    downstream with a gap of ~0).
    """
    if n < 2:
        raise ValueError(f"random_regular_adjacency needs n >= 2, got {n}")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        a = np.zeros((n, n), bool)
        for _ in range(max(1, degree // 2)):
            perm = rng.permutation(n)
            a[perm, np.roll(perm, 1)] = True
            a[np.roll(perm, 1), perm] = True
        np.fill_diagonal(a, False)
        if is_connected(a) and int(a.sum(1).min()) >= min(2, n - 1):
            return a
    raise ValueError(
        f"no connected degree-{degree} graph on {n} nodes in {max_tries} "
        "draws (raise max_tries or the degree)")


def clustered_adjacency(n: int, clusters: int = 2) -> np.ndarray:
    """``clusters`` rings joined into a chain by single bridge edges
    (``clusters - 1`` bridges, no wraparound) — a connected graph with a
    near-zero spectral gap (the partitioned-swarm regime: consensus leaks
    across bridges one edge at a time)."""
    if clusters < 1 or n < 2 * clusters:
        raise ValueError(f"need n >= 2*clusters, got n={n} clusters={clusters}")
    bounds = np.linspace(0, n, clusters + 1).astype(int)
    a = np.zeros((n, n), bool)
    for k in range(clusters):
        lo, hi = bounds[k], bounds[k + 1]
        size = hi - lo
        for i in range(size):
            u, v = lo + i, lo + (i + 1) % size
            if u != v:
                a[u, v] = a[v, u] = True
    for k in range(clusters - 1):        # one bridge per adjacent cluster pair
        u, v = bounds[k + 1] - 1, bounds[k + 1]
        a[u, v] = a[v, u] = True
    return a


# -- mixing matrices & spectra --------------------------------------------------
def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Doubly-stochastic Metropolis mixing matrix from an undirected
    adjacency: ``W_ij = 1/(1+max(deg_i, deg_j))`` on edges, leftover mass on
    the diagonal."""
    adj = np.asarray(adj, bool)
    deg = adj.sum(1)
    w = np.where(adj, 1.0 / (1.0 + np.maximum(deg[:, None], deg[None, :])), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(1))
    return w


def spectral_gap(w: np.ndarray) -> float:
    """``1 - |λ₂|`` of a **symmetric** mixing matrix — the geometric
    consensus rate.  Uses ``eigvalsh`` (every Metropolis matrix is
    symmetric), so eigenvalues are exactly real and cannot pick up complex
    round-off the way the old general-eigvals path could.  ``eigvalsh``
    reads only one triangle, so a non-symmetric matrix (e.g. a push-sum /
    directed-gossip W) would silently get the gap of a *different* matrix
    — rejected loudly instead."""
    w = np.asarray(w, np.float64)
    if not np.allclose(w, w.T, atol=1e-8):
        raise ValueError("spectral_gap expects a symmetric mixing matrix "
                         "(directed/push-sum gossip needs its own analysis)")
    ev = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(1.0 - ev[1])


# -- the registry ---------------------------------------------------------------
@dataclass(frozen=True)
class Topology:
    """A named communication graph family.

    ``builder(n, seed=0, **kwargs)`` returns the boolean adjacency for an
    ``n``-node swarm; deterministic in ``(n, seed, kwargs)``.
    """
    name: str
    description: str
    builder: Callable[..., np.ndarray]


TOPOLOGIES: Dict[str, Topology] = {}


def register_topology(topology: Topology) -> Topology:
    TOPOLOGIES[topology.name] = topology
    return topology


def get_topology(name: str) -> Topology:
    try:
        return TOPOLOGIES[name]
    except KeyError:
        raise KeyError(f"unknown topology {name!r}; "
                       f"registered: {list_topologies()}") from None


def list_topologies() -> List[str]:
    return sorted(TOPOLOGIES)


register_topology(Topology(
    name="ring",
    description="Cycle graph: degree 2, gap ~ 1/n² — the slowest-mixing "
                "connected baseline.",
    builder=lambda n, seed=0: ring_adjacency(n),
))

register_topology(Topology(
    name="torus",
    description="2-D wraparound grid (most-square factorization): degree "
                "~4, gap ~ 1/n.",
    builder=lambda n, seed=0: torus_adjacency(n),
))

register_topology(Topology(
    name="random_regular",
    description="Union of random ring permutations (degree-d-ish expander): "
                "near-constant gap, the communication-efficient sweet spot.",
    builder=lambda n, seed=0, degree=4: random_regular_adjacency(
        n, degree, seed=seed),
))

register_topology(Topology(
    name="fully_connected",
    description="Complete graph: gap 1, one gossip round = exact mean — "
                "equivalent to the centralized aggregator.",
    builder=lambda n, seed=0: fully_connected_adjacency(n),
))

register_topology(Topology(
    name="clustered",
    description="Rings joined by single bridge edges: connected but "
                "near-zero gap — the partitioned-swarm stress case.",
    builder=lambda n, seed=0, clusters=2: clustered_adjacency(n, clusters),
))


def mixing_matrix(name: str, n: int, seed: int = 0, **kwargs) -> np.ndarray:
    """Metropolis mixing matrix of the named topology at size ``n``."""
    return metropolis_weights(get_topology(name).builder(n, seed=seed, **kwargs))


def time_varying_mixing(name: str, n: int, rounds: int, seed: int = 0,
                        **kwargs) -> np.ndarray:
    """A (rounds, N, N) stack of per-round mixing matrices — a fresh graph
    draw each round (deterministic in ``(seed, round)``).  Static topologies
    (ring/torus/fully_connected ignore their seed) stack to identical
    slices.  The decentralized swarm round indexes this by ``round % T``."""
    return np.stack([mixing_matrix(name, n, seed=seed + 7919 * t, **kwargs)
                     for t in range(rounds)])


def churn_coupled_mixing(w: np.ndarray, joins: np.ndarray, leaves: np.ndarray,
                         rounds: int) -> np.ndarray:
    """Couple a base mixing matrix to a membership schedule: a (T, N, N)
    stack where round ``t`` keeps only edges between nodes active at ``t``
    (``joins[i] <= t < leaves[i]``) and returns the lost mass to the
    diagonal.  Inactive nodes become isolated self-loops (rows ``e_i``), so
    their replicas freeze instead of mixing from beyond the grave; each
    slice stays symmetric and doubly stochastic, so consensus guarantees
    hold round by round on the active subgraph."""
    w = np.asarray(w, np.float64)
    n = w.shape[0]
    joins = np.asarray(joins)
    leaves = np.asarray(leaves)
    out = np.empty((rounds, n, n))
    for t in range(rounds):
        act = (joins <= t) & (t < leaves)
        off = w * (act[:, None] & act[None, :])
        np.fill_diagonal(off, 0.0)
        wt = off.copy()
        np.fill_diagonal(wt, 1.0 - off.sum(1))
        out[t] = wt
    return out
