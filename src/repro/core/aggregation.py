"""Byzantine-robust gradient aggregation (paper §3.3).

Aggregators operate on a stack of per-node updates with leading axis N
(nodes).  All of them work on flat vectors OR arbitrary pytrees (leading
node axis on every leaf).

Implemented (each cited in the paper):
- ``mean``          — linear; NOT byzantine robust [6 shows 1 node suffices]
- ``krum`` / ``multi_krum``  — Blanchard et al. [6]
- ``coordinate_median`` / ``trimmed_mean`` — Yin et al. [89]
- ``centered_clip`` — Karimireddy et al. [40], the aggregator Gorbunov et
  al. [27] build on for decentralized byzantine SGD; Pallas kernel twin in
  ``repro.kernels.centered_clip``.

Breakdown points (validated in tests / benchmarks):
  mean: 0; krum: (N-3)/2N (from N ≥ 2f+3, i.e. f ≤ (N-3)/2 — pinned against
  masked_krum at the boundary in tests); median/trimmed: 1/2; CC: ~1/2
  (bounded error).

Every aggregator also has a ``masked_*`` twin taking a fixed (N, D) stack
plus a boolean keep-mask — the form the batched swarm engine needs so the
jitted round keeps a fixed shape across membership churn.  A masked variant
is defined to equal its dense counterpart on ``updates[mask]``.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def _flatten_nodes(updates):
    """pytree with leading node axis -> (N, D) matrix + unravel fn."""
    leaves = jax.tree.leaves(updates)
    n = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)

    treedef = jax.tree.structure(updates)
    shapes = [l.shape[1:] for l in leaves]
    sizes = [math.prod(s) if s else 1 for s in shapes]

    def unravel(vec):
        out, off = [], 0
        for s, sz in zip(shapes, sizes):
            out.append(vec[off:off + sz].reshape(s))
            off += sz
        return jax.tree.unflatten(treedef, out)

    return flat, unravel


def _as_matrix(fn):
    """Adapt a (N, D)->(D,) aggregator to accept pytrees too."""
    @functools.wraps(fn)
    def wrapped(updates, **kw):
        if isinstance(updates, jax.Array):
            return fn(updates, **kw)
        flat, unravel = _flatten_nodes(updates)
        return unravel(fn(flat, **kw))
    return wrapped


@_as_matrix
def mean(updates: Array) -> Array:
    return jnp.mean(updates, axis=0)


@_as_matrix
def coordinate_median(updates: Array) -> Array:
    return jnp.median(updates, axis=0)


@_as_matrix
def trimmed_mean(updates: Array, *, trim: int = 1) -> Array:
    n = updates.shape[0]
    trim = min(trim, (n - 1) // 2)
    s = jnp.sort(updates, axis=0)
    return jnp.mean(s[trim : n - trim], axis=0)


def _krum_scores(updates: Array, f: int) -> Array:
    """Krum score: sum of squared distances to the n-f-2 nearest neighbours."""
    n = updates.shape[0]
    d2 = jnp.sum(
        jnp.square(updates[:, None, :] - updates[None, :, :]), axis=-1)
    d2 = jnp.where(jnp.eye(n, dtype=bool),                # exclude self
                   jnp.asarray(jnp.inf, d2.dtype), d2)
    k = max(n - f - 2, 1)
    nearest = -jax.lax.top_k(-d2, k)[0]                  # k smallest
    return jnp.sum(nearest, axis=-1)


@_as_matrix
def krum(updates: Array, *, f: int = 1) -> Array:
    scores = _krum_scores(updates, f)
    return updates[jnp.argmin(scores)]


@_as_matrix
def multi_krum(updates: Array, *, f: int = 1, m: int = 0) -> Array:
    n = updates.shape[0]
    # clamp like masked_multi_krum: a static m can exceed the stack height
    # when membership shrinks (top_k would fail loudly mid-training)
    m = min(m or max(n - f - 2, 1), n)
    scores = _krum_scores(updates, f)
    _, idx = jax.lax.top_k(-scores, m)                   # m best (lowest) scores
    return jnp.mean(updates[idx], axis=0)


@_as_matrix
def centered_clip(updates: Array, *, clip_tau: float | None = None,
                  iters: int = 3, v0: Array | None = None) -> Array:
    """CenteredClip [40]:  v ← v + mean_i clip(x_i − v, τ), iterated.

    Provably robust aggregation with bounded error under < 1/2 byzantine
    fraction (with bounded honest variance).  ``v0`` warm-starts from the
    previous round's aggregate (as in [27]); the default warm start is the
    coordinate median (robust — a mean start can be pre-corrupted beyond
    τ·iters reach).  ``clip_tau=None`` adapts τ to the median node distance
    each iteration, so the clip radius tracks the gradient scale (a fixed
    τ=1 on gradients of norm ~100 would freeze v at its warm start).
    """
    v = (jnp.median(updates, axis=0) if v0 is None
         else v0.astype(jnp.float32))

    def body(v, _):
        diff = updates - v[None]
        norm = jnp.linalg.norm(diff, axis=-1, keepdims=True)
        tau = (jnp.median(norm) if clip_tau is None else clip_tau)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        return v + jnp.mean(diff * scale, axis=0), None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    return v


# -- masked (fixed-shape) variants ---------------------------------------------
# The batched swarm engine keeps a fixed (N, D) update stack across rounds and
# expresses membership/slashing as a boolean keep-mask, so the jitted round
# never changes shape on churn.  Each ``masked_*`` aggregator therefore must
# equal its dense counterpart applied to the compacted subset
# ``updates[mask]`` (property-tested in tests/test_scenarios.py); under total
# churn (``mask.sum() == 0``, no dense counterpart exists) the krum family
# and centered_clip return zeros — a no-op step.  The shared
# tricks: NaN-padding + ``nanmedian`` for medians, +inf-padding + rank masks
# for order statistics with a *traced* kept-count k.
#
# Numeric keyword arguments (``trim``, ``f``, ``m``, ``clip_tau``) accept
# traced jax scalars, so the campaign engine can vmap one compiled program
# over per-run values (e.g. krum's f tracking each run's attacker count).
# Structural kwargs (``iters``; ``clip_tau=None`` meaning "adaptive") stay
# static — they change the traced graph, not just its inputs.


def _masked_median(updates: Array, mask: Array) -> Array:
    # dtype-matched NaN fill and quantile: bare jnp.nan / nanmedian's
    # internal 0.5 are weak-typed and materialize weak buffers into the
    # program (analysis JX002).  nanquantile(0.5, method='midpoint') IS
    # nanmedian — same op, explicit dtype.
    padded = jnp.where(mask[:, None], updates,
                       jnp.asarray(jnp.nan, updates.dtype))
    return jnp.nanquantile(padded, jnp.asarray(0.5, updates.dtype),
                           axis=0, method="midpoint")


def masked_mean(updates: Array, mask: Array) -> Array:
    k = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return jnp.sum(updates * mask[:, None].astype(updates.dtype), axis=0) / k


def masked_coordinate_median(updates: Array, mask: Array) -> Array:
    return _masked_median(updates, mask)


def masked_trimmed_mean(updates: Array, mask: Array, *, trim: int = 1) -> Array:
    n = updates.shape[0]
    k = jnp.sum(mask.astype(jnp.int32))
    t = jnp.minimum(trim, (k - 1) // 2)
    s = jnp.sort(jnp.where(mask[:, None], updates,
                           jnp.asarray(jnp.inf, updates.dtype)), axis=0)
    ranks = jnp.arange(n)[:, None]
    keep = (ranks >= t) & (ranks < k - t)
    total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
    return total / jnp.maximum(k - 2 * t, 1).astype(updates.dtype)


def _krum_scores_from_d2(d2: Array, mask: Array, f: int) -> Array:
    """Krum's O(N²) selection phase given raw pairwise squared distances.

    Shared by the reference (broadcast d2) and the fused path (streamed
    gram-form d2 from ``kernels.masked_agg``) so selection semantics have a
    single source of truth.  Masked-out rows score +inf.
    """
    n = d2.shape[0]
    k_act = jnp.sum(mask.astype(jnp.int32))
    pair_ok = mask[:, None] & mask[None, :] & ~jnp.eye(n, dtype=bool)
    d2 = jnp.where(pair_ok, d2, jnp.asarray(jnp.inf, d2.dtype))
    k_near = jnp.maximum(k_act - f - 2, 1)
    s = jnp.sort(d2, axis=-1)                            # ascending per row
    nearest = jnp.where(jnp.arange(n)[None, :] < k_near, s, 0.0)
    scores = jnp.sum(nearest, axis=-1)
    # A kept row with no finite neighbour (k_act == 1) scores +inf like the
    # masked rows; cap kept scores below +inf so argmin/argsort can never
    # prefer a masked-out (slashed/inactive) row over a kept one.
    big = jnp.asarray(jnp.finfo(jnp.float32).max, scores.dtype)
    return jnp.where(mask, jnp.minimum(scores, big),
                     jnp.asarray(jnp.inf, scores.dtype))


def _masked_krum_scores(updates: Array, mask: Array, f: int) -> Array:
    """Krum scores over the kept subset; masked-out rows score +inf."""
    d2 = jnp.sum(jnp.square(updates[:, None, :] - updates[None, :, :]), axis=-1)
    return _krum_scores_from_d2(d2, mask, f)


def masked_krum(updates: Array, mask: Array, *, f: int = 1) -> Array:
    scores = _masked_krum_scores(updates, mask, f)
    row = updates[jnp.argmin(scores)]
    # Total churn (mask.sum() == 0): no update survives — define the
    # aggregate as zero (a no-op step) rather than whatever row argmin of
    # an all-inf score vector lands on.
    return jnp.where(jnp.any(mask), row, jnp.zeros_like(row))


def masked_multi_krum(updates: Array, mask: Array, *, f: int = 1, m: int = 0) -> Array:
    n = updates.shape[0]
    k_act = jnp.sum(mask.astype(jnp.int32))
    # clamp m to the kept count: score-sorted masked rows sit at the end but
    # hold real (corrupted/stale) updates, so selecting past k_act would
    # silently average them in (the dense twin fails loudly instead).
    # m may be a traced scalar; only a *static* 0/None means "auto".
    auto = m is None or (not isinstance(m, jax.Array) and m == 0)
    m_eff = (jnp.maximum(k_act - f - 2, 1) if auto
             else jnp.clip(jnp.asarray(m), 1, k_act))
    scores = _masked_krum_scores(updates, mask, f)
    order = jnp.argsort(scores)                          # best first, masked last
    sel = (jnp.arange(n) < m_eff)[:, None]
    out = jnp.sum(jnp.where(sel, updates[order], 0.0), axis=0) / m_eff.astype(updates.dtype)
    return jnp.where(jnp.any(mask), out, jnp.zeros_like(out))


def masked_centered_clip(updates: Array, mask: Array, *, clip_tau: float | None = None,
                         iters: int = 3, v0: Array | None = None) -> Array:
    k = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    v = (_masked_median(updates, mask) if v0 is None else v0.astype(jnp.float32))

    def body(v, _):
        diff = updates - v[None]
        norm = jnp.linalg.norm(diff, axis=-1, keepdims=True)
        tau = (jnp.nanquantile(
                   jnp.where(mask[:, None], norm,
                             jnp.asarray(jnp.nan, norm.dtype)),
                   jnp.asarray(0.5, norm.dtype), method="midpoint")
               if clip_tau is None else clip_tau)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        step = jnp.sum(diff * scale * mask[:, None].astype(jnp.float32), axis=0) / k
        return v + step, None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    # Total churn: the all-NaN warm start would propagate NaN through every
    # iteration — define the empty aggregate as zero (a no-op step).
    return jnp.where(jnp.any(mask), v, jnp.zeros_like(v))


MASKED_AGGREGATORS: Dict[str, Callable] = {
    "mean": masked_mean,
    "median": masked_coordinate_median,
    "trimmed_mean": masked_trimmed_mean,
    "krum": masked_krum,
    "multi_krum": masked_multi_krum,
    "centered_clip": masked_centered_clip,
}


def get_masked_aggregator(name: str, **defaults) -> Callable:
    """Masked twin of :func:`get_aggregator`: ``fn(updates, mask)`` where
    ``updates`` is (N, D) and ``mask`` marks the rows that participate."""
    fn = MASKED_AGGREGATORS[name]
    return functools.partial(fn, **defaults) if defaults else fn


AGGREGATORS: Dict[str, Callable] = {
    "mean": mean,
    "median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "multi_krum": multi_krum,
    "centered_clip": centered_clip,
}


def get_aggregator(name: str, **defaults) -> Callable:
    fn = AGGREGATORS[name]
    return functools.partial(fn, **defaults) if defaults else fn


def breakdown_point(name: str, n: int) -> float:
    """Max tolerated byzantine fraction (theory; validated empirically)."""
    return {
        "mean": 0.0,
        "median": 0.5,
        "trimmed_mean": 0.5,
        "krum": max(0.0, (n - 3) / (2 * n)),
        "multi_krum": max(0.0, (n - 3) / (2 * n)),
        "centered_clip": 0.5,
    }[name]
