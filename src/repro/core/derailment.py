"""The No-Off Problem & model-derailment attacks (paper §5.5).

The paper's core novel risk: a decentralized model cannot be unilaterally
halted.  The one *digital* emergency brake is a derailment attack — joining
the swarm and submitting destructive gradients.  Its effectiveness depends
on the aggregation rule and the verification regime:

- mean aggregation + no verification  → tiny attacker fractions derail
  (the off-switch works, but so does any vandal);
- robust aggregation                  → derailment needs ≥ breakdown-point
  fraction of the swarm;
- near-perfect cheap verification     → derailment is slashed away faster
  than it damages; the paper concludes only physical intervention remains.

``simulate_derailment`` measures one point on a real training run;
``sweep`` measures the whole **phase diagram** — every (attacker fraction,
scale, seed) cell of every (aggregator, verification) regime of a
``scenarios.SweepGrid`` — as **one** compiled device program (the campaign
engine: ``lax.scan`` over rounds, ``vmap`` over runs, regimes fused by
per-lane aggregator id and traced audit rate).
``attack_cost`` prices the attack (compute + slashed stakes);
``no_off_report`` assembles the paper's qualitative table quantitatively.
"""
from __future__ import annotations

import functools
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import economy, topology, unextractable
from repro.core.economy import EconomyConfig, EconomyResult, EconParams
from repro.core.placement import MeshPlan
from repro.core.scenarios import Regime, SweepGrid
from repro.core.swarm import (
    BEHAVIOUR_CODES,
    LaneParams,
    NodeSpec,
    SwarmConfig,
    make_swarm,
    run_campaign,
    stack_lanes,
)
from repro.core.verification import VerificationConfig

_FAR = np.iinfo(np.int32).max


@dataclass(frozen=True)
class DerailmentResult:
    attacker_fraction: float
    aggregator: str
    verified: bool
    final_loss: float
    baseline_loss: float
    attackers_slashed: int
    n_attackers: int
    init_loss: Optional[float] = None
    seed: int = 0
    regime: str = ""
    topology: str = ""      # "" = centralized; else a core.topology name
    staleness_bound: int = 0   # 0 = synchronous round; K = async, ring of K+1
    # -- custody axis (redundancy == 0 means the sweep had no custody lane)
    redundancy: int = 0
    coalition_fraction: float = 0.0
    coalition_coverage: float = 1.0   # shard fraction the coalition holds
    final_coverage: float = 1.0       # live swarm coverage at the last round
    extracted_loss: float = float("nan")  # reconstruct-attack eval loss

    @property
    def extractability(self) -> str:
        """The §4.1 regime of this cell ("" when no custody axis):

        - ``extractable``: the coalition covers every shard — custody
          failed, the reassembled model IS the model;
        - ``degraded``: the coalition cannot extract, but churn/slashing
          drained some shard's last live holder — nobody (including the
          swarm itself) holds the full model any more;
        - ``protocol_model``: the coalition is below full coverage and the
          swarm retains every shard — the §4.1 custody property holds.
        """
        if self.redundancy == 0:
            return ""
        if self.coalition_coverage >= 1.0 - 1e-9:
            return "extractable"
        if self.final_coverage < 1.0 - 1e-9:
            return "degraded"
        return "protocol_model"

    @property
    def derailed(self) -> bool:
        """Derailed = the run recovered less than half the honest learning
        progress (catches both divergence AND saturation-stall attacks,
        where the loss freezes near init while gradients vanish)."""
        if not np.isfinite(self.final_loss):
            return True
        if self.init_loss is not None and np.isfinite(self.init_loss) \
                and self.init_loss > self.baseline_loss:
            half = self.baseline_loss + 0.5 * (self.init_loss - self.baseline_loss)
            return bool(self.final_loss > half)
        return bool(self.final_loss > 1.5 * self.baseline_loss + 0.5)


def make_swarm_nodes(n_honest: int, n_attack: int, attack: str = "inner_product",
                     scale: float = 50.0, delay: int = 0):
    nodes = [NodeSpec(f"h{i}", delay=delay) for i in range(n_honest)]
    nodes += [NodeSpec(f"adv{i}", byzantine=attack, byzantine_scale=scale,
                       delay=delay)
              for i in range(n_attack)]
    return nodes


def simulate_derailment(loss_fn, init_params, optimizer, data_fn, eval_fn, *,
                        n_honest: int, n_attack: int, rounds: int,
                        aggregator: str = "mean",
                        verification: Optional[VerificationConfig] = None,
                        attack: str = "inner_product", scale: float = 50.0,
                        baseline_loss: Optional[float] = None,
                        topology: Optional[str] = None,
                        staleness_bound: int = 0,
                        seed: int = 0, engine: str = "batched") -> DerailmentResult:
    """Measure a single derailment point.

    Pass ``baseline_loss`` when sweeping many points against one honest
    baseline — otherwise *each call* re-trains the honest swarm from
    scratch.  ``topology`` (a ``core.topology`` name) runs the point in the
    decentralized round — the baseline is then trained on the *same*
    topology so the result isolates the attack, not the graph.
    ``staleness_bound=K > 0`` runs the point in the bounded-staleness async
    round (every node may gradient against a snapshot up to K rounds old);
    the baseline then runs at the same bound, so the ratio isolates the
    attack, not the asynchrony.  For whole phase diagrams use
    :func:`sweep`, which shares the baseline and compiles every point of
    every regime into one program.
    """
    init_loss = float(eval_fn(init_params))
    nodes = make_swarm_nodes(n_honest, n_attack, attack, scale,
                             delay=staleness_bound)
    cfg = SwarmConfig(aggregator=aggregator, verification=verification, seed=seed,
                      topology=topology, staleness_bound=staleness_bound,
                      agg_kwargs={"f": max(1, n_attack)} if "krum" in aggregator else {})
    swarm = make_swarm(loss_fn, init_params, optimizer, nodes, cfg, data_fn,
                       engine=engine)
    losses = swarm.run(rounds, eval_fn=eval_fn, eval_every=max(1, rounds // 5))

    if baseline_loss is None:
        base_nodes = [NodeSpec(f"h{i}", delay=staleness_bound)
                      for i in range(n_honest)]
        if topology is not None:
            # keep the mixing graph the SAME SIZE as the attacked swarm's:
            # attacker slots ride as never-joining relays, so the ratio
            # isolates the attack rather than a smaller (different-gap)
            # graph — exactly how sweep()'s count=0 baseline lanes work
            base_nodes += [NodeSpec(f"adv{i}", join_round=_FAR)
                           for i in range(n_attack)]
        base = make_swarm(loss_fn, init_params, optimizer, base_nodes,
                          SwarmConfig(aggregator="mean", seed=seed,
                                      topology=topology,
                                      staleness_bound=staleness_bound),
                          data_fn, engine=engine)
        baseline_loss = base.run(rounds, eval_fn=eval_fn, eval_every=rounds)[-1]

    return DerailmentResult(
        attacker_fraction=n_attack / (n_honest + n_attack),
        aggregator=aggregator,
        verified=verification is not None,
        final_loss=losses[-1],
        baseline_loss=baseline_loss,
        attackers_slashed=sum(1 for s in swarm.slashed if s.startswith("adv")),
        n_attackers=n_attack,
        init_loss=init_loss,
        seed=seed,
        regime=aggregator + ("+verified" if verification else ""),
        topology=topology or "",
        staleness_bound=staleness_bound,
    )


# -- the phase-diagram sweep -----------------------------------------------------
@dataclass
class SweepResult:
    """Every cell of a :class:`~repro.core.scenarios.SweepGrid`, plus how it
    was compiled (``n_programs`` device programs for ``n_runs`` runs —
    baseline lanes included) and how long the whole sweep took."""
    grid: SweepGrid
    results: List[DerailmentResult]
    n_programs: int
    n_runs: int
    wall_s: float
    n_devices: int = 1          # devices the sweep's mesh plan spanned
    econ_results: List[EconomyResult] = field(default_factory=list)

    @property
    def runs_per_s(self) -> float:
        return self.n_runs / max(self.wall_s, 1e-9)

    def economy_phase_table(self, regime: str, *, adaptive: bool = False) -> str:
        """The §4 incentive phase diagram (identity cost rows × fee
        columns, S/D/C cells) — see :func:`economy.phase_table`."""
        return economy.phase_table(self.econ_results, regime=regime,
                                   adaptive=adaptive)

    def economy_adaptive_gap(self) -> Dict[str, float]:
        """The fixed-vs-adaptive gap over matched economy cells — see
        :func:`economy.adaptive_gap`."""
        return economy.adaptive_gap(self.econ_results)

    def phase_table(self) -> str:
        """The §5.5 phase diagram: derailed-seed counts per (regime [,
        topology][, staleness bound], attacker fraction) cell,
        attackers-slashed appended when any.  Topology-axis sweeps get one
        row per (regime, topology), labelled ``regime@topology``;
        staleness-axis sweeps one row per bound, labelled ``... s=K``."""
        fracs = sorted({r.attacker_fraction for r in self.results})
        sbounds: Tuple = self.grid.staleness_bounds or (None,)
        rows: List[Tuple[str, str, Optional[int]]] = []
        for reg in self.grid.regimes:
            for topo in (self.grid.topologies or ("",)):
                for sb in sbounds:
                    if any(r.regime == reg.name and r.topology == topo
                           and (sb is None or r.staleness_bound == sb)
                           for r in self.results):
                        rows.append((reg.name, topo, sb))
        labels = [reg + (f"@{topo}" if topo else "")
                  + (f" s={sb}" if sb is not None else "")
                  for reg, topo, sb in rows]
        width = max([22] + [len(l) + 2 for l in labels])
        head = "regime".ljust(width) + "".join(f"frac={f:.2f}".rjust(12)
                                               for f in fracs)
        lines = [head]
        for (reg, topo, sb), label in zip(rows, labels):
            cells = []
            for f in fracs:
                cell = [r for r in self.results
                        if r.regime == reg and r.topology == topo
                        and (sb is None or r.staleness_bound == sb)
                        and abs(r.attacker_fraction - f) < 1e-9]
                if not cell:
                    cells.append("-".rjust(12))
                    continue
                der = sum(r.derailed for r in cell)
                txt = f"{der}/{len(cell)}"
                slashed = sum(r.attackers_slashed for r in cell)
                if slashed:
                    txt += f" s{slashed}"
                cells.append(txt.rjust(12))
            lines.append(label.ljust(width) + "".join(cells))
        return "\n".join(lines)

    def extractability_table(self) -> str:
        """The §4.1 extractability phase table: one row per (regime [,
        topology], redundancy), one column per coalition fraction; each
        cell shows the regime letter per (seed × count × scale) cell —
        P = protocol_model, X = extractable, D = degraded — plus the mean
        coalition shard coverage."""
        cust = [r for r in self.results if r.redundancy > 0]
        if not cust:
            return "(no custody axis in this sweep)"
        fracs = sorted({r.coalition_fraction for r in cust})
        rows = sorted({(r.regime, r.topology, r.redundancy) for r in cust})
        labels = [reg + (f"@{topo}" if topo else "") + f" r={red}"
                  for reg, topo, red in rows]
        width = max([24] + [len(l) + 2 for l in labels])
        head = "custody".ljust(width) + "".join(f"coal={f:.2f}".rjust(16)
                                                for f in fracs)
        code = {"protocol_model": "P", "extractable": "X", "degraded": "D"}
        lines = [head]
        for (reg, topo, red), label in zip(rows, labels):
            cells = []
            for f in fracs:
                cell = [r for r in cust
                        if r.regime == reg and r.topology == topo
                        and r.redundancy == red
                        and abs(r.coalition_fraction - f) < 1e-9]
                if not cell:
                    cells.append("-".rjust(16))
                    continue
                marks = "".join(code[r.extractability] for r in cell)
                cov = sum(r.coalition_coverage for r in cell) / len(cell)
                cells.append(f"{marks} cov={cov:.2f}".rjust(16))
            lines.append(label.ljust(width) + "".join(cells))
        lines.append("(P=protocol_model  X=extractable  D=degraded, one "
                     "letter per cell; cov = coalition shard coverage)")
        return "\n".join(lines)


@functools.lru_cache(maxsize=None)
def _seed_key(seed: int):
    return jax.random.PRNGKey(seed)


def _sweep_lane(n_total: int, n_honest: int, count: int, code: int,
                scale: float, seed: int,
                v: Optional[VerificationConfig],
                agg_id: int, agg_kwargs: Dict,
                mixing: Optional[np.ndarray] = None,
                leaves: Optional[np.ndarray] = None,
                custody: Optional[np.ndarray] = None,
                coalition: Optional[np.ndarray] = None,
                delays: Optional[np.ndarray] = None,
                econ: Optional[EconParams] = None) -> LaneParams:
    """One run lane: honest nodes first, ``count`` attackers, then padding
    that never joins (all regimes share a fixed N so they vmap together).
    Node indices — and therefore the fold_in key schedule — match the
    single-run ``Swarm`` built by ``simulate_derailment`` exactly.  Leaves
    are host (numpy) arrays — a sweep builds hundreds of lanes and
    ``stack_lanes`` moves each stacked field to device once.  ``mixing``
    (decentralized sweeps) is this lane's topology matrix over ALL
    ``n_total`` slots; padding slots then sit in the graph as silent
    relays — they mix and update but never contribute (their keep bit
    stays off).  That holds the graph fixed across attacker counts (the
    axis stays interpretable), which means decentralized cells equal their
    ``simulate_derailment(topology=...)`` twin — whose graph spans its own
    roster — only at ``count == max(attacker_counts)``, where the sizes
    coincide (pinned in tests/test_topology.py).  ``leaves`` (custody-churn
    sweeps) overrides the default never-leave schedule; ``custody`` /
    ``coalition`` are this lane's (n_total, S) custody matrix and (n_total,)
    extraction-coalition mask (padding rows hold nothing / join nothing).
    ``delays`` (async sweeps) is this lane's (n_total,) per-node staleness
    cap — a *traced* lane, so every bound of the staleness axis shares the
    one program compiled for the max bound's snapshot ring.  ``econ``
    (economy sweeps) is this lane's traced :class:`EconParams` — every
    incentive knob (and the adaptive flag) is lane data, so the whole
    incentive grid shares one program too."""
    codes = np.zeros(n_total, np.int32)
    codes[n_honest:n_honest + count] = code
    scales = np.full(n_total, 10.0, np.float32)     # NodeSpec default
    scales[n_honest:n_honest + count] = scale
    joins = np.zeros(n_total, np.int32)
    joins[n_honest + count:] = _FAR                  # padding: never active
    return LaneParams(
        codes=codes,
        scales=scales,
        speeds=np.ones(n_total, np.float32),
        joins=joins,
        leaves=(np.full(n_total, _FAR, np.int32) if leaves is None
                else leaves),
        custody=custody,
        coalition=coalition,
        delays=delays,
        econ=econ,
        base_key=_seed_key(seed),
        p_check=np.float32(v.p_check if v else 0.0),
        tolerance=np.float32(v.tolerance if v else 1.0),
        numeric_noise=np.float32(v.numeric_noise if v else 0.0),
        agg_id=np.int32(agg_id),
        agg_kwargs={k: np.asarray(x) for k, x in agg_kwargs.items()},
        mixing=mixing,
    )


@dataclass
class SweepProgramSpec:
    """Everything :func:`sweep` feeds the campaign engine, built without
    running anything: the lane list (host arrays — ``swarm.stack_lanes``
    moves them to device once), per-lane metadata, the shared aggregator
    set, and the post-processing helpers.  Split out of :func:`sweep` so
    ``analysis.jaxpr_audit`` traces the *real* sweep program — the same
    lanes, the same multi-aggregator round — instead of a reimplementation
    that could drift."""
    lanes: List[LaneParams]
    metas: List[tuple]
    agg_specs: List[Tuple[str, Dict]]
    verify: bool
    has_custody: bool
    n_honest: int
    n_total: int
    coalition_coverage: Callable[[int, float, int], float]

    @property
    def aggregator(self):
        """The ``aggregator`` argument for ``run_campaign`` — the full
        (name, kwargs) set when several regimes share the program."""
        return (self.agg_specs if len(self.agg_specs) > 1
                else self.agg_specs[0][0])

    @property
    def agg_kwargs(self) -> Optional[Dict]:
        return self.agg_specs[0][1] if len(self.agg_specs) == 1 else None


def build_sweep_lanes(grid: SweepGrid, *,
                      rounds: Optional[int] = None) -> SweepProgramSpec:
    """Build every lane of a :class:`~repro.core.scenarios.SweepGrid`'s
    phase diagram — the grid cells, plus the shared honest baselines —
    without running anything.  See :class:`SweepProgramSpec`."""
    rounds = grid.rounds if rounds is None else rounds
    n_honest = grid.n_honest
    n_total = n_honest + max(grid.attacker_counts)
    code = BEHAVIOUR_CODES[grid.attack]

    # the aggregator set shared by the fused program; the honest baseline is
    # a mean-aggregated run, so make sure plain mean is in the set
    agg_specs: List[Tuple[str, Dict]] = []
    agg_index: Dict[Tuple, int] = {}
    for reg in list(grid.regimes) + [Regime("baseline", "mean")]:
        key = (reg.aggregator, tuple(sorted(reg.agg_kwargs.items())))
        if key not in agg_index:
            agg_index[key] = len(agg_specs)
            agg_specs.append((reg.aggregator, dict(reg.agg_kwargs)))
    # krum aggregators read a traced per-run f (tracking the attacker count,
    # as simulate_derailment does); the traced-kwargs dict must be present
    # on every lane whenever any aggregator in the set wants it
    need_f = any("krum" in name and "f" not in kw for name, kw in agg_specs)

    def traced_kw(count):
        return {"f": max(1, count)} if need_f else {}

    # the decentralized axis: one Metropolis matrix per named topology over
    # all n_total slots (padding slots are silent relays — see _sweep_lane);
    # topology is a *traced lane*, so the whole axis shares one program
    topos = grid.topologies or ("",)
    mixings = {t: (topology.mixing_matrix(t, n_total, seed=0)
                   .astype(np.float32) if t else None) for t in topos}

    # the custody axis (§4.1): one custody matrix per (redundancy, count) —
    # assigned over the slots that actually join (padding rows hold
    # nothing), drawn with seed 0 like the topology axis (run seeds vary
    # noise and churn, never who holds what) — and one coalition mask per
    # (fraction, count): the last ceil(frac * roster) joined slots, i.e.
    # attackers first.  Both ride as traced lanes, so the whole
    # (redundancy x coalition x seed) grid shares the one program.
    has_custody = grid.has_custody
    reds = (grid.redundancies or (2,)) if has_custody else (0,)
    cfracs = (grid.coalition_fractions or (0.0,)) if has_custody else (0.0,)

    # the asynchrony axis: per-node staleness caps ride as a traced lane
    # (swarm.make_campaign_program sizes the snapshot ring by the MAX cap
    # across all lanes, so every bound — including 0 — shares one compiled
    # program); grids without the axis pass delays=None and keep the
    # synchronous round bit-exactly as before
    has_async = bool(grid.staleness_bounds)
    sbounds = grid.staleness_bounds if has_async else (0,)

    @functools.lru_cache(maxsize=None)
    def delays_for(bound: int, count: int) -> Optional[np.ndarray]:
        if not has_async:
            return None
        d = np.zeros(n_total, np.int32)
        d[:n_honest + count] = bound
        return d

    # the economy axes (§4): identity cost, fee inflow, reward schedule and
    # the adaptive flag all ride inside the traced EconParams lane, so the
    # whole incentive grid shares the one program.  The lane's attacker
    # slots double as the strategic coalition, funded from one grid-level
    # capital budget (the Sybil identity count is derived in-program);
    # baseline lanes carry the first knob combo with an empty coalition —
    # fee/reward flows never touch gradients, so one baseline per
    # (topology, staleness bound, seed) still serves every economy cell.
    has_econ = grid.has_economy
    icosts = (grid.identity_costs or (1.0,)) if has_econ else (None,)
    efees = (grid.fees or (1.0,)) if has_econ else (None,)
    scheds = (grid.reward_schedules or ((0.1, 5.0),)) if has_econ else (None,)
    adapts = (grid.adaptive or (False,)) if has_econ else (None,)

    @functools.lru_cache(maxsize=None)
    def econ_for(icost, fee, sched, adp, count) -> Optional[EconParams]:
        if not has_econ:
            return None
        coal = np.zeros(n_total, bool)
        coal[n_honest:n_honest + count] = True
        return EconomyConfig(
            identity_cost=icost, budget=grid.econ_budget,
            min_stake=grid.econ_min_stake, fee_income=fee,
            reward_rate=sched[0], op_cost=grid.econ_op_cost,
            jackpot=sched[1], honest_reserve=grid.econ_reserve,
            adaptive=adp).params_for(coal)

    @functools.lru_cache(maxsize=None)
    def custody_for(red: int, count: int) -> Optional[np.ndarray]:
        if not has_custody:
            return None
        full = np.zeros((n_total, grid.num_shards), bool)
        full[:n_honest + count] = unextractable.assign_matrix(
            n_honest + count, grid.num_shards, red, seed=0,
            max_fraction=grid.custody_max_fraction)
        return full

    @functools.lru_cache(maxsize=None)
    def coalition_for(frac: float, count: int) -> Optional[np.ndarray]:
        if not has_custody:
            return None
        mask = np.zeros(n_total, bool)
        mask[:n_honest + count] = unextractable.coalition_tail_mask(
            n_honest + count, frac)
        return mask

    @functools.lru_cache(maxsize=None)
    def leaves_for(seed: int) -> Optional[np.ndarray]:
        """Custody-churn schedule: ``custody_leave_fraction`` of the honest
        roster leaves on staggered rounds in the back two thirds of the
        run, drawn per seed — what starves low-redundancy cells into the
        'degraded' regime.  Gated on the custody axis: without it the
        results carry no coverage columns, so silent churn would just make
        losses inexplicably differ from the same grid without the field."""
        if grid.custody_leave_fraction <= 0 or not has_custody:
            return None
        lv = np.full(n_total, _FAR, np.int32)
        k = min(n_honest - 1, int(grid.custody_leave_fraction * n_honest))
        rng = np.random.default_rng(10_000 + seed)
        start = max(1, rounds // 3)
        for j, i in enumerate(sorted(rng.choice(n_honest, k, replace=False))):
            lv[int(i)] = start + j % max(1, rounds - start)
        return lv

    lanes, metas = [], []
    econ_combos = list(itertools.product(icosts, efees, scheds, adapts))
    for reg in grid.regimes:
        aid = agg_index[(reg.aggregator, tuple(sorted(reg.agg_kwargs.items())))]
        for topo in topos:
            for sbound in sbounds:
                for red in reds:
                    for cfrac in cfracs:
                        for icost, fee, sched, adp in econ_combos:
                            for count in grid.attacker_counts:
                                for scale in grid.scales:
                                    for seed in grid.seeds:
                                        lanes.append(_sweep_lane(
                                            n_total, n_honest, count, code,
                                            scale, seed, reg.verification,
                                            aid, traced_kw(count),
                                            mixing=mixings[topo],
                                            leaves=leaves_for(seed),
                                            custody=custody_for(red, count),
                                            coalition=coalition_for(cfrac,
                                                                    count),
                                            delays=delays_for(sbound, count),
                                            econ=econ_for(icost, fee, sched,
                                                          adp, count)))
                                        metas.append((reg, topo, sbound, red,
                                                      cfrac, count, scale,
                                                      seed, icost, fee,
                                                      sched, adp))
    for topo in topos:                  # baseline lanes (count = 0), shared
        for sbound in sbounds:          # per (topology, staleness bound,
            for seed in grid.seeds:     # seed) — async baselines run at the
                lanes.append(_sweep_lane(   # same bound, so the ratio
                    n_total, n_honest, 0, code, 0.0, seed, None,  # isolates
                    agg_index[("mean", ())], traced_kw(0),  # the attack,
                    mixing=mixings[topo], leaves=leaves_for(seed),  # not
                    custody=custody_for(reds[0], 0),        # the asynchrony
                    coalition=coalition_for(0.0, 0),
                    delays=delays_for(sbound, 0),
                    econ=econ_for(icosts[0], efees[0], scheds[0], False, 0)))
                metas.append((None, topo, sbound, reds[0], 0.0, 0, 0.0, seed,
                              icosts[0], efees[0], scheds[0], False))

    def coalition_coverage(red, cfrac, count) -> float:
        cov = custody_for(red, count) & coalition_for(cfrac, count)[:, None]
        return float(cov.any(axis=0).mean())

    return SweepProgramSpec(
        lanes=lanes, metas=metas, agg_specs=agg_specs,
        verify=any(reg.verification is not None for reg in grid.regimes),
        has_custody=has_custody, n_honest=n_honest, n_total=n_total,
        coalition_coverage=coalition_coverage)


def sweep(loss_fn, init_params, optimizer, data_fn, eval_fn,
          grid: SweepGrid, *, rounds: Optional[int] = None,
          fast_compile: Optional[bool] = None,
          plan: Optional[MeshPlan] = None) -> SweepResult:
    """Measure a whole §5.5 phase diagram as **one** compiled device program.

    Every (regime × topology × attacker count × scale × seed) cell is a
    lane of a single campaign: verification differences ride in the traced
    ``p_check``/``tolerance`` lanes (``p_check=0`` disables audits),
    aggregator differences in the ``agg_id`` lane of a multi-aggregator
    round (the gradient / corruption / audit machinery — the bulk of the
    compile cost — is shared), topology differences in the traced
    ``mixing`` lane of the decentralized round (``grid.topologies``
    non-empty — every lane then runs per-node replicas + neighborhood
    aggregation + gossip mixing), custody differences in the traced
    ``custody``/``coalition`` lanes (``grid.redundancies`` /
    ``grid.coalition_fractions`` non-empty — every lane then records the
    live coverage frontier and evals the reconstruct attack, feeding
    :meth:`SweepResult.extractability_table`), and the honest baseline
    rides along as extra ``count=0`` lanes, computed once per (topology,
    seed) instead of once per point.  Lane building lives in
    :func:`build_sweep_lanes` (also what ``analysis.jaxpr_audit`` traces).

    ``fast_compile=None`` decides automatically: tiny models (≤ 4096
    params) are compile-bound, so they get XLA's fast/low-optimization
    backend (~3x faster compiles, bit-identical here); larger models are
    runtime-bound and keep full optimization — the unfused fast path costs
    far more in memory traffic than it saves in compilation (see
    :func:`~repro.core.swarm.run_campaign`).

    ``data_fn`` and ``eval_fn`` must be jax-traceable (the fold_in-keyed
    pipelines in this repo all are).  Each result lane reproduces the
    single-point :func:`simulate_derailment` run for the same parameters —
    property-tested in ``tests/test_campaign.py``.

    ``plan`` (a :class:`~repro.core.placement.MeshPlan`, e.g.
    ``MeshPlan.from_grid(grid)``) shards the sweep's lanes across the
    plan's mesh — the whole phase diagram still compiles to ONE program,
    now spanning ``plan.n_devices`` devices.  Lane sharding is bit-exact
    for centralized grids (allclose on topology-axis grids — the gossip
    matmul's reductions reorder under a mesh; see ``core/placement.py``).
    """
    rounds = grid.rounds if rounds is None else rounds
    if fast_compile is None:
        n_params = sum(l.size for l in jax.tree.leaves(init_params))
        fast_compile = n_params <= 4096
    t0 = time.perf_counter()
    init_loss = float(eval_fn(init_params))
    spec = build_sweep_lanes(grid, rounds=rounds)
    n_honest, has_custody = spec.n_honest, spec.has_custody

    state, recs, final = run_campaign(
        loss_fn, init_params, optimizer, data_fn, stack_lanes(spec.lanes),
        rounds=rounds, aggregator=spec.aggregator,
        agg_kwargs=spec.agg_kwargs, verify=spec.verify,
        eval_fn=eval_fn, fast_compile=fast_compile, plan=plan)
    slashed = np.asarray(state.slashed)
    final = np.asarray(final)               # (R,) — or (R, 2) with custody:
    if has_custody:                         # [honest, reconstruct-attack]
        honest_final, extracted_final = final[:, 0], final[:, 1]
        last_coverage = np.asarray(recs.coverage)[:, -1]
    else:
        honest_final = final

    results_raw = []
    baselines: Dict[Tuple[str, int, int], float] = {}
    for j, (reg, topo, sb, red, cfrac, count, scale, seed,
            icost, fee, sched, adp) in enumerate(spec.metas):
        if reg is None:
            baselines[(topo, sb, seed)] = float(honest_final[j])
        else:
            results_raw.append((j, reg, topo, sb, red, cfrac, count, scale,
                                seed, icost, fee, sched, adp))

    results = [DerailmentResult(
        attacker_fraction=count / (n_honest + count) if count else 0.0,
        aggregator=reg.aggregator,
        verified=reg.verification is not None,
        final_loss=float(honest_final[j]),
        baseline_loss=baselines[(topo, sb, seed)],
        attackers_slashed=int(slashed[j, n_honest:n_honest + count].sum()),
        n_attackers=count,
        init_loss=init_loss,
        seed=seed,
        regime=reg.name,
        topology=topo,
        staleness_bound=sb,
        redundancy=red if has_custody else 0,
        coalition_fraction=cfrac,
        coalition_coverage=(spec.coalition_coverage(red, cfrac, count)
                            if has_custody else 1.0),
        final_coverage=float(last_coverage[j]) if has_custody else 1.0,
        extracted_loss=(float(extracted_final[j]) if has_custody
                        else float("nan")),
    ) for j, reg, topo, sb, red, cfrac, count, scale, seed, *_ in results_raw]

    # -- the incentive phase diagram: one EconomyResult per measured lane --
    econ_results: List[EconomyResult] = []
    if grid.has_economy:
        keep = np.asarray(recs.keep)                          # (L, R, N)
        n_act = np.asarray(recs.n_active)                     # (L, R)
        coal_tr = np.asarray(recs.coalition_stake)            # (L, R)
        pay = np.asarray(economy.payoff(state.econ))          # (L, N)
        for (j, reg, topo, sb, red, cfrac, count, scale, seed,
             icost, fee, sched, adp) in results_raw:
            hp = float(pay[j, :n_honest].mean())
            cp = (float(pay[j, n_honest:n_honest + count].mean())
                  if count else 0.0)
            econ_results.append(EconomyResult(
                regime=reg.name, identity_cost=icost, fee=fee,
                reward_rate=sched[0], jackpot=sched[1], adaptive=adp,
                coalition_size=count, seed=seed,
                outcome=economy.classify_outcome(
                    honest_active_first=int(keep[j, 0, :n_honest].sum()),
                    honest_active_last=int(keep[j, -1, :n_honest].sum()),
                    coalition_stake_last=float(coal_tr[j, -1]),
                    honest_payoff_mean=hp),
                honest_payoff=hp, coalition_payoff=cp,
                coalition_stake_share=float(coal_tr[j, -1]),
                n_admitted_first=int(n_act[j, 0]),
                n_admitted_last=int(n_act[j, -1]),
                final_loss=float(honest_final[j])))
    return SweepResult(grid=grid, results=results, n_programs=1,
                       n_runs=len(spec.lanes), wall_s=time.perf_counter() - t0,
                       n_devices=plan.n_devices if plan is not None else 1,
                       econ_results=econ_results)


# -- economics -------------------------------------------------------------------
def attack_cost(n_attackers: int, rounds: int, *, compute_cost_per_round: float,
                verification: Optional[VerificationConfig]) -> float:
    """Price of running the derailment: compute + expected slashed stakes.

    With stake/slash verification each attacker's stake is destroyed with
    prob p_check each round; expected rounds to slash = 1/p_check, so the
    attacker re-stakes ~ rounds·p_check times.
    """
    compute = n_attackers * rounds * compute_cost_per_round
    if verification is None:
        return compute
    expected_slashes = n_attackers * min(rounds * verification.p_check, rounds)
    return compute + expected_slashes * verification.stake


def no_off_report(results) -> str:
    """Render the §5.5 analysis from a list of DerailmentResult (a topology
    column appears when any result is decentralized; custody columns —
    redundancy, coalition coverage, extractability regime, and the
    reconstruct-attack loss relative to the honest loss — when any result
    carries the custody axis)."""
    topo = any(r.topology for r in results)
    cust = any(r.redundancy for r in results)
    head = "attacker_frac  aggregator      "
    head += "topology          " if topo else ""
    head += "verified  derailed  slashed  final/baseline"
    head += "  r  coal_cov  extractability  extracted/honest" if cust else ""
    lines = [head]
    for r in results:
        t = f"{r.topology or 'centralized':16s}  " if topo else ""
        line = (
            f"{r.attacker_fraction:12.2f}  {r.aggregator:14s}  {t}"
            f"{str(r.verified):8s}"
            f"  {str(r.derailed):8s}  {r.attackers_slashed}/{r.n_attackers:<6d}"
            f"  {r.final_loss / max(r.baseline_loss, 1e-9):6.2f}")
        if cust:
            line += (f"  {r.redundancy}  {r.coalition_coverage:8.2f}"
                     f"  {r.extractability:14s}"
                     f"  {r.extracted_loss / max(r.final_loss, 1e-9):8.1f}")
        lines.append(line)
    return "\n".join(lines)
