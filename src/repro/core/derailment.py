"""The No-Off Problem & model-derailment attacks (paper §5.5).

The paper's core novel risk: a decentralized model cannot be unilaterally
halted.  The one *digital* emergency brake is a derailment attack — joining
the swarm and submitting destructive gradients.  Its effectiveness depends
on the aggregation rule and the verification regime:

- mean aggregation + no verification  → tiny attacker fractions derail
  (the off-switch works, but so does any vandal);
- robust aggregation                  → derailment needs ≥ breakdown-point
  fraction of the swarm;
- near-perfect cheap verification     → derailment is slashed away faster
  than it damages; the paper concludes only physical intervention remains.

``simulate_derailment`` measures this on a real training run;
``attack_cost`` prices the attack (compute + slashed stakes); ``no_off_report``
assembles the paper's qualitative table quantitatively.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.swarm import NodeSpec, SwarmConfig, make_swarm
from repro.core.verification import VerificationConfig


@dataclass(frozen=True)
class DerailmentResult:
    attacker_fraction: float
    aggregator: str
    verified: bool
    final_loss: float
    baseline_loss: float
    attackers_slashed: int
    n_attackers: int
    init_loss: Optional[float] = None

    @property
    def derailed(self) -> bool:
        """Derailed = the run recovered less than half the honest learning
        progress (catches both divergence AND saturation-stall attacks,
        where the loss freezes near init while gradients vanish)."""
        if not np.isfinite(self.final_loss):
            return True
        if self.init_loss is not None and np.isfinite(self.init_loss) \
                and self.init_loss > self.baseline_loss:
            half = self.baseline_loss + 0.5 * (self.init_loss - self.baseline_loss)
            return bool(self.final_loss > half)
        return bool(self.final_loss > 1.5 * self.baseline_loss + 0.5)


def make_swarm_nodes(n_honest: int, n_attack: int, attack: str = "inner_product",
                     scale: float = 50.0):
    nodes = [NodeSpec(f"h{i}") for i in range(n_honest)]
    nodes += [NodeSpec(f"adv{i}", byzantine=attack, byzantine_scale=scale)
              for i in range(n_attack)]
    return nodes


def simulate_derailment(loss_fn, init_params, optimizer, data_fn, eval_fn, *,
                        n_honest: int, n_attack: int, rounds: int,
                        aggregator: str = "mean",
                        verification: Optional[VerificationConfig] = None,
                        attack: str = "inner_product", scale: float = 50.0,
                        baseline_loss: Optional[float] = None,
                        seed: int = 0, engine: str = "batched") -> DerailmentResult:
    init_loss = float(eval_fn(init_params))
    nodes = make_swarm_nodes(n_honest, n_attack, attack, scale)
    cfg = SwarmConfig(aggregator=aggregator, verification=verification, seed=seed,
                      agg_kwargs={"f": max(1, n_attack)} if "krum" in aggregator else {})
    swarm = make_swarm(loss_fn, init_params, optimizer, nodes, cfg, data_fn,
                       engine=engine)
    losses = swarm.run(rounds, eval_fn=eval_fn, eval_every=max(1, rounds // 5))

    if baseline_loss is None:
        base = make_swarm(loss_fn, init_params, optimizer,
                          [NodeSpec(f"h{i}") for i in range(n_honest)],
                          SwarmConfig(aggregator="mean", seed=seed), data_fn,
                          engine=engine)
        baseline_loss = base.run(rounds, eval_fn=eval_fn, eval_every=rounds)[-1]

    return DerailmentResult(
        attacker_fraction=n_attack / (n_honest + n_attack),
        aggregator=aggregator,
        verified=verification is not None,
        final_loss=losses[-1],
        baseline_loss=baseline_loss,
        attackers_slashed=sum(1 for s in swarm.slashed if s.startswith("adv")),
        n_attackers=n_attack,
        init_loss=init_loss,
    )


# -- economics -------------------------------------------------------------------
def attack_cost(n_attackers: int, rounds: int, *, compute_cost_per_round: float,
                verification: Optional[VerificationConfig]) -> float:
    """Price of running the derailment: compute + expected slashed stakes.

    With stake/slash verification each attacker's stake is destroyed with
    prob p_check each round; expected rounds to slash = 1/p_check, so the
    attacker re-stakes ~ rounds·p_check times.
    """
    compute = n_attackers * rounds * compute_cost_per_round
    if verification is None:
        return compute
    expected_slashes = n_attackers * min(rounds * verification.p_check, rounds)
    return compute + expected_slashes * verification.stake


def no_off_report(results) -> str:
    """Render the §5.5 analysis from a list of DerailmentResult."""
    lines = ["attacker_frac  aggregator      verified  derailed  slashed  final/baseline"]
    for r in results:
        lines.append(
            f"{r.attacker_fraction:12.2f}  {r.aggregator:14s}  {str(r.verified):8s}"
            f"  {str(r.derailed):8s}  {r.attackers_slashed}/{r.n_attackers:<6d}"
            f"  {r.final_loss / max(r.baseline_loss, 1e-9):6.2f}")
    return "\n".join(lines)
