"""Scenario registry: named, reproducible swarm configurations.

The paper's claims are claims about *regimes* — honest swarms, byzantine
minorities, collusion, churn, heterogeneous capacity, lossy wires, audit
economics, derailment attacks, and (since the topology engine) fully
decentralized gossip regimes, and (since the custody engine) Protocol-Model
custody regimes.  Rather than every benchmark, example, and test
hand-rolling its own ``NodeSpec`` list, this module registers ~13 named
scenarios that all of them consume, so results are comparable across
entry points and documented in one place (``docs/scenarios.md``).

A :class:`Scenario` is a factory: it scales to any node count and builds
either the raw ``(nodes, SwarmConfig)`` pair or a ready-to-run swarm on
either engine.

Usage::

    from repro.core.scenarios import get_scenario, list_scenarios

    scenario = get_scenario("sign_flip_minority")
    nodes, cfg = scenario.build(n_nodes=16, seed=0)

    # or go straight to a batched swarm:
    swarm = scenario.build_swarm(loss_fn, params, optimizer, data_fn,
                                 n_nodes=16)
    swarm.run(rounds=50, eval_fn=eval_fn)

    print(list_scenarios())   # all registered names

Every scenario guarantees at least one active honest node in round 0, so
``swarm.step(0)`` never raises.  Custom scenarios register with
:func:`register_scenario`.

Three campaign-level registries sit on top:

- :func:`scenario_campaign` runs one scenario across many seeds as a single
  compiled program (the scanned swarm round vmapped over per-seed lanes);
- :class:`SweepGrid` (``register_sweep_grid`` / ``get_sweep_grid``) names
  the §5.5 derailment phase-diagram grids consumed by
  ``core.derailment.sweep`` (documented in ``docs/no_off.md``);
- :class:`ServingGrid` (``register_serving_grid`` / ``get_serving_grid``)
  names the *inference-side* (load × churn × redundancy × coalition)
  grids consumed by ``core.serving.sweep`` — the serving availability
  phase diagrams (documented in ``docs/serving.md``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.economy import EconomyConfig
from repro.core.swarm import (
    NodeSpec,
    SwarmConfig,
    lane_for_nodes,
    make_swarm,
    run_campaign,
    stack_lanes,
)
from repro.core.unextractable import CustodyConfig
from repro.core.verification import VerificationConfig


@dataclass(frozen=True)
class Scenario:
    """A named, size-scalable swarm regime.

    ``make_nodes(n)`` returns the node roster for an ``n``-node swarm;
    ``make_config(seed)`` the matching :class:`SwarmConfig`.  Both are pure,
    so the same (name, n, seed) triple always reproduces the same run.
    """
    name: str
    description: str
    make_nodes: Callable[[int], List[NodeSpec]]
    make_config: Callable[[int], SwarmConfig]
    default_nodes: int = 16

    def build(self, n_nodes: Optional[int] = None, seed: int = 0
              ) -> Tuple[List[NodeSpec], SwarmConfig]:
        n = self.default_nodes if n_nodes is None else n_nodes
        if n < 2:
            raise ValueError(f"scenario {self.name!r} needs >= 2 nodes, got {n}")
        return self.make_nodes(n), self.make_config(seed)

    def build_swarm(self, loss_fn, params, optimizer, data_fn, *,
                    n_nodes: Optional[int] = None, seed: int = 0,
                    engine: str = "batched",
                    batched_data_fn: Optional[Callable[[int], dict]] = None):
        """Instantiate a swarm for this scenario on the requested engine."""
        nodes, cfg = self.build(n_nodes, seed)
        return make_swarm(loss_fn, params, optimizer, nodes, cfg, data_fn,
                          engine=engine, batched_data_fn=batched_data_fn)


SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (overwrites an existing name)."""
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {list_scenarios()}") from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def batched_data_fn_for(data_fn: Callable[[int, int], dict], n_nodes: int,
                        ) -> Callable[[int], dict]:
    """Lift a jax-pure per-node ``data_fn(node_idx, rnd)`` into one batched
    ``fn(rnd)`` producing a leading-N stack — skips the batched engine's
    per-node host stacking loop (one dispatch instead of N per round).

    Only valid when ``data_fn`` is traceable with a traced ``node_idx``
    (e.g. built from ``jax.random.fold_in``); the stacked result is
    element-for-element identical to stacking N eager calls.
    """
    @jax.jit
    def fn(rnd):
        return jax.vmap(lambda i: data_fn(i, rnd))(jnp.arange(n_nodes))
    return fn


# -- helpers -------------------------------------------------------------------
def _mixed_nodes(n: int, n_byz: int, attack: str, scale: float,
                 speeds: Tuple[float, ...] = (1.0,),
                 delays: Tuple[int, ...] = (0,),
                 byz_delay: int = 0) -> List[NodeSpec]:
    """n - n_byz honest nodes (speeds/delays cycling) then n_byz attackers."""
    nodes = [NodeSpec(f"h{i}", speed=speeds[i % len(speeds)],
                      delay=delays[i % len(delays)])
             for i in range(n - n_byz)]
    nodes += [NodeSpec(f"adv{i}", byzantine=attack, byzantine_scale=scale,
                       delay=byz_delay)
              for i in range(n_byz)]
    return nodes


# -- the registry --------------------------------------------------------------
register_scenario(Scenario(
    name="honest_baseline",
    description=("All nodes honest, equal speed, mean aggregation, no "
                 "verification or compression.  The control every other "
                 "scenario is read against."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0),
    make_config=lambda seed: SwarmConfig(aggregator="mean", seed=seed),
))

register_scenario(Scenario(
    name="sign_flip_minority",
    description=("A 25% minority submits sign-flipped, 10x-amplified "
                 "gradients (§3.3).  CenteredClip aggregation holds within "
                 "its breakdown point."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "sign_flip", 10.0),
    make_config=lambda seed: SwarmConfig(aggregator="centered_clip", seed=seed),
))

register_scenario(Scenario(
    name="inner_product_collusion",
    description=("A 25% coalition colludes on the [87]-style inner-product "
                 "attack: every attacker submits -scale x the honest mean, "
                 "the strongest directed attack in the corruption table.  "
                 "CenteredClip aggregation."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "inner_product", 20.0),
    make_config=lambda seed: SwarmConfig(aggregator="centered_clip", seed=seed),
))

def _churn_nodes(n: int) -> List[NodeSpec]:
    core = max(2, n // 3)
    nodes = [NodeSpec(f"core{i}") for i in range(core)]
    for i in range(n - core):
        join = 1 + (i % 6)
        nodes.append(NodeSpec(f"churn{i}", join_round=join,
                              leave_round=join + 8 + (i % 5)))
    return nodes

register_scenario(Scenario(
    name="high_churn_elastic",
    description=("Elastic membership stress (§3 property 3): a third of the "
                 "swarm is always on; the rest join and leave on staggered "
                 "1-6 round offsets with 8-12 round lifetimes.  The batched "
                 "engine must absorb this churn without recompiling."),
    make_nodes=_churn_nodes,
    make_config=lambda seed: SwarmConfig(aggregator="mean", seed=seed),
))

register_scenario(Scenario(
    name="heterogeneous_speed",
    description=("Heterogeneous capacity (§3 property 5): node speeds cycle "
                 "0.5x/1x/2x/4x and minted ownership shares must stay "
                 "proportional to speed-weighted verified work (§4)."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0,
                                      speeds=(0.5, 1.0, 2.0, 4.0)),
    make_config=lambda seed: SwarmConfig(aggregator="mean", seed=seed),
))

register_scenario(Scenario(
    name="compressed_wire",
    description=("Communication efficiency (§3.1): every gradient is "
                 "round-tripped through 64-level bucketed QSGD before "
                 "aggregation.  Honest swarm; measures what lossy wires cost "
                 "in convergence."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="mean", compression="qsgd",
        compression_kwargs={"levels": 64, "bucket_size": 512}, seed=seed),
))

register_scenario(Scenario(
    name="audit_heavy",
    description=("Verification economics (§4.2): a 25% freeloader minority "
                 "submits zero gradients; validators audit half of all "
                 "updates per round (p_check=0.5), slashing stake and paying "
                 "jackpots until the freeloaders are excluded."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "zero", 0.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="mean",
        verification=VerificationConfig(p_check=0.5, stake=5.0,
                                        tolerance=1e-3, jackpot=5.0),
        seed=seed),
))

register_scenario(Scenario(
    name="derailment_stress",
    description=("The No-Off stress case (§5.5): a 40% inner-product "
                 "coalition at 50x scale tries to derail the run against "
                 "CenteredClip aggregation plus stake/slash audits at "
                 "p_check=0.25 — the regime where the paper argues only "
                 "physical intervention remains."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, (2 * n) // 5),
                                      "inner_product", 50.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="centered_clip",
        verification=VerificationConfig(p_check=0.25, stake=10.0,
                                        tolerance=1e-3, jackpot=5.0),
        seed=seed),
))

register_scenario(Scenario(
    name="gossip_ring_honest",
    description=("Fully decentralized honest swarm (§3.2): per-node model "
                 "replicas on a ring, each node mean-aggregates its "
                 "neighborhood and replicas gossip-mix once per round.  "
                 "Convergence and consensus_error are gated by the ring's "
                 "O(1/n²) spectral gap — the no-central-aggregator control."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0),
    make_config=lambda seed: SwarmConfig(aggregator="mean", topology="ring",
                                         seed=seed),
))

register_scenario(Scenario(
    name="byzantine_neighborhood",
    description=("Decentralized robustness (§3.3 x §3.2): a 25% sign-flip "
                 "minority attacks a degree-4 random-regular gossip graph; "
                 "every node CenteredClips its *own* neighborhood, so an "
                 "attacker can exceed the breakdown point locally even "
                 "while globally below it."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "sign_flip", 10.0),
    make_config=lambda seed: SwarmConfig(aggregator="centered_clip",
                                         topology="random_regular",
                                         seed=seed),
))

register_scenario(Scenario(
    name="custody_leech",
    description=("Unextractability under attack (§4.1): a 25% leech "
                 "minority submits zero gradients while doubling as the "
                 "extraction coalition.  Redundancy-2 custody with a 0.4 "
                 "per-node bound keeps the coalition below full shard "
                 "coverage, so the reconstruct-attack eval prices their "
                 "reassembled model as garbage; the live coverage trace "
                 "stays at 1.0 (leeches keep relaying custody).  The leech "
                 "count is ceil(n/4) so it coincides with the coalition "
                 "tail mask (ceil(0.25 * n)) at every roster size."),
    make_nodes=lambda n: _mixed_nodes(n, -(-n // 4), "zero", 0.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="mean", seed=seed,
        custody=CustodyConfig(num_shards=16, redundancy=2,
                              max_fraction=0.4, coalition_fraction=0.25)),
))

def _collapse_nodes(n: int) -> List[NodeSpec]:
    core = max(2, n // 3)
    nodes = [NodeSpec(f"core{i}") for i in range(core)]
    for i in range(n - core):
        nodes.append(NodeSpec(f"leaver{i}", leave_round=3 + 2 * (i % 4)))
    return nodes

register_scenario(Scenario(
    name="custody_churn_collapse",
    description=("Custody-coupled churn (§4.1 x §3 property 3): two thirds "
                 "of the swarm departs on staggered rounds and never "
                 "returns, against redundancy-2 custody.  Once every holder "
                 "of some shard has left, the live coverage "
                 "(RoundRecord.coverage) collapses below 1.0 — the model "
                 "is no longer fully held by anyone; the swarm 'degraded' "
                 "regime of the extractability phase table."),
    make_nodes=_collapse_nodes,
    make_config=lambda seed: SwarmConfig(
        aggregator="mean", seed=seed,
        custody=CustodyConfig(num_shards=16, redundancy=2,
                              max_fraction=0.5)),
))

register_scenario(Scenario(
    name="straggler_majority",
    description=("Bounded-staleness asynchrony (§3 property 5): two thirds "
                 "of an honest swarm are stragglers gradienting against "
                 "parameter snapshots up to 3 rounds old (delay cycles "
                 "0/3/3, speeds 1x/0.5x/0.5x) under staleness_bound=3, "
                 "mean aggregation.  The convergence price of *not* "
                 "waiting for the slow majority — the DOWNPOUR regime."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0,
                                      speeds=(1.0, 0.5, 0.5),
                                      delays=(0, 3, 3)),
    make_config=lambda seed: SwarmConfig(aggregator="mean",
                                         staleness_bound=3, seed=seed),
))

register_scenario(Scenario(
    name="stale_poisoning",
    description=("Stale Byzantine updates (§3.3 x asynchrony): a 25% "
                 "sign-flip minority submits maximally stale poisoned "
                 "gradients (delay=3) while honest nodes run fresh — does "
                 "CenteredClip's breakdown point survive when the attack "
                 "rides the staleness the protocol must tolerate?  Audits "
                 "recompute against the claimed stale snapshot (the delay "
                 "is part of the claim), so staleness alone never "
                 "slashes — only corruption does."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "sign_flip", 10.0,
                                      byz_delay=3),
    make_config=lambda seed: SwarmConfig(
        aggregator="centered_clip",
        verification=VerificationConfig(p_check=0.25, stake=10.0,
                                        tolerance=1e-3, jackpot=5.0),
        staleness_bound=3, seed=seed),
))

def _async_churn_nodes(n: int) -> List[NodeSpec]:
    core = max(2, n // 3)
    nodes = [NodeSpec(f"core{i}", delay=i % 3) for i in range(core)]
    for i in range(n - core):
        join = 1 + (i % 6)
        nodes.append(NodeSpec(f"churn{i}", join_round=join,
                              leave_round=join + 8 + (i % 5),
                              delay=1 + (i % 2)))
    return nodes

register_scenario(Scenario(
    name="async_churn",
    description=("Asynchrony x elastic membership (§3 properties 3+5): the "
                 "high_churn_elastic roster with per-node staleness (core "
                 "delays cycle 0/1/2, transients 1/2) under "
                 "staleness_bound=2 — late joiners gradient against "
                 "snapshots taken before they were active, the hardest "
                 "bookkeeping case for the snapshot ring."),
    make_nodes=_async_churn_nodes,
    make_config=lambda seed: SwarmConfig(aggregator="mean",
                                         staleness_bound=2, seed=seed),
))

register_scenario(Scenario(
    name="economy_rational",
    description=("The §4 incentive control: a 25% inner-product coalition "
                 "buys identities from one capital budget (identity cost "
                 "1.0, bond 5.0) against CenteredClip + p_check=0.5 audits, "
                 "while fees and rewards pay honest stakes — the schedule "
                 "the paper argues sustains rational participation.  "
                 "Admission is stake-gated in-program; slashed or insolvent "
                 "nodes drop out of aggregation for good."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 4), "inner_product", 20.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="centered_clip",
        verification=VerificationConfig(p_check=0.5, stake=5.0,
                                        tolerance=1e-3, jackpot=5.0),
        economy=EconomyConfig(),
        seed=seed),
))

register_scenario(Scenario(
    name="economy_sybil_adaptive",
    description=("Sybil pressure meets an adaptive adversary (§4 x §5.5): "
                 "identities are cheap (cost 0.1), so the coalition's "
                 "budget buys a count majority, and instead of a fixed "
                 "behaviour it best-responds each round — scoring a menu "
                 "of attack scales against the known aggregator and "
                 "submitting the one that pushes the aggregate hardest "
                 "against honest descent.  Sparse audits (p_check=0.1) "
                 "price what adaptivity buys that fixed attacks don't."),
    make_nodes=lambda n: _mixed_nodes(n, max(1, n // 2), "inner_product", 20.0),
    make_config=lambda seed: SwarmConfig(
        aggregator="centered_clip",
        verification=VerificationConfig(p_check=0.1, stake=5.0,
                                        tolerance=1e-3, jackpot=5.0),
        economy=EconomyConfig(identity_cost=0.1, adaptive=True),
        seed=seed),
))

register_scenario(Scenario(
    name="partitioned_swarm",
    description=("Near-partition stress (§5.5): two ring clusters joined "
                 "by a single bridge edge (near-zero spectral gap).  "
                 "Honest swarm; consensus leaks across the bridge one edge "
                 "per round, so consensus_error decays at the bridge rate, "
                 "not the cluster rate."),
    make_nodes=lambda n: _mixed_nodes(n, 0, "zero", 0.0),
    make_config=lambda seed: SwarmConfig(aggregator="mean",
                                         topology="clustered", seed=seed),
))


# -- campaigns over scenarios ----------------------------------------------------
def scenario_campaign(name: str, loss_fn, params, optimizer, data_fn, *,
                      n_nodes: Optional[int] = None, seeds: Tuple[int, ...] = (0,),
                      rounds: int, eval_fn: Optional[Callable] = None):
    """Run one scenario across many seeds as a **single compiled program** —
    the scanned swarm round vmapped over per-seed lanes.

    Returns ``(state, records, final_losses, node_ids, cfg)``: every output
    leaf carries a leading seed axis (lane *k* is ``seeds[k]``), and lane
    *k* reproduces the single-run ``Swarm`` history for the same (scenario,
    seed) — see ``swarm.history_from_records`` / ``swarm.ledger_from_run``
    for turning a lane back into host-side history and ledger.
    """
    scn = get_scenario(name)
    nodes, cfg = scn.build(n_nodes, seeds[0])
    lanes = stack_lanes([lane_for_nodes(nodes, scn.make_config(s))
                         for s in seeds])
    state, recs, final = run_campaign(
        loss_fn, params, optimizer, data_fn, lanes, rounds=rounds,
        aggregator=cfg.aggregator, agg_kwargs=cfg.agg_kwargs,
        compression_kind=cfg.compression,
        compression_kwargs=cfg.compression_kwargs,
        verify=cfg.verification is not None, eval_fn=eval_fn)
    return state, recs, final, [n.node_id for n in nodes], cfg


# -- derailment sweep grids (§5.5 phase diagrams) --------------------------------
@dataclass(frozen=True)
class Regime:
    """One (aggregator, verification) column of the §5.5 phase diagram.

    ``agg_kwargs`` are *static* aggregator kwargs (baked per program);
    per-run traced kwargs (krum's ``f`` tracking the attacker count) are
    added by ``derailment.sweep`` itself.
    """
    name: str
    aggregator: str
    agg_kwargs: Dict = field(default_factory=dict)
    verification: Optional[VerificationConfig] = None


@dataclass(frozen=True)
class SweepGrid:
    """A named derailment sweep: the cartesian grid (attacker counts ×
    scales × seeds) per regime that ``derailment.sweep`` compiles into one
    device program per distinct (aggregator, static kwargs) group.

    A non-empty ``topologies`` adds the **decentralized axis**: every cell
    is additionally crossed with each named ``core.topology`` entry, runs
    in the decentralized round (per-node replicas, neighborhood
    aggregation, gossip mixing — the mixing matrix rides as a traced lane),
    and honest baselines are shared per (topology, seed).  Empty = the
    centralized round, exactly as before.

    Non-empty ``redundancies`` / ``coalition_fractions`` add the **custody
    axis** (§4.1): every cell is additionally crossed with each
    (redundancy, coalition fraction) pair — the ``(N, num_shards)`` custody
    matrix and coalition mask ride as traced lanes, the round traces the
    live coverage frontier, and the eval reports the reconstruct-attack
    loss next to the honest loss, feeding
    ``SweepResult.extractability_table``.  ``custody_leave_fraction > 0``
    staggers that fraction of the honest roster out of the run mid-sweep
    (drawn per seed), which is what drives redundancy-starved cells into
    the "degraded" regime — the custody analogue of churn-coupled
    mixing.

    A non-empty ``staleness_bounds`` adds the **asynchrony axis**: every
    cell is additionally crossed with each bound K — all nodes in that
    cell gradient against snapshots up to K rounds old (realized delays
    drawn per ``(seed, node, round)``).  Per-node delay caps ride as a
    traced lane, so every bound shares ONE compiled program shaped by the
    *max* bound's K+1-snapshot ring; honest baselines are shared per
    (topology, staleness bound, seed).  A 0 entry is the synchronous
    limit measured inside the async program (numerically equal, not
    bit-exact, to the dedicated sync engine — reduction order differs).

    Non-empty ``identity_costs`` / ``fees`` / ``reward_schedules`` /
    ``adaptive`` add the **economy axes** (§4): every cell is additionally
    crossed with each (identity cost × fee inflow × (reward_rate, jackpot)
    schedule × adaptive flag) combination — the knobs ride as the traced
    ``econ`` lane (``economy.EconParams``), the attacker slots double as
    the strategic coalition holding one ``econ_budget``, and the round
    gains stake-gated admission, the per-round economy update, and (in
    adaptive lanes) the coalition's best-response inner step.
    ``derailment.sweep`` then also emits one ``economy.EconomyResult`` per
    measured lane, classified sustained / death_spiral / captured.  Empty
    on all four = no economy lane, exactly as before."""
    name: str
    description: str
    regimes: Tuple[Regime, ...]
    n_honest: int = 10
    attacker_counts: Tuple[int, ...] = (1, 3, 6, 12)
    seeds: Tuple[int, ...] = (0, 1, 2)
    scales: Tuple[float, ...] = (50.0,)
    attack: str = "inner_product"
    rounds: int = 25
    topologies: Tuple[str, ...] = ()
    redundancies: Tuple[int, ...] = ()
    coalition_fractions: Tuple[float, ...] = ()
    num_shards: int = 16
    custody_max_fraction: float = 0.5
    custody_leave_fraction: float = 0.0
    staleness_bounds: Tuple[int, ...] = ()
    # -- economy axes (§4): empty on all four = no economy lane --------------
    identity_costs: Tuple[float, ...] = ()
    fees: Tuple[float, ...] = ()
    reward_schedules: Tuple[Tuple[float, float], ...] = ()  # (rate, jackpot)
    adaptive: Tuple[bool, ...] = ()
    econ_budget: float = 50.0        # the coalition's total capital
    econ_min_stake: float = 5.0      # admission bond
    econ_op_cost: float = 0.05       # per-round operating cost per unit speed
    econ_reserve: float = 1.0        # honest starting balance

    @property
    def has_custody(self) -> bool:
        return bool(self.redundancies) or bool(self.coalition_fractions)

    @property
    def has_economy(self) -> bool:
        return bool(self.identity_costs) or bool(self.fees) \
            or bool(self.reward_schedules) or bool(self.adaptive)

    @property
    def n_points(self) -> int:
        return (len(self.regimes) * len(self.attacker_counts)
                * len(self.scales) * len(self.seeds)
                * max(1, len(self.topologies))
                * max(1, len(self.staleness_bounds))
                * max(1, len(self.redundancies))
                * max(1, len(self.coalition_fractions))
                * max(1, len(self.identity_costs))
                * max(1, len(self.fees))
                * max(1, len(self.reward_schedules))
                * max(1, len(self.adaptive)))

    @property
    def n_lanes(self) -> int:
        """Total campaign lanes ``derailment.sweep`` builds for this grid:
        every measured point plus the shared honest-baseline lanes (one per
        (topology, staleness bound, seed)).  This is the count a
        :class:`~repro.core.placement.MeshPlan` must shard evenly."""
        return self.n_points + (max(1, len(self.topologies))
                                * max(1, len(self.staleness_bounds))
                                * len(self.seeds))


SWEEP_GRIDS: Dict[str, SweepGrid] = {}


def register_sweep_grid(grid: SweepGrid) -> SweepGrid:
    SWEEP_GRIDS[grid.name] = grid
    return grid


def get_sweep_grid(name: str) -> SweepGrid:
    try:
        return SWEEP_GRIDS[name]
    except KeyError:
        raise KeyError(f"unknown sweep grid {name!r}; "
                       f"registered: {list_sweep_grids()}") from None


def list_sweep_grids() -> List[str]:
    return sorted(SWEEP_GRIDS)


_AUDIT = VerificationConfig(p_check=0.25, stake=10.0, tolerance=1e-3,
                            jackpot=5.0)
_PERFECT_AUDIT = VerificationConfig(p_check=1.0, stake=5.0, tolerance=1e-3,
                                    jackpot=5.0)

register_sweep_grid(SweepGrid(
    name="no_off_quick",
    description=("The benchmark grid: 4 attacker fractions x 3 seeds x "
                 "2 regimes (mean / CenteredClip+audits) = 24 runs in one "
                 "fused compiled program."),
    regimes=(Regime("mean", "mean"),
             Regime("centered_clip+audit", "centered_clip",
                    verification=_AUDIT)),
))

register_sweep_grid(SweepGrid(
    name="no_off_phase",
    description=("The paper's full §5.5 table: mean (off-switch works), "
                 "CenteredClip (breakdown point), and mean under "
                 "near-perfect verification (derailment slashed away).  "
                 "All three regimes fuse into one program — p_check is a "
                 "traced lane, the aggregator a per-lane id."),
    regimes=(Regime("mean", "mean"),
             Regime("centered_clip", "centered_clip"),
             Regime("mean+verified", "mean", verification=_PERFECT_AUDIT)),
))

register_sweep_grid(SweepGrid(
    name="no_off_smoke",
    description="CI smoke: 2 counts x 1 seed x 2 regimes = 4 tiny runs.",
    regimes=(Regime("mean", "mean"),
             Regime("centered_clip", "centered_clip")),
    n_honest=6,
    attacker_counts=(2, 6),
    seeds=(0,),
    rounds=8,
))

register_sweep_grid(SweepGrid(
    name="no_off_topology",
    description=("The decentralized §5.5 diagram: at what spectral gap "
                 "does local robust aggregation stop resisting "
                 "derailment?  2 regimes x 4 topologies x 3 fractions x "
                 "2 seeds, all lanes (and per-topology baselines) in one "
                 "compiled program — the mixing matrix is a traced lane."),
    regimes=(Regime("mean", "mean"),
             Regime("centered_clip", "centered_clip")),
    topologies=("ring", "random_regular", "clustered", "fully_connected"),
    n_honest=10,
    attacker_counts=(1, 3, 6),
    seeds=(0, 1),
    rounds=20,
))

register_sweep_grid(SweepGrid(
    name="no_off_async",
    description=("The asynchrony frontier (§5.5 x §3): does CenteredClip's "
                 "breakdown point survive *stale* Byzantine updates?  2 "
                 "regimes x 3 staleness bounds x 3 attacker counts x 2 "
                 "seeds — every bound shares one compiled program (per-node "
                 "delay caps are a traced lane over the max bound's ring), "
                 "so staleness x attacker-fraction renders like any other "
                 "phase diagram."),
    regimes=(Regime("mean", "mean"),
             Regime("centered_clip", "centered_clip")),
    staleness_bounds=(0, 2, 4),
    n_honest=10,
    attacker_counts=(1, 3, 6),
    seeds=(0, 1),
    rounds=20,
))

register_sweep_grid(SweepGrid(
    name="no_off_async_smoke",
    description=("CI smoke for the asynchrony axis: 1 regime x 2 staleness "
                 "bounds x 2 counts x 1 seed = 4 tiny runs."),
    regimes=(Regime("centered_clip", "centered_clip"),),
    staleness_bounds=(0, 2),
    n_honest=6,
    attacker_counts=(2, 6),
    seeds=(0,),
    rounds=8,
))

register_sweep_grid(SweepGrid(
    name="custody_frontier",
    description=("The §4.1 extractability frontier: at what redundancy and "
                 "coalition fraction does a swarm stop being a Protocol "
                 "Model?  (redundancy x coalition fraction x churn seed) "
                 "cells, each with the reconstruct-attack eval, in one "
                 "compiled program; a third of the honest roster churns "
                 "out mid-run, so low-redundancy cells degrade."),
    regimes=(Regime("mean", "mean"),),
    n_honest=10,
    attacker_counts=(0,),
    seeds=(0, 1, 2),
    rounds=20,
    redundancies=(1, 2, 3),
    coalition_fractions=(0.2, 0.4, 0.6, 0.8, 1.0),
    num_shards=12,
    custody_max_fraction=0.4,
    custody_leave_fraction=0.3,
))

register_sweep_grid(SweepGrid(
    name="custody_smoke",
    description=("CI smoke for the custody axis: 2 redundancies x 2 "
                 "coalition fractions x 1 seed = 4 tiny runs with the "
                 "reconstruct-attack eval."),
    regimes=(Regime("mean", "mean"),),
    n_honest=6,
    attacker_counts=(0,),
    seeds=(0,),
    rounds=8,
    redundancies=(1, 2),
    coalition_fractions=(0.5, 1.0),
    num_shards=8,
    custody_max_fraction=0.5,
    custody_leave_fraction=0.34,
))

register_sweep_grid(SweepGrid(
    name="no_off_economy",
    description=("The §4 incentive phase diagram: at what identity cost "
                 "and fee schedule does rational participation survive a "
                 "strategic coalition?  2 regimes x 3 identity costs x 3 "
                 "fees x 2 reward schedules x fixed-vs-adaptive x 2 seeds "
                 "= 144 lanes (+ baselines) in ONE compiled program — "
                 "every economy knob is a traced lane, the adaptive "
                 "best-response an in-program inner step.  Each lane is "
                 "classified sustained / death_spiral / captured; the "
                 "fixed-vs-adaptive gap is the paper's open question "
                 "rendered as a phase-diagram delta.  The fixed attack "
                 "runs at a moderate scale (2.0); the adaptive coalition "
                 "recalibrates per round, so the gap concentrates in the "
                 "weakly-defended (mean) regime and robust aggregation "
                 "closes it."),
    regimes=(Regime("mean+audit", "mean", verification=_AUDIT),
             Regime("centered_clip+audit", "centered_clip",
                    verification=_AUDIT)),
    n_honest=8,
    attacker_counts=(4,),
    seeds=(0, 1),
    scales=(2.0,),
    rounds=20,
    identity_costs=(0.25, 2.0, 8.0),
    fees=(0.25, 1.0, 4.0),
    reward_schedules=((0.05, 2.0), (0.2, 8.0)),
    adaptive=(False, True),
))

register_sweep_grid(SweepGrid(
    name="no_off_economy_smoke",
    description=("CI smoke for the economy axes: 2 regimes x 2 identity "
                 "costs x 2 fees x 1 schedule x fixed-vs-adaptive x 1 seed "
                 "= 16 tiny lanes (+ 1 baseline) with the full economy "
                 "round (Sybil funding, stake-gated admission, escrowed "
                 "rewards, pool-funded jackpots, best-response lanes) — "
                 "small enough for CI, large enough that the mean-regime "
                 "adaptive lanes show the loss gap."),
    regimes=(Regime("mean+audit", "mean", verification=_AUDIT),
             Regime("centered_clip+audit", "centered_clip",
                    verification=_AUDIT)),
    n_honest=6,
    attacker_counts=(3,),
    seeds=(0,),
    scales=(2.0,),
    rounds=8,
    identity_costs=(0.5, 4.0),
    fees=(0.5, 2.0),
    reward_schedules=((0.1, 5.0),),
    adaptive=(False, True),
))


# -- serving grids (no-off at inference) -----------------------------------------
@dataclass(frozen=True)
class ServingGrid:
    """A named serving sweep: the cartesian (load × churn rate × custody
    redundancy × coalition fraction × seed) grid that ``core.serving.sweep``
    compiles into ONE device program — the inference twin of
    :class:`SweepGrid`.

    ``loads`` are request arrivals per serve step; ``churn_rates`` make
    that fraction of non-coalition custody nodes transient (half leave on
    staggered mid-horizon steps, half join late — elastic relief, the
    source of coverage gaps that *heal* and hence of the "degraded"
    regime); ``coalition_fractions`` mark roster-tail coalitions that
    defect together at ``defect_step`` (the inference no-off attack: who
    can refuse serving by leaving); ``redundancies`` draw one custody
    matrix each (seed 0 — serving seeds vary churn, never who holds
    what).  Engine shape: ``slots`` decode slots serve ``n_requests``
    requests of ``prompt_len`` (max) prompt tokens and ``max_new``
    generated tokens over a ``steps`` horizon; admission costs ``fee``
    credentials from one of ``n_holders`` balances."""
    name: str
    description: str
    loads: Tuple[float, ...] = (0.25, 0.5, 1.0)
    churn_rates: Tuple[float, ...] = (0.0, 0.3, 0.6)
    redundancies: Tuple[int, ...] = (1, 2)
    coalition_fractions: Tuple[float, ...] = (0.0,)
    seeds: Tuple[int, ...] = (0, 1)
    n_nodes: int = 8
    num_shards: int = 12
    max_fraction: float = 0.5
    n_requests: int = 12
    n_holders: int = 4
    slots: int = 4
    prompt_len: int = 8
    max_new: int = 8
    steps: int = 96
    defect_step: int = 32
    fee: float = 1.0

    @property
    def n_points(self) -> int:
        return (len(self.loads) * len(self.churn_rates)
                * len(self.redundancies) * len(self.coalition_fractions)
                * len(self.seeds))

    @property
    def n_lanes(self) -> int:
        """Serving sweeps have no baseline lanes: lanes == points.  Named
        ``n_lanes`` so ``MeshPlan.from_grid`` works on either grid kind."""
        return self.n_points


SERVING_GRIDS: Dict[str, ServingGrid] = {}


def register_serving_grid(grid: ServingGrid) -> ServingGrid:
    SERVING_GRIDS[grid.name] = grid
    return grid


def get_serving_grid(name: str) -> ServingGrid:
    try:
        return SERVING_GRIDS[name]
    except KeyError:
        raise KeyError(f"unknown serving grid {name!r}; "
                       f"registered: {list_serving_grids()}") from None


def list_serving_grids() -> List[str]:
    return sorted(SERVING_GRIDS)


register_serving_grid(ServingGrid(
    name="serving_frontier",
    description=("The inference no-off frontier: at what load, churn rate "
                 "and custody redundancy does continuous-batching serving "
                 "stay available?  (3 loads x 3 churn rates x 2 "
                 "redundancies x 2 seeds) = 36 lanes in one compiled "
                 "program, classified served / degraded / halted."),
))

register_serving_grid(ServingGrid(
    name="serving_coalition",
    description=("Who can refuse serving?  A roster-tail coalition defects "
                 "at defect_step against increasing custody redundancy: "
                 "the serving twin of the §5.5 off-switch question — at "
                 "redundancy 1 every holder holds a veto; redundancy r "
                 "needs a coalition covering some shard's every holder."),
    loads=(0.5,),
    churn_rates=(0.0,),
    redundancies=(1, 2, 3),
    coalition_fractions=(0.25, 0.5, 0.75, 1.0),
    seeds=(0, 1, 2),
))

register_serving_grid(ServingGrid(
    name="serving_smoke",
    description=("CI smoke: 2 loads x 2 churn rates x 2 redundancies x 1 "
                 "seed = 8 tiny serving lanes with the full load/churn/"
                 "redundancy axis set."),
    loads=(0.5, 1.5),
    churn_rates=(0.0, 0.6),
    redundancies=(1, 2),
    seeds=(0,),
    n_requests=8,
    num_shards=8,
    slots=3,
    prompt_len=6,
    max_new=6,
    steps=48,
    defect_step=16,
))


register_sweep_grid(SweepGrid(
    name="no_off_topology_smoke",
    description=("CI smoke for the decentralized axis: 1 regime x 2 "
                 "topologies x 2 counts x 1 seed = 4 tiny runs."),
    regimes=(Regime("centered_clip", "centered_clip"),),
    topologies=("ring", "fully_connected"),
    n_honest=6,
    attacker_counts=(2, 6),
    seeds=(0,),
    rounds=8,
))
