"""Protocol serving engine (paper §4.1 meets §5): continuous-batching
inference over custody shards, with serving as a campaign axis.

This module is to *inference* what ``core.swarm`` is to training.  A
Protocol Model is defined by what callers can and cannot get at serving
time — logits yes, weights no — and the paper's no-off question has an
inference-time twin: **who can refuse or halt serving** when custody
holders churn or defect?  Three layers answer it:

1. **Scanned decoding** — :func:`greedy_decode` replaces the per-token
   python loop of the old serving driver with two device programs (a
   scanned prefill via ``Model.decode_scan`` and a ``lax.scan`` over
   ``decode_step``), bit-identical tokens at a fraction of the dispatch
   cost.  The old loop survives as :func:`greedy_decode_loop`, the
   reference oracle the engine is equivalence-tested (and benchmarked)
   against.

2. **The continuous-batching engine** — :class:`ServingEngine` steps a
   fixed pool of decode *slots* through one ``lax.scan``
   (:func:`make_serve_step`): every step each occupied slot advances one
   token (mid-prompt slots feed the next prompt token — prefill and decode
   are the same step function, which is what keeps shapes fixed), finished
   slots retire, and free slots admit queued requests by arrival order —
   all via masks, so admission/retirement under load never changes shapes
   and the program **never recompiles**.  Requests live in arrival/length
   arrays (:class:`ServeLane`); generated tokens land in a per-request
   output buffer via masked scatters.

3. **Protocol coupling + the campaign axis** — the PR-4 custody matrix
   rides through serving: per-step node availability (churn, defection)
   gates the live shard coverage, and the engine **halts exactly when
   coverage < 1** (no admissions, no token progress — nobody holds the
   full model, so nobody can serve it).  Credential balances (the
   vectorized :class:`~repro.core.ledger.Ledger` view) gate admission on
   device with the same strict ``balance - fee > min_shares`` boundary as
   ``Ledger.can_infer``.  :func:`sweep` vmaps whole *serving lanes* —
   traced load / churn / redundancy / coalition axes from a
   ``scenarios.ServingGrid`` — into ONE compiled program and renders the
   throughput-vs-availability phase diagram
   (:meth:`ServingResult.availability_table`), mirroring
   ``derailment.sweep``.

The no-off-at-inference finding this machinery measures: below full
redundancy, serving inherits an off-switch nobody designed — any custody
coalition whose departure uncovers a shard can refuse the entire swarm's
inference, and at redundancy 1 every single holder holds that veto
(``docs/serving.md``).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import MeshPlan

Array = jax.Array

_FAR = np.iinfo(np.int32).max


# ============================ scanned greedy decoding ===========================
@dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int
    batch: int

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out * self.batch / max(self.decode_s, 1e-9)


@functools.lru_cache(maxsize=32)
def _greedy_programs(model, batch: int, prompt_len: int, max_new: int,
                     cache_len: int):
    """The two jitted programs of the scanned greedy decoder, cached per
    (model, shape) so repeated calls never retrace.  LRU-bounded: a
    long-lived server decoding many distinct request shapes must not
    accumulate compiled executables without bound."""

    @jax.jit
    def prefill(params, prompts):
        cache = model.init_cache(batch, cache_len)
        logits, cache = model.decode_scan(params, prompts, cache)
        tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok0, cache

    @jax.jit
    def decode(params, tok0, cache):
        def body(carry, _):
            tok, c = carry
            logits, c = model.decode_step(params, tok[:, None], c)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return (nxt, c), tok

        (_, cache), toks = jax.lax.scan(body, (tok0, cache), None,
                                        length=max_new)
        return jnp.moveaxis(toks, 0, 1)                       # (B, max_new)

    return prefill, decode


def greedy_decode(model, params, prompts: Array, max_new: int,
                  *, cache_len: Optional[int] = None):
    """Scanned greedy decoding: prompts (B, S0) int32 -> (B, max_new) tokens.

    Exactly the math of :func:`greedy_decode_loop` (prefill by stepping the
    prompt through ``decode_step`` — exact for every family including the
    recurrent ones — then argmax feedback), but the token loops run inside
    two compiled programs instead of one python dispatch per token."""
    b, s0 = prompts.shape
    cache_len = cache_len or (s0 + max_new)
    prefill, decode = _greedy_programs(model, b, s0, max_new, cache_len)

    t0 = time.perf_counter()
    tok0, cache = jax.block_until_ready(prefill(params, prompts))
    prefill_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    gen = jax.block_until_ready(decode(params, tok0, cache))
    decode_s = time.perf_counter() - t0
    return gen, ServeStats(prefill_s, decode_s, max_new, b)


@functools.lru_cache(maxsize=8)
def _loop_decode_step(model):
    # one jitted decode_step per model, shared across greedy_decode_loop
    # calls: the ORIGINAL driver re-jitted (hence re-traced) every call —
    # caching here gives the baseline its best steady-state behaviour, so
    # benchmark speedups never include the baseline's tracing time
    return jax.jit(model.decode_step)


def greedy_decode_loop(model, params, prompts: Array, max_new: int,
                       *, cache_len: Optional[int] = None):
    """The replaced per-token python loop — kept as the readable reference
    oracle :func:`greedy_decode` (and the continuous-batching engine) are
    equivalence-tested against, and as the benchmark baseline."""
    b, s0 = prompts.shape
    cache_len = cache_len or (s0 + max_new)
    cache = model.init_cache(b, cache_len)

    decode = _loop_decode_step(model)

    t0 = time.perf_counter()
    logits = None
    for i in range(s0):
        logits, cache = decode(params, prompts[:, i:i + 1], cache)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    outs: List[Array] = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(max_new):
        outs.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    gen = jax.block_until_ready(jnp.concatenate(outs, axis=1))
    decode_s = time.perf_counter() - t0
    return gen, ServeStats(prefill_s, decode_s, max_new, b)


# ======================== the continuous-batching engine ========================
@dataclass(frozen=True)
class ServingConfig:
    """Static engine shape: slot-pool size, per-request decode budget, scan
    horizon, and the admission boundary.  ``min_shares`` uses the same
    strict ``>`` boundary as ``Ledger.can_infer``: a holder whose balance
    after the fee would not *exceed* ``min_shares`` is refused.  (The fee
    itself is NOT static — it rides :class:`ServeLane` as a traced value,
    so a campaign can sweep pricing.)"""
    slots: int = 4
    max_new: int = 8
    steps: int = 64
    min_shares: float = 0.0
    cache_len: Optional[int] = None       # default: prompt_len + max_new


class ServeLane(NamedTuple):
    """Per-run traced serving parameters — the inference twin of
    ``swarm.LaneParams``.  Every field is an array, so a *campaign* is a
    ServeLane whose leaves carry a leading lane axis (``stack_serve_lanes``)
    vmapped by :meth:`ServingEngine.run_many`.

    Request fields have shape (R,); ``balances`` is the vectorized Ledger
    view (H credential holders); ``node_down_from``/``node_down_until``
    are the custody roster's *outage windows* — node n is offline while
    ``down_from <= t < down_until``.  One window expresses every serving
    churn shape: a permanent defection is ``[defect_step, FAR)``, a node
    that joins late is ``[0, join_step)``, a transient outage heals
    (which is what makes the "degraded" regime — coverage gaps that stall
    serving and then recover — reachable at all; the swarm engine's
    join/leave membership windows are the complement convention).
    ``custody`` is the (N, S) shard-custody matrix from
    ``core.unextractable`` (``None`` = un-sharded serving, never halts;
    all lanes of a campaign must agree)."""
    arrivals: Array        # (R,) int32 — step at which request r arrives
    holders: Array         # (R,) int32 — credential-holder index per request
    prompt_lens: Array     # (R,) int32
    max_new: Array         # (R,) int32 — per-request decode budget
                           #   (<= ServingConfig.max_new, the buffer width;
                           #   slots retire the moment THEIR request is done
                           #   — no head-of-line padding to the batch max)
    balances: Array        # (H,) f32 — initial credential balances
    node_down_from: Array  # (N,) int32 — outage start (inclusive; _FAR = never)
    node_down_until: Array # (N,) int32 — outage end (exclusive)
    fee: Array             # ()  f32 — credentials spent per admission
    custody: Optional[Array] = None   # (N, S) bool | None


class ServeState(NamedTuple):
    """The carry of the scanned serve step — the whole serving frontier
    lives on device, so a run never round-trips to the host."""
    caches: Any           # model cache pytree, leading slot axis
    slot_req: Array       # (S,) int32 — occupying request id; R = free
    slot_t: Array         # (S,) int32 — tokens fed so far for the occupant
    last_tok: Array       # (S,) int32 — the occupant's previous output
    admitted: Array       # (R,) bool
    done: Array           # (R,) bool — all max_new tokens delivered
    balances: Array       # (H,) f32 — live credential balances
    out_tokens: Array     # (R, max_new) int32 — delivered tokens


class ServeRecord(NamedTuple):
    """Per-step outputs stacked by ``lax.scan`` (leading step axis)."""
    coverage: Array       # () f32 — live shard coverage (1.0 un-sharded)
    live: Array           # () bool — coverage complete; serving possible
    n_active: Array       # () int32 — occupied slots after admission
    n_admitted: Array     # () int32 — requests admitted this step
    new_tokens: Array     # () int32 — tokens delivered this step
    queued: Array         # () int32 — arrived, unadmitted, fundable after
                          #   this step (credential-refused waiters are
                          #   not counted as demand)


def stack_serve_lanes(lanes: Sequence[ServeLane]) -> ServeLane:
    """Stack single-run lanes into a campaign (leading lane axis on every
    leaf).  All lanes must share R/H/N and agree on ``custody`` (all None,
    or all same-shaped matrices)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)


def make_serve_step(model, cfg: ServingConfig, prompt_shape: Tuple[int, int],
                    *, has_custody: bool) -> Tuple[Callable, Callable]:
    """Build the pure serve step — returns ``(step, init_state)`` where
    ``step(params, prompts, lane, state, t) -> (state, ServeRecord)`` and
    ``init_state(lane) -> ServeState`` is the matching empty pool.
    ``prompts`` is a traced (R, P) argument (only its shape is baked), so
    one compiled program serves any prompt batch of that shape.

    Static structure (slot count, horizon, whether the custody gate exists)
    is baked here; everything per-run rides in ``lane`` as traced arrays,
    so one trace serves every lane of a campaign.  The step is four masked
    stages — availability, admission, decode, retire — with fixed shapes
    throughout:

    - **availability**: nodes online outside their outage window; live shard
      coverage from the custody matrix; ``live = every shard held`` (the
      serving twin of ``RoundRecord.coverage``).  A dead step admits
      nothing and advances nothing — serving is halted, not degraded
      gracefully: with a shard missing there is no model to run.
    - **admission**: arrived, unadmitted requests whose holder can afford
      the fee (strict ``balance - fee > min_shares``, the
      ``Ledger.can_infer`` boundary, counting same-step same-holder
      siblings so a burst can never overdraw a balance) fill free slots in
      arrival order; fees are deducted on device.  Newly admitted slots
      get a pristine cache (masked reset), so a recycled slot never leaks
      its previous occupant's KV state.
    - **decode**: every slot advances one token through a vmapped
      ``decode_step`` (B=1 per slot — each slot sits at its own position).
      Mid-prompt slots feed the next prompt token; finished-prompt slots
      feed their previous argmax.  Idle slots compute and discard — the
      fixed-shape price, exactly the swarm engine's inactive-lane trade.
    - **retire**: the token produced at prompt position ``plen-1+i`` is
      generated token ``i``; token ``max_new-1`` completes the request,
      frees the slot, and marks ``done``.
    """
    n_req, p_max = prompt_shape
    slots, max_new = cfg.slots, cfg.max_new
    cache_len = cfg.cache_len or (p_max + max_new)
    template = model.init_cache(1, cache_len)

    def decode_all(params, toks, caches):
        return jax.vmap(model.decode_step,
                        in_axes=(None, 0, 0))(params, toks, caches)

    def step(params, prompts: Array, lane: ServeLane, state: ServeState, t):
        # -- availability: who holds the model right now ------------------------
        online = ~((lane.node_down_from <= t) & (t < lane.node_down_until))
        if has_custody:
            covered = jnp.any(lane.custody & online[:, None], axis=0)
            coverage = jnp.mean(covered.astype(jnp.float32))
            live = jnp.all(covered)
        else:
            coverage = jnp.ones((), jnp.float32)
            live = jnp.ones((), bool)

        # -- admission: queued requests fill free slots in arrival order --------
        occ = state.slot_req < n_req
        waiting = (~state.admitted) & (lane.arrivals <= t)
        # funding is strict (balance - fee > min_shares, the can_infer
        # boundary) and accounts for waiting same-holder siblings: the
        # k-th waiting request of a holder (by request index) must afford
        # k+1 fees.  Any same-step admitted subset of a holder then needs
        # at least |subset| fees — a burst can never drive a balance past
        # the boundary, whatever order admission picks.  The index-prefix
        # rule is deliberately deterministic: when a holder cannot fund
        # every waiting sibling, the LOWEST-index ones stay fundable (a
        # documented tie-break, not a fairness guarantee).
        idx = jnp.arange(n_req)
        prior_same = jnp.sum((lane.holders[:, None] == lane.holders[None, :])
                             & waiting[None, :]
                             & (idx[:, None] > idx[None, :]), axis=1)
        funded = (state.balances[lane.holders]
                  - (prior_same + 1).astype(jnp.float32) * lane.fee
                  > cfg.min_shares)
        cand = waiting & funded & live
        # FIFO: priority by (arrival step, request index) — a request that
        # has waited longer is admitted first, whatever its index (ties
        # and the monotone-arrival builders reduce to request order)
        fifo = lane.arrivals * n_req + idx                     # (R,)
        rank = jnp.sum(cand[None, :]
                       & (fifo[None, :] < fifo[:, None]), axis=1)
        admit = cand & (rank < jnp.sum(~occ))
        free_first = jnp.argsort(occ)            # free slots, in slot order
        slot_of = free_first[jnp.clip(rank, 0, slots - 1)]
        scatter_to = jnp.where(admit, slot_of, slots)
        upd = jnp.full((slots,), -1, jnp.int32).at[scatter_to].set(
            jnp.arange(n_req, dtype=jnp.int32), mode="drop")
        newly = upd >= 0
        slot_req = jnp.where(newly, upd, state.slot_req)
        slot_t = jnp.where(newly, 0, state.slot_t)
        caches = jax.tree.map(
            lambda init, c: jnp.where(
                newly.reshape((slots,) + (1,) * init.ndim),
                init[None], c),
            template, state.caches)
        balances = state.balances.at[
            jnp.where(admit, lane.holders, lane.balances.shape[0])
        ].add(-lane.fee, mode="drop")
        admitted = state.admitted | admit
        occ = slot_req < n_req

        # -- decode: every slot advances one token ------------------------------
        req = jnp.minimum(slot_req, n_req - 1)
        plen = lane.prompt_lens[req]
        tok_in = jnp.where(slot_t < plen,
                           prompts[req, jnp.clip(slot_t, 0, p_max - 1)],
                           state.last_tok)
        logits, new_caches = decode_all(params, tok_in[:, None, None], caches)
        next_tok = jnp.argmax(logits[:, 0, -1], axis=-1).astype(jnp.int32)

        # -- record / retire ----------------------------------------------------
        advance = occ & live
        gen_i = slot_t - (plen - 1)
        budget = lane.max_new[req]
        rec = advance & (gen_i >= 0) & (gen_i < budget)
        out_tokens = state.out_tokens.at[
            jnp.where(rec, req, n_req), jnp.clip(gen_i, 0, max_new - 1)
        ].set(next_tok, mode="drop")
        finished = rec & (gen_i == budget - 1)
        done = state.done.at[jnp.where(finished, req, n_req)].set(
            True, mode="drop")
        slot_t = jnp.where(advance, slot_t + 1, slot_t)
        last_tok = jnp.where(advance, next_tok, state.last_tok)
        caches = jax.tree.map(
            lambda new, old: jnp.where(
                advance.reshape((slots,) + (1,) * (new.ndim - 1)), new, old),
            new_caches, caches)
        slot_req = jnp.where(finished, n_req, slot_req)

        new_state = ServeState(
            caches=caches, slot_req=slot_req, slot_t=slot_t,
            last_tok=last_tok, admitted=admitted, done=done,
            balances=balances, out_tokens=out_tokens)
        record = ServeRecord(
            coverage=coverage, live=live,
            n_active=jnp.sum(occ).astype(jnp.int32),
            n_admitted=jnp.sum(admit).astype(jnp.int32),
            new_tokens=jnp.sum(rec).astype(jnp.int32),
            # serviceable backlog only: credential-refused waiters are not
            # demand (they would otherwise poison the availability metric
            # — and hence the served/degraded classification — forever)
            queued=(jnp.sum(waiting & funded)
                    - jnp.sum(admit)).astype(jnp.int32))
        return new_state, record

    def init_state(lane: ServeLane) -> ServeState:
        caches = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (slots,) + l.shape), template)
        return ServeState(
            caches=caches,
            slot_req=jnp.full((slots,), n_req, jnp.int32),
            slot_t=jnp.zeros((slots,), jnp.int32),
            last_tok=jnp.zeros((slots,), jnp.int32),
            admitted=jnp.zeros((n_req,), bool),
            done=jnp.zeros((n_req,), bool),
            balances=lane.balances.astype(jnp.float32),
            out_tokens=jnp.zeros((n_req, max_new), jnp.int32))

    return step, init_state


@dataclass
class ServeResult:
    """One lane's host-side outcome.  ``wall_s`` is the measured wall time
    of the lane's program (for ``run_many`` campaigns: the shared program's
    wall split evenly across lanes, so per-lane ``tok_per_s`` is an
    amortized rate)."""
    tokens: np.ndarray        # (R, max_new) int32
    done: np.ndarray          # (R,) bool
    admitted: np.ndarray      # (R,) bool
    balances: np.ndarray      # (H,) f32 — final credential balances
    coverage: np.ndarray      # (T,) f32
    live: np.ndarray          # (T,) bool
    n_active: np.ndarray      # (T,) int32
    n_admitted: np.ndarray    # (T,) int32
    new_tokens: np.ndarray    # (T,) int32
    queued: np.ndarray        # (T,) int32
    wall_s: float = 0.0

    @property
    def tokens_served(self) -> int:
        return int(self.new_tokens.sum())

    @property
    def tok_per_s(self) -> float:
        return self.tokens_served / max(self.wall_s, 1e-9)

    @property
    def availability(self) -> float:
        """Fraction of *demand* steps (work queued or in flight) on which
        serving was live.  1.0 when there was never demand."""
        demand = (self.n_active > 0) | (self.queued > 0)
        if not demand.any():
            return 1.0
        return float((self.live & demand).sum() / demand.sum())


def settle_fees(ledger, holders: Sequence[str], result: ServeResult,
                fee: float) -> Dict[str, float]:
    """Mirror a serving lane's device-side fee spending back onto the host
    :class:`~repro.core.ledger.Ledger`, closing the §4.1 inference-market
    loop: admission fees deducted on device (``ServeState.balances``) become
    ``Ledger.charge_fee`` events, and the accumulated pool is paid out to
    stakers pro-rata by stake (``Ledger.distribute_fees``) — serving income
    flows to the capital that keeps the model held.

    The lane must have been built from this ledger's balances
    (``ledger.balance_vector(holders)`` → ``ServeLane.balances``); each
    holder's spend is recovered as an integer number of fees (device
    balances are f32 — rounding squashes the accumulation noise), so the
    ledger's conservation invariant survives the round-trip bit-exactly.
    Returns the per-staker payouts."""
    init = ledger.balance_vector(holders)
    for name, b0, b1 in zip(holders, init, result.balances):
        spent = fee * round(float(b0 - b1) / fee) if fee > 0 else 0.0
        if spent > 0:
            ledger.charge_fee(name, spent)
    return ledger.distribute_fees()


def _result_from_device(state: ServeState, recs: ServeRecord,
                        wall_s: float = 0.0) -> ServeResult:
    return ServeResult(
        tokens=np.asarray(state.out_tokens),
        done=np.asarray(state.done),
        admitted=np.asarray(state.admitted),
        balances=np.asarray(state.balances),
        coverage=np.asarray(recs.coverage),
        live=np.asarray(recs.live),
        n_active=np.asarray(recs.n_active),
        n_admitted=np.asarray(recs.n_admitted),
        new_tokens=np.asarray(recs.new_tokens),
        queued=np.asarray(recs.queued),
        wall_s=wall_s)


class ServingEngine:
    """Device-resident continuous-batching server: one compiled
    ``lax.scan`` of :func:`make_serve_step` per (lane-shape, custody)
    signature, cached so repeated runs (tests, benchmarks, property
    examples) never retrace.

    ``run`` serves one :class:`ServeLane`; ``run_many`` vmaps a stacked
    campaign of lanes through the same scan — ONE program for a whole
    (load × churn × redundancy × coalition) grid.  ``prompts`` given at
    construction are the default workload; ``run``/``run_many`` accept a
    same-shaped override without retracing (prompts are a traced program
    argument).

    ``plan`` (a :class:`~repro.core.placement.MeshPlan`) shards
    ``run_many``'s lane axis over the plan's mesh (bit-exact — lanes are
    embarrassingly parallel) and the shared params over its within-lane
    axes (allclose); single-lane ``run`` has no lane axis to shard and
    ignores it.  Lowering failures under a plan re-raise through
    ``plan.reraise_lowering`` (the ``compat.collectives_emulated()``
    gate)."""

    def __init__(self, model, cfg: ServingConfig, prompts: Array,
                 plan: Optional[MeshPlan] = None):
        self.model = model
        self.cfg = cfg
        self.prompts = jnp.asarray(prompts, jnp.int32)
        self.plan = plan
        self._programs: Dict[Tuple[bool, bool], Callable] = {}

    def _program(self, has_custody: bool, vmapped: bool) -> Callable:
        key = (has_custody, vmapped)
        if key not in self._programs:
            step, init_state = make_serve_step(
                self.model, self.cfg, tuple(self.prompts.shape),
                has_custody=has_custody)

            def run(params, prompts, lane):
                def body(st, t):
                    return step(params, prompts, lane, st, t)
                return jax.lax.scan(body, init_state(lane),
                                    jnp.arange(self.cfg.steps))

            if vmapped and self.plan is not None:
                fn = jax.vmap(run, in_axes=(None, None, 0),
                              spmd_axis_name=self.plan.lanes_axis)
            elif vmapped:
                fn = jax.vmap(run, in_axes=(None, None, 0))
            else:
                fn = run
            self._programs[key] = jax.jit(fn)
        return self._programs[key]

    def program(self, *, has_custody: bool, vmapped: bool) -> Callable:
        """THE engine program for this (custody, vmapped) signature — the
        jitted ``fn(params, prompts, lane(s))`` that :meth:`run` /
        :meth:`run_many` execute, straight from the program cache.  Public
        so ``analysis.jaxpr_audit`` traces the real serve scan (and so
        callers can pre-lower it) instead of a reimplementation."""
        return self._program(has_custody, vmapped)

    def _check(self, lane: ServeLane,
               prompts: Optional[Array]) -> Array:
        budgets = np.asarray(lane.max_new)
        if budgets.max() > self.cfg.max_new or budgets.min() < 1:
            raise ValueError(
                "per-request max_new must lie in [1, "
                f"{self.cfg.max_new}] (the engine's decode budget) — a "
                "zero budget would wedge its slot for the whole horizon")
        plens = np.asarray(lane.prompt_lens)
        if plens.max() > self.prompts.shape[-1] or plens.min() < 1:
            raise ValueError(
                f"prompt_lens must lie in [1, {self.prompts.shape[-1]}] "
                "(the engine's prompt buffer width) — a longer prompt "
                "would silently re-feed the last buffered token")
        if prompts is None:
            return self.prompts
        prompts = jnp.asarray(prompts, jnp.int32)
        if prompts.shape != self.prompts.shape:
            raise ValueError(
                f"prompts override must match the engine's compiled shape "
                f"{self.prompts.shape}, got {prompts.shape}")
        return prompts

    def run(self, params, lane: ServeLane,
            prompts: Optional[Array] = None) -> ServeResult:
        p = self._check(lane, prompts)
        fn = self._program(lane.custody is not None, False)
        t0 = time.perf_counter()
        state, recs = jax.block_until_ready(fn(params, p, lane))
        return _result_from_device(state, recs, time.perf_counter() - t0)

    def run_many(self, params, lanes: ServeLane,
                 prompts: Optional[Array] = None) -> List[ServeResult]:
        p = self._check(lanes, prompts)
        fn = self._program(lanes.custody is not None, True)
        t0 = time.perf_counter()
        if self.plan is not None:
            lanes = self.plan.place_lanes(lanes)
            params = self.plan.place_params(params)
            with self.plan.mesh:
                try:
                    state, recs = jax.block_until_ready(fn(params, p, lanes))
                except Exception as e:
                    self.plan.reraise_lowering(e)
        else:
            state, recs = jax.block_until_ready(fn(params, p, lanes))
        wall = time.perf_counter() - t0
        n = int(lanes.arrivals.shape[0])
        out = []
        for i in range(n):
            out.append(_result_from_device(
                jax.tree.map(lambda x: x[i], state),
                jax.tree.map(lambda x: x[i], recs),
                wall / n))
        return out


# ============================== lane building ==================================
def build_lane(*, n_requests: int, prompt_lens: Sequence[int],
               max_new, steps: int, n_nodes: int,
               balances: Sequence[float], fee: float = 1.0,
               load: Optional[float] = None,
               arrivals: Optional[Sequence[int]] = None,
               holders: Optional[Sequence[int]] = None,
               custody: Optional[np.ndarray] = None,
               churn_rate: float = 0.0,
               coalition_fraction: float = 0.0,
               defect_step: Optional[int] = None,
               seed: int = 0) -> ServeLane:
    """Host-side :class:`ServeLane` builder — the serving twin of
    ``derailment._sweep_lane``.

    ``max_new`` is the per-request decode budget — a scalar broadcast to
    all requests or a length-R sequence (mixed budgets are what continuous
    batching exists for: slots retire per-request, no head-of-line
    padding).  ``load`` (requests per step) spaces arrivals as
    ``floor(r / load)`` unless explicit ``arrivals`` are given.
    ``coalition_fraction`` marks
    the *last* ``ceil(fraction * N)`` roster slots (the same tail
    convention as ``CustodyConfig``) as a defecting coalition that goes
    down at ``defect_step`` and never returns — the inference no-off
    attack.  ``churn_rate`` makes that fraction of the remaining nodes
    transient: each gets one staggered mid-horizon *outage window* (down,
    then back up), so redundancy-starved shards open coverage gaps that
    later heal — the "degraded" regime.  Drawn with ``seed`` (numpy),
    deliberately separate from any model seed: sweeping serving seeds
    varies churn, never the custody draw."""
    if arrivals is None:
        if load is None or load <= 0:
            raise ValueError("pass either arrivals or a positive load")
        arrivals = np.floor(np.arange(n_requests) / load).astype(np.int32)
    arrivals = np.asarray(arrivals, np.int32)
    prompt_lens = np.asarray(prompt_lens, np.int32)
    max_new = np.broadcast_to(np.asarray(max_new, np.int32),
                              (n_requests,)).copy()
    if arrivals.shape != (n_requests,) or prompt_lens.shape != (n_requests,):
        raise ValueError("arrivals / prompt_lens must have shape (n_requests,)")
    balances = np.asarray(balances, np.float32)
    if holders is None:
        holders = np.arange(n_requests, dtype=np.int32) % balances.shape[0]
    holders = np.asarray(holders, np.int32)

    down_from = np.full(n_nodes, _FAR, np.int32)
    down_until = np.full(n_nodes, _FAR, np.int32)
    n_coal = int(np.ceil(coalition_fraction * n_nodes))
    if n_coal:
        down_from[n_nodes - n_coal:] = (steps // 3 if defect_step is None
                                        else defect_step)
    if churn_rate > 0:
        rng = np.random.default_rng(seed)
        rest = np.arange(n_nodes - n_coal)
        k = min(len(rest), int(np.ceil(churn_rate * len(rest))))
        picked = rng.choice(rest, size=k, replace=False)
        lo, hi = max(1, steps // 4), max(2, (3 * steps) // 4)
        dur = max(2, steps // 6)
        for j, node in enumerate(sorted(int(i) for i in picked)):
            at = lo + (j * max(1, (hi - lo) // max(1, k))) % max(1, hi - lo)
            down_from[node] = at
            down_until[node] = at + dur
    return ServeLane(
        arrivals=jnp.asarray(arrivals),
        holders=jnp.asarray(holders),
        prompt_lens=jnp.asarray(prompt_lens),
        max_new=jnp.asarray(max_new),
        balances=jnp.asarray(balances),
        node_down_from=jnp.asarray(down_from),
        node_down_until=jnp.asarray(down_until),
        fee=jnp.asarray(fee, jnp.float32),
        custody=None if custody is None else jnp.asarray(custody))


# ============================ the serving campaign ==============================
@dataclass(frozen=True)
class ServingCell:
    """One lane of a serving sweep, classified."""
    load: float
    churn_rate: float
    redundancy: int
    coalition_fraction: float
    seed: int
    n_requests: int
    completed: int
    refused: int              # unadmitted for lack of credentials
    tokens_served: int
    availability: float       # live fraction of demand steps
    final_coverage: float

    @property
    def regime(self) -> str:
        """The serving twin of ``DerailmentResult.extractability``:

        - ``halted``: credentialed work left unserved after coverage loss
          stalled serving (``availability < 1``).  A healed outage that
          consumed the horizon still counts — the coverage loss, not the
          load, spent the capacity; when both overload and an outage
          contribute, attribution goes to the outage;
        - ``backlogged``: work left unserved with every demand step live —
          offered load exceeded capacity within the horizon (a load
          regime, not a no-off one);
        - ``degraded``: everything served, but coverage gaps stalled
          serving on some demand steps (availability < 1);
        - ``served``: everything served, every demand step live.
        """
        pending = self.n_requests - self.completed - self.refused
        if pending > 0:
            return "halted" if self.availability < 1.0 else "backlogged"
        if self.availability < 1.0:
            return "degraded"
        return "served"


@dataclass
class ServingResult:
    """Every cell of a ``scenarios.ServingGrid``, plus how it was compiled
    (one program for ``n_runs`` lanes) and the aggregate decode rate."""
    grid: Any                 # scenarios.ServingGrid
    cells: List[ServingCell]
    n_programs: int
    n_runs: int
    wall_s: float
    tokens_total: int
    n_devices: int = 1        # devices the sweep's mesh plan spanned

    @property
    def runs_per_s(self) -> float:
        return self.n_runs / max(self.wall_s, 1e-9)

    @property
    def tok_per_s(self) -> float:
        return self.tokens_total / max(self.wall_s, 1e-9)

    def availability_table(self) -> str:
        """The serving phase diagram: one row per (redundancy [, coalition
        fraction], churn rate), one column per load; each cell shows the
        regime letter per seed — S = served, D = degraded, H = halted,
        B = backlogged — plus the mean availability."""
        loads = sorted({c.load for c in self.cells})
        coal = len({c.coalition_fraction for c in self.cells}) > 1
        rows = sorted({(c.redundancy, c.coalition_fraction, c.churn_rate)
                       for c in self.cells})
        labels = [f"r={r}" + (f" coal={cf:.2f}" if coal else "")
                  + f" churn={ch:.2f}" for r, cf, ch in rows]
        width = max([22] + [len(l) + 2 for l in labels])
        head = "serving".ljust(width) + "".join(f"load={l:.2f}".rjust(16)
                                                for l in loads)
        code = {"served": "S", "degraded": "D", "halted": "H",
                "backlogged": "B"}
        lines = [head]
        for (r, cf, ch), label in zip(rows, labels):
            cells = []
            for l in loads:
                cell = [c for c in self.cells
                        if (c.redundancy, c.coalition_fraction,
                            c.churn_rate) == (r, cf, ch)
                        and abs(c.load - l) < 1e-9]
                if not cell:
                    cells.append("-".rjust(16))
                    continue
                marks = "".join(code[c.regime] for c in cell)
                avail = sum(c.availability for c in cell) / len(cell)
                cells.append(f"{marks} a={avail:.2f}".rjust(16))
            lines.append(label.ljust(width) + "".join(cells))
        lines.append("(S=served  D=degraded  H=halted  B=backlogged, one "
                     "letter per seed; a = availability)")
        return "\n".join(lines)


def sweep(model, params, grid, *, prompts: Optional[Array] = None,
          plan: Optional[MeshPlan] = None) -> ServingResult:
    """Measure a whole serving phase diagram — every (load × churn ×
    redundancy × coalition × seed) cell of a ``scenarios.ServingGrid`` —
    as **one** compiled device program, mirroring ``derailment.sweep``.

    Load rides in the traced ``arrivals`` lane, churn and coalition
    defection in the ``node_down_from``/``node_down_until`` outage lanes,
    redundancy in the traced ``custody`` lane; prompts and the engine
    program are shared
    by every cell.  Each lane reproduces the single-run
    :meth:`ServingEngine.run` for the same parameters (one scan, vmapped).

    ``plan`` (e.g. ``MeshPlan.from_grid(grid)``) shards the lane axis over
    the plan's mesh — bit-exact (pinned in
    ``tests/test_campaign_sharded.py``) — and the shared model params over
    its within-lane axes (allclose).
    """
    from repro.core.unextractable import assign_matrix

    r, p = grid.n_requests, grid.prompt_len
    if prompts is None:
        prompts = jax.random.randint(jax.random.PRNGKey(0), (r, p), 0,
                                     model.cfg.vocab_size)
    # varied prompt lengths exercise mixed prefill/decode slot states
    prompt_lens = (p // 2 + np.arange(r) % (p - p // 2 + 1)).astype(np.int32)
    cfg = ServingConfig(slots=grid.slots, max_new=grid.max_new,
                        steps=grid.steps)
    balances = np.full(grid.n_holders, grid.fee * grid.n_requests + 1.0,
                       np.float32)
    custody_for = {
        red: assign_matrix(grid.n_nodes, grid.num_shards, red, seed=0,
                           max_fraction=grid.max_fraction)
        for red in grid.redundancies}

    engine = ServingEngine(model, cfg, prompts, plan=plan)
    lanes, metas = [], []
    for load in grid.loads:
        for churn in grid.churn_rates:
            for red in grid.redundancies:
                for cf in grid.coalition_fractions:
                    for seed in grid.seeds:
                        lanes.append(build_lane(
                            n_requests=r, prompt_lens=prompt_lens,
                            max_new=grid.max_new,
                            steps=grid.steps, n_nodes=grid.n_nodes,
                            balances=balances, fee=grid.fee, load=load,
                            custody=custody_for[red], churn_rate=churn,
                            coalition_fraction=cf,
                            defect_step=grid.defect_step, seed=seed))
                        metas.append((load, churn, red, cf, seed))

    t0 = time.perf_counter()
    results = engine.run_many(params, stack_serve_lanes(lanes))
    wall = time.perf_counter() - t0

    cells = []
    for (load, churn, red, cf, seed), lane, res in zip(metas, lanes, results):
        pending = ~res.done
        # a pending request counts as credential-refused only when serving
        # never halted in its lane — in a halted lane the coverage loss,
        # not the balance, explains unserved work (balances only decrease,
        # so an exhausted balance at the end does not prove the request
        # was ever refused while serving was live)
        refused = pending & ~res.admitted & res.live.all() & (
            res.balances[np.asarray(lane.holders)] - grid.fee
            <= cfg.min_shares)
        cells.append(ServingCell(
            load=load, churn_rate=churn, redundancy=red,
            coalition_fraction=cf, seed=seed, n_requests=r,
            completed=int(res.done.sum()), refused=int(refused.sum()),
            tokens_served=res.tokens_served,
            availability=res.availability,
            final_coverage=float(res.coverage[-1])))
    return ServingResult(grid=grid, cells=cells, n_programs=1,
                         n_runs=len(lanes), wall_s=wall,
                         tokens_total=sum(c.tokens_served for c in cells),
                         n_devices=plan.n_devices if plan is not None else 1)
