"""Fractional-ownership ledger (paper §4): contribution-proportional shares.

The incentive core of Protocol Learning: each verified unit of useful work
mints shares; inference access requires credentials backed by shares; a
slashed node loses its stake (verification.py) and forfeits pending shares.

Invariants (property-tested):
- conservation: every unit of value entering the ledger (mint events,
  staked external capital) is still accounted for — as balances, stakes,
  the slash pool, the fee pool, or burned shares.  Jackpots do NOT mint:
  they are funded from the slash pool (capped by it), so a validator can
  never be paid more than cheaters actually forfeited.
- monotonicity: honest work never decreases a node's balance
- proportionality: balances / total == contributed work / total work
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass
class Ledger:
    balances: Dict[str, float] = field(default_factory=dict)
    stakes: Dict[str, float] = field(default_factory=dict)
    burned: float = 0.0          # forfeited shares
    burned_stake: float = 0.0    # cumulative slashed stake (monotone counter)
    slash_pool: float = 0.0      # slashed stake not yet paid out as jackpots
    fee_pool: float = 0.0        # inference fees awaiting distribution
    history: List[Tuple[str, str, float]] = field(default_factory=list)

    # -- shares ---------------------------------------------------------------
    @property
    def total_shares(self) -> float:
        return sum(self.balances.values())

    def record_contribution(self, node: str, work_units: float) -> None:
        if work_units < 0:
            raise ValueError("work must be non-negative")
        self.balances[node] = self.balances.get(node, 0.0) + work_units
        self.history.append(("mint", node, work_units))

    def ownership_fraction(self, node: str) -> float:
        t = self.total_shares
        return self.balances.get(node, 0.0) / t if t > 0 else 0.0

    def transfer(self, src: str, dst: str, amount: float) -> None:
        """Credentials are transferable (paper §4.1)."""
        if amount < 0 or self.balances.get(src, 0.0) < amount:
            raise ValueError("insufficient balance")
        self.balances[src] -= amount
        self.balances[dst] = self.balances.get(dst, 0.0) + amount
        self.history.append(("transfer", f"{src}->{dst}", amount))

    # -- staking / slashing -----------------------------------------------------
    def stake(self, node: str, amount: float) -> None:
        """Lock external capital behind ``node``.  The inflow is recorded in
        the history so ``check_conservation`` can balance it against the
        stakes / slash-pool / jackpot side of the books."""
        if amount < 0:
            raise ValueError("stake must be non-negative")
        self.stakes[node] = self.stakes.get(node, 0.0) + amount
        self.history.append(("stake", node, amount))

    def slash(self, node: str) -> float:
        """Destroy the node's stake + forfeit its shares (caught cheating).

        Slashing a node the ledger has never seen (no stake, no balance) is
        a **no-op recording nothing**: there is no capital to destroy, and a
        phantom ``("slash", node, 0.0)`` event would put a participant that
        never staked or contributed into the audit trail."""
        if node not in self.stakes and node not in self.balances:
            return 0.0
        stake_lost = self.stakes.pop(node, 0.0)
        shares_lost = self.balances.pop(node, 0.0)
        self.burned += shares_lost
        self.burned_stake += stake_lost
        self.slash_pool += stake_lost
        self.history.append(("slash", node, stake_lost + shares_lost))
        return stake_lost + shares_lost

    def pay_jackpot(self, validator: str, amount: float) -> float:
        """Validator reward for catching bad work [41, 66].

        Jackpots are funded from the slash pool, never minted: the payout
        is capped at what slashed cheaters actually forfeited, and the
        history records the amount actually paid.  Returns that amount."""
        if amount < 0:
            raise ValueError("jackpot must be non-negative")
        paid = min(amount, self.slash_pool)
        self.slash_pool -= paid
        self.balances[validator] = self.balances.get(validator, 0.0) + paid
        self.history.append(("jackpot", validator, paid))
        return paid

    # -- fees (§4.1 inference markets) ------------------------------------------
    def charge_fee(self, holder: str, amount: float) -> None:
        """Move ``amount`` shares from ``holder`` into the fee pool (an
        inference request's fee).  Insufficient balance is an error — the
        device-side gate in ``core.serving`` refuses the request instead."""
        if amount < 0 or self.balances.get(holder, 0.0) < amount:
            raise ValueError("insufficient balance for fee")
        self.balances[holder] -= amount
        self.fee_pool += amount
        self.history.append(("fee", holder, amount))

    def distribute_fees(self) -> Dict[str, float]:
        """Pay the accumulated fee pool out to stakers pro-rata by stake
        (stake-weighted fee market: serving income flows to the capital
        that keeps the model held).  No stakers → the pool carries over."""
        total_stake = sum(self.stakes.values())
        if total_stake <= 0.0 or self.fee_pool <= 0.0:
            return {}
        pool, payouts = self.fee_pool, {}
        for node, s in self.stakes.items():
            share = pool * (s / total_stake)
            self.balances[node] = self.balances.get(node, 0.0) + share
            self.fee_pool -= share
            payouts[node] = share
            self.history.append(("fee_payout", node, share))
        return payouts

    # -- inference credentials (§4.1) -----------------------------------------
    def can_infer(self, holder: str, min_shares: float = 0.0) -> bool:
        """Inference access requires *strictly more* than ``min_shares``
        (the boundary is exclusive): at the default ``min_shares=0`` a
        holder with a zero balance — including one who just transferred
        their entire balance away — is refused, so credentials cannot be
        spent and kept at the same time.  ``core.serving`` applies the
        same strict ``balance - fee > min_shares`` gate on device."""
        return self.balances.get(holder, 0.0) > min_shares

    def balance_vector(self, holders: List[str]) -> List[float]:
        """Vectorized ledger view for the device-side serving engine: the
        balances of ``holders`` in order (0.0 for unknown names), ready to
        become ``ServeLane.balances``."""
        return [self.balances.get(h, 0.0) for h in holders]

    def check_conservation(self) -> bool:
        """Every unit of value that entered the ledger (mints + staked
        capital) is still held somewhere: balances, stakes, the slash pool,
        the fee pool, or burned shares.  Transfers, fees, slashes, and
        pool-funded jackpots only move value between those buckets."""
        inflow = sum(a for op, _, a in self.history if op in ("mint", "stake"))
        held = (self.total_shares + sum(self.stakes.values())
                + self.burned + self.slash_pool + self.fee_pool)
        return abs(held - inflow) < 1e-6 * max(1.0, inflow)
