"""Fractional-ownership ledger (paper §4): contribution-proportional shares.

The incentive core of Protocol Learning: each verified unit of useful work
mints shares; inference access requires credentials backed by shares; a
slashed node loses its stake (verification.py) and forfeits pending shares.

Invariants (property-tested):
- conservation: total_shares == Σ balances (+ burned)
- monotonicity: honest work never decreases a node's balance
- proportionality: balances / total == contributed work / total work
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass
class Ledger:
    balances: Dict[str, float] = field(default_factory=dict)
    stakes: Dict[str, float] = field(default_factory=dict)
    burned: float = 0.0          # forfeited shares
    burned_stake: float = 0.0    # destroyed staked capital
    history: List[Tuple[str, str, float]] = field(default_factory=list)

    # -- shares ---------------------------------------------------------------
    @property
    def total_shares(self) -> float:
        return sum(self.balances.values())

    def record_contribution(self, node: str, work_units: float) -> None:
        if work_units < 0:
            raise ValueError("work must be non-negative")
        self.balances[node] = self.balances.get(node, 0.0) + work_units
        self.history.append(("mint", node, work_units))

    def ownership_fraction(self, node: str) -> float:
        t = self.total_shares
        return self.balances.get(node, 0.0) / t if t > 0 else 0.0

    def transfer(self, src: str, dst: str, amount: float) -> None:
        """Credentials are transferable (paper §4.1)."""
        if amount < 0 or self.balances.get(src, 0.0) < amount:
            raise ValueError("insufficient balance")
        self.balances[src] -= amount
        self.balances[dst] = self.balances.get(dst, 0.0) + amount
        self.history.append(("transfer", f"{src}->{dst}", amount))

    # -- staking / slashing -----------------------------------------------------
    def stake(self, node: str, amount: float) -> None:
        if amount < 0:
            raise ValueError("stake must be non-negative")
        self.stakes[node] = self.stakes.get(node, 0.0) + amount

    def slash(self, node: str) -> float:
        """Destroy the node's stake + forfeit its shares (caught cheating).

        Slashing a node the ledger has never seen (no stake, no balance) is
        a **no-op recording nothing**: there is no capital to destroy, and a
        phantom ``("slash", node, 0.0)`` event would put a participant that
        never staked or contributed into the audit trail."""
        if node not in self.stakes and node not in self.balances:
            return 0.0
        stake_lost = self.stakes.pop(node, 0.0)
        shares_lost = self.balances.pop(node, 0.0)
        self.burned += shares_lost
        self.burned_stake += stake_lost
        self.history.append(("slash", node, stake_lost + shares_lost))
        return stake_lost + shares_lost

    def pay_jackpot(self, validator: str, amount: float) -> None:
        """Validator reward for catching bad work [41, 66]."""
        self.balances[validator] = self.balances.get(validator, 0.0) + amount
        self.history.append(("jackpot", validator, amount))

    # -- inference credentials (§4.1) -----------------------------------------
    def can_infer(self, holder: str, min_shares: float = 0.0) -> bool:
        """Inference access requires *strictly more* than ``min_shares``
        (the boundary is exclusive): at the default ``min_shares=0`` a
        holder with a zero balance — including one who just transferred
        their entire balance away — is refused, so credentials cannot be
        spent and kept at the same time.  ``core.serving`` applies the
        same strict ``balance - fee > min_shares`` gate on device."""
        return self.balances.get(holder, 0.0) > min_shares

    def balance_vector(self, holders: List[str]) -> List[float]:
        """Vectorized ledger view for the device-side serving engine: the
        balances of ``holders`` in order (0.0 for unknown names), ready to
        become ``ServeLane.balances``."""
        return [self.balances.get(h, 0.0) for h in holders]

    def check_conservation(self) -> bool:
        minted = sum(a for op, _, a in self.history if op in ("mint", "jackpot"))
        return abs((self.total_shares + self.burned) - minted) < 1e-6 * max(1.0, minted)
