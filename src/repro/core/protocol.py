"""Protocol Model server (paper §4.1): credential-gated, custody-sharded
inference.

The serving counterpart of the swarm trainer: weights live only as custody
shards across participants; a request is served by reassembling activations
*inside* the protocol (here: reconstructing params transiently from the
full custody set, which by construction requires the whole swarm); callers
interact only through logits, never weights; access requires ledger
credentials.

Serving is cached per *online-node set*: the jitted apply and the
reconstructed params are built once per distinct set of live custody
holders and reused while that set recurs (a small LRU bounds the cache —
heavy churn evicts the oldest sets), instead of re-reconstructing the
full parameter tree on every request.  For batched multi-token serving
over a fixed slot pool —
churn *during* decode, admission queues, the availability phase diagram —
see ``core.serving`` (the continuous-batching engine this server's
single-shot ``serve`` is the transparent reference for).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro.core.ledger import Ledger
from repro.core.unextractable import (
    ShardCustody,
    reconstruct_params,
    shard_params,
)

Array = jax.Array


class ExtractionError(PermissionError):
    pass


class CredentialError(PermissionError):
    pass


@dataclass
class ProtocolModelServer:
    """Inference only within the protocol; weights never leave it."""

    model: object                        # repro.models.Model
    custody: ShardCustody
    ledger: Ledger
    _shards: Dict[str, Dict[int, Array]] = field(
        default_factory=dict, repr=False)    # node -> {shard_id: data}
    _template: Optional[object] = field(default=None, repr=False)
    _true_size: int = 0
    #: reconstructed params per frozenset of online nodes — reconstruction
    #: is O(model size), so churn-stable swarms pay it once, not per
    #: request.  LRU-bounded: each entry is a full parameter tree, and a
    #: heavily churning swarm can visit combinatorially many node sets.
    _params_cache: Dict[frozenset, object] = field(
        default_factory=dict, repr=False)
    _jit_prefill: Optional[Callable] = field(default=None, repr=False)
    cache_size: int = 8

    @classmethod
    def create(cls, model, params, nodes: List[str], ledger: Ledger, *,
               num_shards: int = 16, redundancy: int = 2, seed: int = 0,
               max_fraction: float = 0.5):
        custody = ShardCustody.assign(nodes, num_shards, redundancy, seed,
                                      max_fraction)
        shards, true_size = shard_params(params, num_shards)
        per_node: Dict[str, Dict[int, Array]] = {n: {} for n in nodes}
        for sid, holders in custody.assignment.items():
            for h in holders:
                per_node[h][sid] = shards[sid]
        template = jax.tree.map(lambda x: x, params)
        srv = cls(model=model, custody=custody, ledger=ledger)
        srv._shards = per_node
        srv._template = template
        srv._true_size = true_size
        srv._jit_prefill = jax.jit(model.prefill)
        return srv

    # -- protocol-side reassembly ------------------------------------------------
    def _gather(self, nodes: List[str]) -> Dict[int, Array]:
        gathered: Dict[int, Array] = {}
        for n in nodes:
            gathered.update(self._shards.get(n, {}))
        return gathered

    def _params_for(self, nodes: List[str]):
        """Reconstructed params for this online-node set, cached on the
        set (order-free).  Raises with the *missing shard ids* when the
        set cannot cover the model, so a serving outage is diagnosable."""
        key = frozenset(nodes)
        if key in self._params_cache:
            self._params_cache[key] = self._params_cache.pop(key)  # LRU bump
            return self._params_cache[key]
        gathered = self._gather(nodes)
        if len(gathered) < self.custody.num_shards:
            missing = self.custody.missing_shards(nodes)
            raise ExtractionError(
                f"swarm incomplete: {len(gathered)}/{self.custody.num_shards} "
                f"shards online, missing shard ids {missing}")
        params = reconstruct_params(gathered, self._template,
                                    self.custody.num_shards, self._true_size)
        while len(self._params_cache) >= max(1, self.cache_size):
            self._params_cache.pop(next(iter(self._params_cache)))
        self._params_cache[key] = params
        return params

    # -- the only public capability: logits ------------------------------------
    def serve(self, holder: str, batch, *,
              online_nodes: Optional[List[str]] = None):
        if not self.ledger.can_infer(holder):
            raise CredentialError(f"{holder} holds no credentials")
        nodes = online_nodes if online_nodes is not None else list(self._shards)
        return self._jit_prefill(self._params_for(nodes), batch)

    def decode(self, holder: str, prompts: Array, max_new: int, *,
               online_nodes: Optional[List[str]] = None):
        """Credential-gated batched greedy decoding through the scanned
        serving path (``core.serving.greedy_decode``) — multi-token
        inference without ever exposing the reconstructed weights."""
        from repro.core import serving
        if not self.ledger.can_infer(holder):
            raise CredentialError(f"{holder} holds no credentials")
        nodes = online_nodes if online_nodes is not None else list(self._shards)
        return serving.greedy_decode(self.model, self._params_for(nodes),
                                     prompts, max_new)

    # -- what an attacker coalition gets ----------------------------------------
    def attempt_extraction(self, coalition: List[str]):
        """Returns the (broken) params a coalition can reassemble — tests show
        they are unusable below full coverage."""
        gathered = self._gather(coalition)
        if len(gathered) >= self.custody.num_shards:
            raise ExtractionError(
                "coalition covers the full model — custody bound violated; "
                "this configuration is NOT a Protocol Model")
        return reconstruct_params(gathered, self._template,
                                  self.custody.num_shards, self._true_size)
