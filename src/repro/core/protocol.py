"""Protocol Model server (paper §4.1): credential-gated, custody-sharded
inference.

The serving counterpart of the swarm trainer: weights live only as custody
shards across participants; a request is served by reassembling activations
*inside* the protocol (here: reconstructing params transiently from the
full custody set, which by construction requires the whole swarm); callers
interact only through logits, never weights; access requires ledger
credentials.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.ledger import Ledger
from repro.core.unextractable import (
    ShardCustody,
    reconstruct_params,
    shard_params,
)

Array = jax.Array


class ExtractionError(PermissionError):
    pass


class CredentialError(PermissionError):
    pass


@dataclass
class ProtocolModelServer:
    """Inference only within the protocol; weights never leave it."""

    model: object                        # repro.models.Model
    custody: ShardCustody
    ledger: Ledger
    _shards: Dict[str, Dict[int, Array]] = None     # node -> {shard_id: data}
    _template: object = None
    _true_size: int = 0

    @classmethod
    def create(cls, model, params, nodes: List[str], ledger: Ledger, *,
               num_shards: int = 16, redundancy: int = 2, seed: int = 0,
               max_fraction: float = 0.5):
        custody = ShardCustody.assign(nodes, num_shards, redundancy, seed,
                                      max_fraction)
        shards, true_size = shard_params(params, num_shards)
        per_node: Dict[str, Dict[int, Array]] = {n: {} for n in nodes}
        for sid, holders in custody.assignment.items():
            for h in holders:
                per_node[h][sid] = shards[sid]
        template = jax.tree.map(lambda x: x, params)
        srv = cls(model=model, custody=custody, ledger=ledger)
        srv._shards = per_node
        srv._template = template
        srv._true_size = true_size
        return srv

    # -- the only public capability: logits ------------------------------------
    def serve(self, holder: str, batch, *, online_nodes: Optional[List[str]] = None):
        if not self.ledger.can_infer(holder):
            raise CredentialError(f"{holder} holds no credentials")
        nodes = online_nodes if online_nodes is not None else list(self._shards)
        gathered: Dict[int, Array] = {}
        for n in nodes:
            gathered.update(self._shards.get(n, {}))
        if len(gathered) < self.custody.num_shards:
            raise ExtractionError(
                f"swarm incomplete: {len(gathered)}/{self.custody.num_shards} shards online")
        params = reconstruct_params(gathered, self._template,
                                    self.custody.num_shards, self._true_size)
        return self.model.prefill(params, batch)

    # -- what an attacker coalition gets ----------------------------------------
    def attempt_extraction(self, coalition: List[str]):
        """Returns the (broken) params a coalition can reassemble — tests show
        they are unusable below full coverage."""
        gathered: Dict[int, Array] = {}
        for n in coalition:
            gathered.update(self._shards.get(n, {}))
        if len(gathered) >= self.custody.num_shards:
            raise ExtractionError(
                "coalition covers the full model — custody bound violated; "
                "this configuration is NOT a Protocol Model")
        return reconstruct_params(gathered, self._template,
                                  self.custody.num_shards, self._true_size)
