"""Protocol Models & unextractability (paper §4.1) — the custody engine.

A Protocol Model is (1) trustlessly co-trainable and (2) never extractable:
no coalition can reassemble a usable weight set for less compute than
retraining.  This module implements the custody layer and the extraction-
economics analysis the definition rests on, in a form the jit(vmap(scan))
campaign engine can sweep:

- the custody state is a device-resident ``(N, S)`` boolean **custody
  matrix** ``holds[n, s]`` (node n holds shard s) — redundant assignment
  with the invariant that a single node holds ≤ max_fraction of the model
  (redundancy r for elasticity — Moshpit/SWARM style);
- coalition analysis is pure jnp reductions over that matrix
  (:func:`shards_covered` / :func:`coverage_frac` / :func:`can_extract_all`
  / :func:`tolerates_departures_all` / :func:`missing_shards`), so a whole
  *stack* of coalitions — or one coalition per campaign lane — evaluates as
  one vmapped program (``core.swarm`` traces the matrix as
  ``LaneParams.custody``; ``core.derailment.sweep`` sweeps redundancy ×
  coalition fraction as campaign axes);
- :class:`ShardCustody` keeps the original name-keyed API (``assignment``
  / ``node_shards`` views, ``coverage``/``can_extract``/... methods) as
  thin wrappers over the matrix, for the server / checkpoint / example
  layers that speak node ids;
- an actual reconstruct path: :func:`shard_params` /
  :func:`reconstruct_params` on the host, and the traced twin
  :func:`masked_reconstruct` the campaign engine evaluates *inside* the
  compiled program — extraction succeeds exactly at full coverage, and
  below it the reassembled model is missing shards (tests show its loss is
  garbage);
- the economic comparison cost(acquire missing shards) vs cost(retrain)
  = 6·N·D (:func:`extraction_cost_flops` / :func:`is_protocol_model`).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ============================ assignment =======================================
def assign_matrix(n_nodes: int, num_shards: int, redundancy: int = 2,
                  seed: int = 0, max_fraction: float = 0.5) -> np.ndarray:
    """Round-robin-with-shuffle custody draw honouring the custody bound.

    Returns the ``(n_nodes, num_shards)`` boolean custody matrix.  Each
    shard is handed to ``redundancy`` distinct nodes, candidates visited in
    a freshly shuffled order per shard, skipping nodes already at the
    ``ceil(max_fraction * num_shards)`` per-node cap.  Raises
    ``ValueError`` when the bound is too tight for the swarm size.  Pure in
    ``seed`` — the same (n, S, r, seed, bound) always draws the same
    matrix, which is what lets a sweep share one matrix per redundancy.
    """
    if redundancy < 1:
        # redundancy 0 would never hit the "enough holders" break below and
        # silently assign every shard to every node under the cap — the
        # opposite of what the caller asked for
        raise ValueError(f"redundancy must be >= 1, got {redundancy}")
    rng = np.random.default_rng(seed)
    per_node_cap = int(np.ceil(max_fraction * num_shards))
    holds = np.zeros((n_nodes, num_shards), bool)
    order = list(range(n_nodes))
    for s in range(num_shards):
        rng.shuffle(order)
        n_holders = 0
        for n in order:
            if holds[n].sum() < per_node_cap:
                holds[n, s] = True
                n_holders += 1
            if n_holders == redundancy:
                break
        if n_holders < redundancy:
            raise ValueError("custody bound too tight for this swarm size")
    return holds


# ===================== vectorized coalition analysis ===========================
# All reductions take the (N, S) custody matrix plus a boolean coalition /
# departure mask with shape (..., N) — any number of leading batch axes —
# and reduce over the node axis, so a stacked batch of coalitions (or a
# vmapped campaign lane) evaluates in one call.

def shards_covered(holds: Array, coalition: Array) -> Array:
    """(..., N) coalition mask -> (..., S) bool: shards the coalition holds."""
    return jnp.any(holds & coalition[..., :, None], axis=-2)


def coverage_frac(holds: Array, coalition: Array) -> Array:
    """Fraction of the model's shards the coalition covers: (..., N) -> (...,)."""
    return jnp.mean(shards_covered(holds, coalition).astype(jnp.float32),
                    axis=-1)


def can_extract_all(holds: Array, coalition: Array) -> Array:
    """(..., N) -> (...,) bool: coalition covers *every* shard."""
    return jnp.all(shards_covered(holds, coalition), axis=-1)


def tolerates_departures_all(holds: Array, departed: Array) -> Array:
    """Elasticity: the swarm still holds every shard after the departures
    marked in the (..., N) mask — (...,) bool."""
    return jnp.all(jnp.any(holds & ~departed[..., :, None], axis=-2), axis=-1)


def missing_shards(holds: Array, coalition: Array) -> Array:
    """(..., N) -> (...,) int32: shards the coalition does NOT cover."""
    s = holds.shape[-1]
    return (s - jnp.sum(shards_covered(holds, coalition), axis=-1)
            ).astype(jnp.int32)


# ============================ ShardCustody =====================================
@dataclass
class ShardCustody:
    """Custody state: the ``(N, S)`` matrix plus the node-id row labels.

    The matrix is the single source of truth; ``assignment`` and
    ``node_shards`` are derived dict/set *views* kept for the name-keyed
    consumers (Protocol Model server, custody checkpoints, examples).
    """
    num_shards: int
    redundancy: int
    node_ids: Tuple[str, ...]
    holds: Array                              # (N, S) bool, device-resident

    @staticmethod
    def assign(nodes: Sequence[str], num_shards: int, redundancy: int = 2,
               seed: int = 0, max_fraction: float = 0.5) -> "ShardCustody":
        """Round-robin-with-shuffle assignment honouring the custody bound."""
        holds = assign_matrix(len(nodes), num_shards, redundancy, seed,
                              max_fraction)
        return ShardCustody(num_shards, redundancy, tuple(nodes),
                            jnp.asarray(holds))

    # -- name-keyed compat views ------------------------------------------------
    @property
    def assignment(self) -> Dict[int, List[str]]:
        """shard -> holder ids (node order; the matrix is order-free)."""
        h = np.asarray(self.holds)
        return {s: [self.node_ids[n] for n in np.flatnonzero(h[:, s])]
                for s in range(self.num_shards)}

    @property
    def node_shards(self) -> Dict[str, Set[int]]:
        """node -> shards held."""
        h = np.asarray(self.holds)
        return {nid: set(np.flatnonzero(h[n]).tolist())
                for n, nid in enumerate(self.node_ids)}

    def coalition_mask(self, coalition: Sequence[str]) -> Array:
        """Names -> (N,) boolean mask (unknown names are ignored, matching
        the old dict ``.get(n, set())`` semantics)."""
        members = set(coalition)
        return jnp.asarray([nid in members for nid in self.node_ids])

    # -- coverage ---------------------------------------------------------------
    def coverage(self, coalition: Sequence[str]) -> float:
        return float(coverage_frac(self.holds, self.coalition_mask(coalition)))

    def can_extract(self, coalition: Sequence[str]) -> bool:
        return bool(can_extract_all(self.holds, self.coalition_mask(coalition)))

    def tolerates_departures(self, departed: Sequence[str]) -> bool:
        """Elasticity: the swarm still holds every shard after departures."""
        return bool(tolerates_departures_all(self.holds,
                                             self.coalition_mask(departed)))

    def missing_shards(self, coalition: Sequence[str]) -> List[int]:
        """The shard *ids* the coalition does NOT cover (the module-level
        :func:`missing_shards` is its traced twin and returns the count) —
        what a failed serve/extraction should report so the outage is
        diagnosable: which shards, hence (via ``assignment``) which
        departed holders."""
        covered = np.asarray(shards_covered(self.holds,
                                            self.coalition_mask(coalition)))
        return [int(s) for s in np.flatnonzero(~covered)]

    def min_extraction_coalition(self, exact: bool = False) -> int:
        """Size of a coalition achieving full coverage; -1 if even the full
        swarm cannot cover.

        Default is greedy set cover — an **upper** bound on the true
        minimum coalition (within ln S of it, but a bound from above: the
        real custody guarantee can only be *stronger* than the greedy
        number suggests).  ``exact=True`` brute-forces subsets in
        increasing size up to the greedy bound — exponential, meant for
        the small swarms where the governance question is sharp
        (property-tested ``exact <= greedy`` in tests/test_properties.py).
        """
        h = np.asarray(self.holds)
        greedy = _greedy_cover(h)
        if not exact or greedy < 0:
            return greedy
        nonempty = [int(n) for n in np.flatnonzero(h.any(axis=1))]
        for size in range(1, greedy):
            for combo in itertools.combinations(nonempty, size):
                if h[list(combo)].any(axis=0).all():
                    return size
        return greedy


def _greedy_cover(holds: np.ndarray) -> int:
    """Greedy set cover over the custody matrix (ties -> lowest node index,
    matching the original dict-insertion-order tie-break)."""
    remaining = np.ones(holds.shape[1], bool)
    available = holds.copy()
    size = 0
    while remaining.any():
        gains = (available & remaining).sum(axis=1)
        best = int(np.argmax(gains))
        if gains[best] == 0:
            return -1
        remaining &= ~available[best]
        available[best] = False
        size += 1
    return size


# -- shard/reassemble real parameter trees ---------------------------------------
def shard_params(params, num_shards: int):
    """Split a parameter pytree into num_shards flat chunks."""
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in jax.tree.leaves(params)])
    pad = (-flat.size) % num_shards
    flat = jnp.pad(flat, (0, pad))
    return list(flat.reshape(num_shards, -1)), flat.size - pad


def reconstruct_params(shards: Dict[int, Array], template, num_shards: int,
                       true_size: int):
    """Reassemble from held shards; missing shards are zero-filled (unusable).

    A zero-coverage coalition (no shards at all) gets the fully zero-filled
    template — the degenerate "every shard missing" case, not an error (it
    used to crash trying to reshape a size-0 flat vector)."""
    if shards:
        size = shards[next(iter(shards))].size
        flat = jnp.zeros((num_shards * size,), jnp.float32)
        for i, s in shards.items():
            flat = flat.at[i * size:(i + 1) * size].set(s)
        flat = flat[:true_size]
    else:
        flat = jnp.zeros((true_size,), jnp.float32)
    leaves = jax.tree.leaves(template)
    rebuilt, off = [], 0
    for l in leaves:
        rebuilt.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(jax.tree.structure(template), rebuilt)


def masked_reconstruct(params, covered: Array):
    """The traced twin of ``shard_params -> reconstruct_params``: zero-fill
    the shards *not* in the ``(S,)`` boolean ``covered`` mask of a params
    pytree, preserving structure/shapes/dtypes.

    Same chunking as :func:`shard_params` (flat fp32 concat, zero-pad to a
    multiple of S, shard s = contiguous chunk s), but fully jax-traceable —
    this is what the campaign engine's reconstruct-attack eval runs inside
    the compiled program to price what a coalition actually gets.  At full
    coverage it is the identity (exact roundtrip, including bf16 leaves:
    bf16 -> fp32 -> bf16 is value-preserving)."""
    leaves = jax.tree.leaves(params)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    num_shards = covered.shape[-1]
    pad = (-flat.size) % num_shards
    chunks = jnp.pad(flat, (0, pad)).reshape(num_shards, -1)
    flat2 = (chunks * covered[:, None]).reshape(-1)[:flat.size]
    rebuilt, off = [], 0
    for l in leaves:
        rebuilt.append(flat2[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(jax.tree.structure(params), rebuilt)


# ======================= swarm-lane custody config =============================
@dataclass(frozen=True)
class CustodyConfig:
    """Custody lane of a swarm run (``SwarmConfig.custody``).

    ``coalition_fraction`` marks the extraction coalition as the *last*
    ``ceil(fraction * N)`` roster slots — the same tail convention the
    scenario/sweep rosters use for attackers (honest first, adversaries
    appended), so "the byzantine minority doubles as the extraction
    coalition" needs no extra bookkeeping.  ``seed`` draws the custody
    matrix and is deliberately separate from the run seed (sweeping run
    seeds varies noise and churn, never who holds what — the
    ``topology_seed`` convention)."""
    num_shards: int = 16
    redundancy: int = 2
    seed: int = 0
    max_fraction: float = 0.5
    coalition_fraction: float = 0.0


def coalition_tail_mask(n_nodes: int, fraction: float) -> np.ndarray:
    """(N,) bool marking the last ``ceil(fraction * n_nodes)`` roster slots."""
    k = min(n_nodes, int(math.ceil(fraction * n_nodes)))
    mask = np.zeros(n_nodes, bool)
    if k:
        mask[n_nodes - k:] = True
    return mask


# -- economics (the definition's inequality) ------------------------------------
def retrain_cost_flops(param_count: int, tokens: int) -> float:
    return 6.0 * param_count * tokens


def extraction_cost_flops(custody: ShardCustody, coalition: Sequence[str],
                          cost_per_shard_flops: float) -> float:
    """Cost to acquire the shards the coalition is missing, by doing enough
    verified work to be assigned custody of each (join-and-leech strategy)."""
    missing = int(missing_shards(custody.holds,
                                 custody.coalition_mask(coalition)))
    return missing * cost_per_shard_flops


def is_protocol_model(custody: ShardCustody, coalition: Sequence[str],
                      param_count: int, tokens: int,
                      cost_per_shard_flops: float) -> bool:
    """Paper §4.1 property 2 for this coalition: extraction ≥ retraining."""
    if custody.can_extract(coalition):
        return False
    return (extraction_cost_flops(custody, coalition, cost_per_shard_flops)
            >= retrain_cost_flops(param_count, tokens))
