"""Protocol Models & unextractability (paper §4.1).

A Protocol Model is (1) trustlessly co-trainable and (2) never extractable:
no coalition can reassemble a usable weight set for less compute than
retraining.  This module implements the custody layer and the extraction-
economics analysis the definition rests on:

- ``ShardCustody``: redundant assignment of parameter shards to nodes
  (redundancy r for elasticity — Moshpit/SWARM style), with the invariant
  that a single node holds ≤ max_fraction of the model.
- coalition analysis: which fraction of the weights a coalition covers, the
  minimum coalition that covers everything, and the economic comparison
  cost(acquire missing shards) vs cost(retrain) = 6·N·D.
- an actual ``reconstruct``: proves extraction *succeeds* exactly when
  coverage is complete — and that below full coverage the reassembled model
  is missing shards (tests show its loss is garbage).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass
class ShardCustody:
    num_shards: int
    redundancy: int
    assignment: Dict[int, List[str]]          # shard -> holders
    node_shards: Dict[str, Set[int]]          # node -> shards held

    @staticmethod
    def assign(nodes: Sequence[str], num_shards: int, redundancy: int = 2,
               seed: int = 0, max_fraction: float = 0.5) -> "ShardCustody":
        """Round-robin-with-shuffle assignment honouring the custody bound."""
        rng = np.random.default_rng(seed)
        per_node_cap = int(np.ceil(max_fraction * num_shards))
        assignment: Dict[int, List[str]] = {}
        node_shards: Dict[str, Set[int]] = {n: set() for n in nodes}
        order = list(nodes)
        for s in range(num_shards):
            rng.shuffle(order)
            holders = []
            for n in order:
                if len(node_shards[n]) < per_node_cap:
                    holders.append(n)
                    node_shards[n].add(s)
                if len(holders) == redundancy:
                    break
            if len(holders) < redundancy:
                raise ValueError("custody bound too tight for this swarm size")
            assignment[s] = holders
        return ShardCustody(num_shards, redundancy, assignment, node_shards)

    # -- coverage ---------------------------------------------------------------
    def coverage(self, coalition: Sequence[str]) -> float:
        covered = set()
        for n in coalition:
            covered |= self.node_shards.get(n, set())
        return len(covered) / self.num_shards

    def can_extract(self, coalition: Sequence[str]) -> bool:
        return self.coverage(coalition) >= 1.0

    def min_extraction_coalition(self) -> int:
        """Greedy set-cover lower bound on coalition size for full coverage."""
        remaining = set(range(self.num_shards))
        size = 0
        shards = {n: set(s) for n, s in self.node_shards.items()}
        while remaining:
            best = max(shards, key=lambda n: len(shards[n] & remaining), default=None)
            if best is None or not (shards[best] & remaining):
                return -1
            remaining -= shards[best]
            del shards[best]
            size += 1
        return size

    def tolerates_departures(self, departed: Sequence[str]) -> bool:
        """Elasticity: the swarm still holds every shard after departures."""
        gone = set(departed)
        return all(any(h not in gone for h in holders)
                   for holders in self.assignment.values())


# -- shard/reassemble real parameter trees ---------------------------------------
def shard_params(params, num_shards: int):
    """Split a parameter pytree into num_shards flat chunks."""
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in jax.tree.leaves(params)])
    pad = (-flat.size) % num_shards
    flat = jnp.pad(flat, (0, pad))
    return list(flat.reshape(num_shards, -1)), flat.size - pad


def reconstruct_params(shards: Dict[int, Array], template, num_shards: int,
                       true_size: int):
    """Reassemble from held shards; missing shards are zero-filled (unusable).

    A zero-coverage coalition (no shards at all) gets the fully zero-filled
    template — the degenerate "every shard missing" case, not an error (it
    used to crash trying to reshape a size-0 flat vector)."""
    if shards:
        size = shards[next(iter(shards))].size
        flat = jnp.zeros((num_shards * size,), jnp.float32)
        for i, s in shards.items():
            flat = flat.at[i * size:(i + 1) * size].set(s)
        flat = flat[:true_size]
    else:
        flat = jnp.zeros((true_size,), jnp.float32)
    leaves = jax.tree.leaves(template)
    rebuilt, off = [], 0
    for l in leaves:
        rebuilt.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(jax.tree.structure(template), rebuilt)


# -- economics (the definition's inequality) ------------------------------------
def retrain_cost_flops(param_count: int, tokens: int) -> float:
    return 6.0 * param_count * tokens


def extraction_cost_flops(custody: ShardCustody, coalition: Sequence[str],
                          cost_per_shard_flops: float) -> float:
    """Cost to acquire the shards the coalition is missing, by doing enough
    verified work to be assigned custody of each (join-and-leech strategy)."""
    covered = set()
    for n in coalition:
        covered |= custody.node_shards.get(n, set())
    missing = custody.num_shards - len(covered)
    return missing * cost_per_shard_flops


def is_protocol_model(custody: ShardCustody, coalition: Sequence[str],
                      param_count: int, tokens: int,
                      cost_per_shard_flops: float) -> bool:
    """Paper §4.1 property 2 for this coalition: extraction ≥ retraining."""
    if custody.can_extract(coalition):
        return False
    return (extraction_cost_flops(custody, coalition, cost_per_shard_flops)
            >= retrain_cost_flops(param_count, tokens))
