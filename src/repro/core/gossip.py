"""Gossip averaging (paper §3.2): communication-efficient replacement for the
synchronous all-reduce, with convergence guarantees under time-varying
topologies [7, 10, 42, 51, 52, 77].

The mixing step is ``x ← W x`` with a doubly-stochastic Metropolis matrix
built from the (possibly per-round) adjacency; per-round per-node traffic is
O(degree · D) instead of the all-reduce's ring O(D) *with global
synchronization*.  Convergence to the exact mean is geometric with rate λ₂
(second eigenvalue of W) — benchmarked in bench_gossip.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# -- topologies ---------------------------------------------------------------
def ring_adjacency(n: int) -> np.ndarray:
    a = np.zeros((n, n), bool)
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = True
    a[idx, (idx - 1) % n] = True
    return a


def random_regular_adjacency(n: int, degree: int, seed: int = 0) -> np.ndarray:
    """Random degree-regular-ish graph (union of `degree/2` random ring perms)."""
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), bool)
    for _ in range(max(1, degree // 2)):
        perm = rng.permutation(n)
        a[perm, np.roll(perm, 1)] = True
        a[np.roll(perm, 1), perm] = True
    np.fill_diagonal(a, False)
    return a


def fully_connected_adjacency(n: int) -> np.ndarray:
    a = np.ones((n, n), bool)
    np.fill_diagonal(a, False)
    return a


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Doubly-stochastic mixing matrix from an undirected adjacency."""
    adj = np.asarray(adj, bool)
    deg = adj.sum(1)
    n = adj.shape[0]
    w = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if adj[i, j]:
                w[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(1))
    return w


def spectral_gap(w: np.ndarray) -> float:
    ev = np.sort(np.abs(np.linalg.eigvals(w)))[::-1]
    return float(1.0 - ev[1])


# -- mixing -------------------------------------------------------------------
def gossip_round(x: Array, w: Array) -> Array:
    """x: (N, ...) per-node values; one synchronous gossip mixing step."""
    n = x.shape[0]
    flat = x.reshape(n, -1)
    return (w.astype(flat.dtype) @ flat).reshape(x.shape)


@partial(jax.jit, static_argnames=("rounds",))
def gossip_average(x: Array, w: Array, rounds: int) -> Array:
    """``rounds`` mixing steps, jit-compiled (cached per round count)."""
    def body(x, _):
        return gossip_round(x, w), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out


def consensus_error(x: Array) -> Array:
    """Max node deviation from the true mean (convergence metric)."""
    n = x.shape[0]
    flat = x.reshape(n, -1)
    mean = jnp.mean(flat, axis=0, keepdims=True)
    return jnp.max(jnp.linalg.norm(flat - mean, axis=1))


def rounds_for_tolerance(w: np.ndarray, tol: float) -> int:
    """Analytic round count: error shrinks by (1-gap) per round."""
    gap = spectral_gap(w)
    if gap <= 0:
        return 10**9
    return int(np.ceil(np.log(tol) / np.log(max(1e-12, 1.0 - gap))))


def gossip_traffic_bytes(adj: np.ndarray, d: int, dtype_bytes: int = 4) -> int:
    """Bytes moved per round (each edge carries D values each way)."""
    return int(adj.sum()) * d * dtype_bytes


def allreduce_traffic_bytes(n: int, d: int, dtype_bytes: int = 4) -> int:
    """Ring all-reduce: 2(N-1)/N · D per node · N nodes."""
    return int(2 * (n - 1) * d * dtype_bytes)
