"""Gossip averaging (paper §3.2): communication-efficient replacement for the
synchronous all-reduce, with convergence guarantees under time-varying
topologies [7, 10, 42, 51, 52, 77].

The mixing step is ``x ← W x`` with a doubly-stochastic Metropolis matrix
built from the (possibly per-round) adjacency; per-round per-node traffic is
O(degree · D) instead of the all-reduce's ring O(D) *with global
synchronization*.  Convergence to the exact mean is geometric with rate λ₂
(second eigenvalue of W) — benchmarked in bench_gossip.py.

The graph layer — adjacency builders, Metropolis weights, spectral-gap
utilities, and the named-topology registry — lives in ``core.topology``
(this module grew into it) and is re-exported here for backward
compatibility.  This module keeps the mixing *runtime*: the gossip step,
the scanned multi-round average, consensus metrics, and traffic accounting.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import (  # noqa: F401  (compat re-exports)
    clustered_adjacency,
    fully_connected_adjacency,
    metropolis_weights,
    mixing_matrix,
    random_regular_adjacency,
    ring_adjacency,
    spectral_gap,
    torus_adjacency,
)

Array = jax.Array


# -- mixing -------------------------------------------------------------------
def gossip_round(x: Array, w: Array) -> Array:
    """x: (N, ...) per-node values; one synchronous gossip mixing step."""
    n = x.shape[0]
    flat = x.reshape(n, -1)
    return (w.astype(flat.dtype) @ flat).reshape(x.shape)


@partial(jax.jit, static_argnames=("rounds",))
def gossip_average(x: Array, w: Array, rounds: int) -> Array:
    """``rounds`` mixing steps, jit-compiled (cached per round count)."""
    def body(x, _):
        return gossip_round(x, w), None
    out, _ = jax.lax.scan(body, x, None, length=rounds)
    return out


def consensus_error(x: Array) -> Array:
    """Max node deviation from the true mean (convergence metric)."""
    n = x.shape[0]
    flat = x.reshape(n, -1)
    mean = jnp.mean(flat, axis=0, keepdims=True)
    return jnp.max(jnp.linalg.norm(flat - mean, axis=1))


def rounds_for_tolerance(w: np.ndarray, tol: float) -> int:
    """Analytic round count to shrink consensus error by ``tol``: error
    contracts by (1-gap) per round, so ``ceil(log tol / log(1-gap))``,
    clamped to >= 0 (``tol >= 1`` is already satisfied by round 0 — the
    unclamped formula used to return *negative* counts there).  A zero
    spectral gap means the mixing graph is disconnected and gossip never
    reaches consensus: that is now a loud ``ValueError`` instead of the old
    silent ``10**9`` sentinel."""
    if tol >= 1.0:
        return 0                 # round 0 satisfies it on ANY graph
    gap = spectral_gap(w)
    if gap <= 1e-9:
        raise ValueError(
            "mixing matrix has zero spectral gap (disconnected graph): "
            "gossip never reaches consensus — no finite round count exists")
    return max(0, int(np.ceil(np.log(tol) / np.log(max(1e-12, 1.0 - gap)))))


def gossip_traffic_bytes(adj: np.ndarray, d: int, dtype_bytes: int = 4) -> int:
    """Bytes moved per round (each edge carries D values each way)."""
    return int(adj.sum()) * d * dtype_bytes


def allreduce_traffic_bytes(n: int, d: int, dtype_bytes: int = 4) -> int:
    """Ring all-reduce: 2(N-1)/N · D per node · N nodes."""
    return int(2 * (n - 1) * d * dtype_bytes)
