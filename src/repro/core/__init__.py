"""Protocol Learning core: the paper's contribution as composable modules.

- aggregation    : byzantine-robust aggregators (§3.3)
- compression    : QSGD / top-k / PowerSGD wire compression (§3.1)
- gossip         : gossip averaging runtime (§3.2)
- topology       : communication graphs, mixing matrices, spectral gaps —
                   the decentralized round's graph layer (§3.2, §5.5)
- swarm          : elastic, heterogeneous, byzantine swarm trainer (§3);
                   batched jit engine + sequential reference oracle
- scenarios      : named scenario registry (byzantine mixes, churn, wire
                   compression, audit economics) consumed by benchmarks,
                   examples, and tests
- ledger         : fractional-ownership credentials (§4)
- verification   : stake/slash game-theoretic compute verification (§4.2)
- unextractable  : Protocol Model custody + extraction economics (§4.1)
- derailment     : the No-Off problem, quantified (§5.5)
- hierarchical   : pod-axis sync (TPU adaptation of the internet layer)
- protocol       : credential-gated Protocol Model server (§4.1)
"""
from repro.core import (  # noqa: F401
    aggregation,
    compression,
    derailment,
    gossip,
    hierarchical,
    ledger,
    protocol,
    scenarios,
    swarm,
    topology,
    unextractable,
    verification,
)
