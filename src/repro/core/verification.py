"""Compute verification (paper §4.2).

The paper rejects proof-of-learning for frontier workloads (numerical
nondeterminism [20, 73]) and lands on *game-theoretic* verification:
contributors stake capital; validators recompute a random subset of claimed
gradients and slash on mismatch beyond a tolerance; jackpots incentivize
validation [41, 66].

This module implements that mechanism over real gradients, with the
real-world numerical spread *simulated* as configurable noise (this
container's XLA/CPU is deterministic — DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class VerificationConfig:
    """Audit-game parameters.

    ``p_check`` / ``tolerance`` / ``numeric_noise`` may be **array-valued**
    (including jax tracers): the swarm campaign engine sweeps them as traced
    per-run lanes, so one compiled program serves every audit regime —
    ``p_check == 0`` disables auditing.  ``stake`` / ``jackpot`` /
    ``reward_per_step`` are host-side economics consumed by the ledger and
    stay Python floats.  Jackpots are funded from the slashed-stake pool,
    never minted (``Ledger.pay_jackpot`` caps the payout by the pool;
    ``economy.econ_round_update`` applies the same cap on device), so a
    validator can never be paid more than cheaters actually forfeited —
    keep ``jackpot <= stake`` unless under-funded jackpots are the point.
    """
    p_check: "float | Array" = 0.1   # probability a given update is audited
    stake: float = 10.0              # capital locked per contributor
    reward_per_step: float = 1.0     # shares minted per verified step
    tolerance: "float | Array" = 1e-3   # relative mismatch tolerated
    jackpot: float = 5.0             # validator reward for a catch
                                     # (pool-capped — see class docstring)
    numeric_noise: "float | Array" = 1e-5  # simulated cross-stack nondeterminism


def relative_mismatch(claimed, recomputed) -> Array:
    """‖claimed − recomputed‖ / ‖recomputed‖ over the full update pytree."""
    c = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(claimed)])
    r = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(recomputed)])
    return jnp.linalg.norm(c - r) / jnp.maximum(jnp.linalg.norm(r), 1e-30)


def _perturbed(recomputed, key: Array, cfg: VerificationConfig):
    """Add the simulated cross-stack numeric spread to a recomputed pytree.

    The key is ``fold_in``-ed per leaf — one shared key would draw the *same*
    noise pattern on every same-shaped leaf (correlated "nondeterminism",
    unlike the independent per-node keys ``audit_flat`` receives), which
    systematically under-disperses the mismatch statistic on multi-leaf
    trees.  Leaf i of a flattened (single-leaf) tree sees exactly the noise
    ``audit_flat`` would draw from ``fold_in(key, 0)``.
    """
    leaves, treedef = jax.tree.flatten(recomputed)
    noisy = [
        x + cfg.numeric_noise
        * jax.random.normal(jax.random.fold_in(key, i), x.shape, jnp.float32)
        * jnp.linalg.norm(x.astype(jnp.float32)) / np.sqrt(max(1, x.size))
        for i, x in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, noisy)


def audit(claimed, recompute_fn: Callable[[], object], cfg: VerificationConfig,
          key: Array) -> tuple[bool, Array]:
    """Recompute the work and compare.  Returns (passes, mismatch).

    ``recompute_fn`` re-runs the gradient; simulated nondeterminism is added
    (one independent draw per leaf — see :func:`_perturbed`) so honest work
    shows a small nonzero mismatch — the tolerance must absorb it (paper:
    proofs fail precisely because this spread exists).
    """
    noisy = _perturbed(recompute_fn(), key, cfg)
    mm = relative_mismatch(claimed, noisy)
    return bool(mm <= cfg.tolerance), mm


def audit_flat(claimed: Array, recomputed: Array, key: Array,
               cfg: VerificationConfig) -> tuple[Array, Array]:
    """§4.2 audit over flat fp32 update vectors — the ONE noise-and-compare
    formula both swarm engines use, so that with a shared key they reach the
    same pass/slash decision even at the tolerance boundary.  Returns
    ``(passes, mismatch)`` (0-d bool/float arrays; jit-safe)."""
    d = claimed.shape[-1]
    noisy = recomputed + (cfg.numeric_noise
                          * jax.random.normal(key, recomputed.shape, jnp.float32)
                          * jnp.linalg.norm(recomputed) / np.sqrt(max(1, d)))
    mm = jnp.linalg.norm(claimed - noisy) / jnp.maximum(
        jnp.linalg.norm(noisy), 1e-30)
    return mm <= cfg.tolerance, mm


def audit_batch(claimed: Array, recomputed: Array, keys: Array,
                cfg: VerificationConfig) -> tuple[Array, Array]:
    """Vectorized :func:`audit_flat` over fixed (N, D) stacks — per-node
    claimed vs validator-recomputed updates, one noise key per node.
    jit/vmap-safe — the batched engine evaluates every node each round and
    selects the audited subset with a boolean mask."""
    return jax.vmap(lambda c, r, k: audit_flat(c, r, k, cfg))(
        claimed, recomputed, keys)


# -- economics (paper §4.2 / §5.5) ---------------------------------------------
def expected_cheat_value(gain_per_step: float, cfg: VerificationConfig) -> float:
    """E[value of submitting fake work for one step]."""
    return gain_per_step - cfg.p_check * cfg.stake


def honest_value(cost_per_step: float, cfg: VerificationConfig) -> float:
    return cfg.reward_per_step - cost_per_step


def cheating_irrational(gain_per_step: float, cfg: VerificationConfig) -> bool:
    """The protocol is incentive-secure when cheating has non-positive EV.

    The boundary (EV exactly 0) counts as irrational: faking work has
    strictly positive effort cost the EV formula doesn't price, so zero
    expected gain already loses to honesty.  This is also what makes
    :func:`min_p_check`'s "smallest sufficient audit rate" actually
    sufficient at the boundary instead of one ulp short."""
    return expected_cheat_value(gain_per_step, cfg) <= 0


def min_p_check(gain_per_step: float, stake: float) -> float:
    """Smallest audit rate making cheating irrational for a given stake.

    Guaranteed sufficient *in floating point*: the quotient
    ``gain / stake`` is nudged up by ulps until ``p * stake >= gain``
    (division and multiplication each round, so the raw quotient can land
    a hair below break-even), hence
    ``cheating_irrational(gain, VerificationConfig(p_check=p, stake=s))``
    holds for the returned ``p`` whenever any rate <= 1 suffices —
    property-tested over random (gain, stake) in tests/test_properties.py.
    Non-positive gain needs no auditing at all (rate 0)."""
    if gain_per_step <= 0.0:
        return 0.0
    p = gain_per_step / max(stake, 1e-12)
    while 0.0 < p < 1.0 and p * stake < gain_per_step:
        p = math.nextafter(p, 1.0)
    return min(1.0, p)


def validator_ev(cost_of_audit: float, p_cheater: float, cfg: VerificationConfig) -> float:
    """Validators audit iff jackpot × catch-rate exceeds audit cost."""
    return p_cheater * cfg.jackpot - cost_of_audit
