"""Gradient compression (paper §3.1): the communication-efficiency substrate.

- QSGD stochastic quantization (fixed compression, Alistarh et al. [2]);
  Pallas kernel twin in ``repro.kernels.qsgd``.
- Top-k sparsification with error feedback (the standard adaptive scheme
  the paper cites as [19]-style).
- PowerSGD-style low-rank compression (rank-r outer product) — included as
  the beyond-survey option for 2-D tensors.

All compressors return a ``Compressed`` payload plus the bits-on-wire count
so benchmarks can report exact compression ratios, and a ``decompress``
path used by tests to bound reconstruction error.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class Compressed:
    kind: str
    payload: Dict[str, Any]
    bits: int          # exact bits on the wire
    orig_shape: tuple
    orig_bits: int


def _nbits(x) -> int:
    return int(x.size * jnp.dtype(x.dtype).itemsize * 8)


# -- QSGD ---------------------------------------------------------------------
def qsgd_compress(key, x: Array, *, levels: int = 16,
                  bucket_size: int = 1024) -> Compressed:
    """Stochastic uniform quantization to ``levels`` levels per |x|/norm.

    Bucketed as in Alistarh et al. [2]: one fp32 L2 norm per
    ``bucket_size`` elements + a sign+magnitude code per element.  Without
    bucketing the relative error grows as √d/levels — unusable at
    million-dim gradients (observed: a 5M-dim LM gradient quantized
    against a single global norm carries 35× the signal in noise).
    Unbiased: E[decompress(compress(x))] = x.
    """
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % bucket_size
    padded = jnp.pad(flat, (0, pad)).reshape(-1, bucket_size)
    norms = jnp.linalg.norm(padded, axis=1, keepdims=True)   # (nb, 1)
    scaled = jnp.abs(padded) / jnp.maximum(norms, 1e-30) * levels
    lower = jnp.floor(scaled)
    p = scaled - lower
    rnd = jax.random.uniform(key, padded.shape)
    q = (lower + (rnd < p)).astype(jnp.int32)            # in [0, levels]
    sign = jnp.signbit(padded)
    # levels is static, so the wire width is plain Python math — keeps the
    # codec traceable under jit/vmap (the batched swarm engine vmaps it).
    bits_per_el = math.ceil(math.log2(levels + 1)) + 1
    return Compressed(
        kind="qsgd",
        payload={"q": q, "sign": sign, "norms": norms, "levels": levels,
                 "size": flat.size},
        bits=32 * norms.size + flat.size * bits_per_el,
        orig_shape=shape,
        orig_bits=_nbits(x),
    )


def qsgd_decompress(c: Compressed) -> Array:
    p = c.payload
    mag = p["q"].astype(jnp.float32) / p["levels"] * p["norms"]
    out = jnp.where(p["sign"], -mag, mag).reshape(-1)[:p["size"]]
    return out.reshape(c.orig_shape)


# -- top-k with error feedback --------------------------------------------------
def topk_compress(x: Array, *, k_frac: float = 0.01) -> Compressed:
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return Compressed(
        kind="topk",
        payload={"vals": vals, "idx": idx, "size": flat.size},
        bits=k * (32 + 32),
        orig_shape=x.shape,
        orig_bits=_nbits(x),
    )


def topk_decompress(c: Compressed) -> Array:
    p = c.payload
    out = jnp.zeros((p["size"],), jnp.float32).at[p["idx"]].set(p["vals"])
    return out.reshape(c.orig_shape)


def topk_with_error_feedback(x: Array, error: Array, *, k_frac: float = 0.01):
    """Returns (compressed, new_error).  error accumulates what wasn't sent."""
    corrected = x + error
    c = topk_compress(corrected, k_frac=k_frac)
    new_error = corrected - topk_decompress(c)
    return c, new_error


# -- PowerSGD (rank-r) -----------------------------------------------------------
def powersgd_compress(key, x: Array, *, rank: int = 4, iters: int = 1) -> Compressed:
    """Low-rank (subspace-iteration) approximation of a 2-D tensor."""
    assert x.ndim == 2, "powersgd applies to matrices"
    if iters < 1:
        # the left factor p only exists after the first projection — iters=0
        # used to escape the loop with p unbound (UnboundLocalError)
        raise ValueError(f"powersgd needs iters >= 1, got {iters}")
    m, n = x.shape
    xf = x.astype(jnp.float32)
    q = jax.random.normal(key, (n, rank), jnp.float32)
    for _ in range(iters):
        p = xf @ q                                       # (m, r)
        p, _ = jnp.linalg.qr(p)
        q = xf.T @ p                                     # (n, r)
    return Compressed(
        kind="powersgd",
        payload={"p": p, "q": q},
        bits=(m + n) * rank * 32,
        orig_shape=x.shape,
        orig_bits=_nbits(x),
    )


def powersgd_decompress(c: Compressed) -> Array:
    return (c.payload["p"] @ c.payload["q"].T).reshape(c.orig_shape)


WIRE_CODECS = (None, "qsgd", "topk", "powersgd")


def roundtrip(kind: Optional[str], key, x: Array, **kwargs) -> Array:
    """Lossy wire round-trip: what the receiver reconstructs from ``x``.

    ``kind=None`` is the uncompressed wire (identity).  Pure function of
    ``(kind, key, x)`` — jit- and vmap-safe, so the batched swarm engine
    round-trips all N node gradients in one ``jax.vmap`` call over per-node
    keys.  The key seeds QSGD's stochastic rounding and PowerSGD's subspace
    init; top-k ignores it.

    PowerSGD natively compresses matrices; non-2-D payloads (the swarm's
    flat gradients) are zero-padded onto the squarest 2-D grid, compressed,
    and sliced back — sizes are static, so this stays jit/vmap-safe.
    """
    if kind is None:
        return x
    if kind == "qsgd":
        return qsgd_decompress(qsgd_compress(key, x, **kwargs))
    if kind == "topk":
        return topk_decompress(topk_compress(x, **kwargs))
    if kind == "powersgd":
        if x.ndim == 2:
            return powersgd_decompress(powersgd_compress(key, x, **kwargs))
        flat = x.reshape(-1)
        d = flat.size
        cols = int(math.ceil(math.sqrt(d)))
        rows = int(math.ceil(d / cols))
        grid = jnp.pad(flat, (0, rows * cols - d)).reshape(rows, cols)
        out = powersgd_decompress(powersgd_compress(key, grid, **kwargs))
        return out.reshape(-1)[:d].reshape(x.shape)
    raise ValueError(f"unknown wire codec: {kind!r} "
                     f"(roundtrip carries: {WIRE_CODECS})")


DECOMPRESSORS = {
    "qsgd": qsgd_decompress,
    "topk": topk_decompress,
    "powersgd": powersgd_decompress,
}


def decompress(c: Compressed) -> Array:
    return DECOMPRESSORS[c.kind](c)


def compression_ratio(c: Compressed) -> float:
    return c.orig_bits / c.bits
