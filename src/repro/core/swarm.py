"""Swarm simulator: the paper's five §3 properties in one runnable system.

Simulates N protocol participants training one model:
  1. communication efficiency — optional on-the-wire compression (lossy,
     round-tripped through core.compression);
  2. model sharding — the model itself runs sharded under pjit in
     launch/train.py; the swarm layer treats a node as a *logical* gradient
     contributor (a node may be a whole cluster — paper §2 last paragraph);
  3. elastic membership — nodes join/leave on a schedule, aggregation only
     sees currently-active nodes;
  4. byzantine tolerance — per-node corruption behaviours + robust
     aggregation from core.aggregation;
  5. heterogeneous capacity — per-node speed scales both contributed batch
     count and minted shares.

Plus the §4 mechanisms: stake/slash verification audits and the ownership
ledger.  Runs on CPU with a real (small) model; the aggregation math is
identical at any scale.

The round itself is a **pure functional core**: :class:`SwarmState` (params,
optimizer state, slashed mask, per-node contribution counters) advanced by
the ``round_fn`` built with :func:`make_round_fn`, parameterized by a
:class:`LaneParams` pytree of per-run traced values (behaviour codes,
byzantine scales, membership windows, PRNG base key, audit rate/tolerance,
and any traced aggregator kwargs).  The core has **no host round-trips** —
slashing and contribution minting happen on device, and the host-side
:class:`~repro.core.ledger.Ledger` is reconstructed from the device counters
after a run.  That makes two compositions possible:

- :func:`scan_rounds` — ``lax.scan`` the round over the round axis, so a
  whole training run is one device program;
- :func:`run_campaign` — additionally ``vmap`` over a leading *campaign*
  axis of stacked :class:`LaneParams`, so a full parameter sweep (attacker
  fractions × scales × seeds, per aggregator regime) is **one** compiled
  program (see ``core.derailment.sweep``).

Two engines share one API (``step``/``run``/``history``/``ledger``):

- :class:`Swarm` — the default **batched engine**, now a thin wrapper over
  the functional core: ``step`` invokes one jitted core round; ``run``
  dispatches the scanned core when the data function is traceable.
- :class:`SequentialSwarm` — the original per-node Python loop, kept as the
  readable reference oracle the batched engine is equivalence-tested against.

Both engines draw every random number from the same per-(purpose, round,
node) ``fold_in`` schedule, so with the same seed they produce the *same*
corruption noise, wire-codec realizations, audit selections, and therefore
the same ``agg_norm`` history (within fp32 reduction-order tolerance).

**Decentralized mode** (paper §3.2 meets §5.5): when a round is built with
``decentralized=True`` (``SwarmConfig.topology`` on the engine,
``LaneParams.mixing`` on the functional core), there is *no central
aggregator*.  ``SwarmState.params`` carries a leading node axis — one model
replica per node — and each round every node (1) computes its gradient at
its **own** replica, (2) robust-aggregates the submitted gradients of its
*neighborhood* (the rows of the mixing matrix, via the same masked
aggregators with a per-node neighbor ∧ keep mask), (3) applies the result
to its replica with its own optimizer state, and (4) gossip-mixes replicas
``params ← W @ params``.  ``RoundRecord.consensus_err`` tracks the maximum
replica deviation from the swarm mean after mixing.  A fully-connected
mixing matrix makes every neighborhood global and every replica identical,
which reproduces the centralized engine exactly (property-tested in
``tests/test_topology.py``).  ``mixing`` may also be a (T, N, N) stack —
time-varying or churn-coupled graphs from ``core.topology`` — indexed by
``round % T`` inside the scanned round.

**Custody lane** (paper §4.1 meets §5.5): ``SwarmConfig.custody`` (a
``core.unextractable.CustodyConfig``; ``LaneParams.custody`` /
``LaneParams.coalition`` on the functional core) rides the Protocol-Model
custody matrix through the compiled round as a pure *observability* layer —
it never perturbs the training math, which is what makes a fully-redundant
custody lane reproduce the plain engine bit-exactly (property-tested in
``tests/test_custody.py``).  Each round records
``RoundRecord.coverage`` — the fraction of shards held by at least one
*active* node, i.e. the live extraction frontier: custody-coupled churn
zeroes a shard's availability once every holder has left or been slashed
(the custody analogue of ``churn_coupled_mixing``).  At eval time a
campaign with a custody lane additionally runs the **reconstruct-attack
eval** inside the program: the coalition's shards are reassembled
(``masked_reconstruct``) and evaluated next to the honest params, so the
final losses come back as an (honest, extracted) pair per lane
(``core.derailment.sweep`` turns this into the extractability phase
table).
"""
from __future__ import annotations

import functools
import inspect
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, compression, economy
from repro.core.economy import EconomyConfig, EconParams
from repro.core.ledger import Ledger
from repro.core.placement import MeshPlan
from repro.core.unextractable import (
    CustodyConfig,
    assign_matrix,
    coalition_tail_mask,
    masked_reconstruct,
    shards_covered,
)
from repro.core.verification import VerificationConfig, audit_batch, audit_flat
from repro.kernels.masked_agg import ops as masked_agg_ops
from repro.kernels.qsgd_decode import ops as qsgd_decode_ops

Array = jax.Array

#: Byzantine behaviours, indexed by the code used in the vectorized
#: corruption table (``_corrupt_all``).  Code 0 is honest (identity).
BEHAVIOURS = ("honest", "sign_flip", "scale", "noise", "zero", "inner_product")
BEHAVIOUR_CODES: Dict[str, int] = {name: i for i, name in enumerate(BEHAVIOURS)}

# Key-schedule purposes.  Every random draw in a round is keyed by
# (seed, purpose, round, node_index) via fold_in — engine-independent, which
# is what makes the sequential reference and the batched engine bit-identical
# in their randomness (and keeps the batched round free of host-side key
# chains that would serialize it).
_CORRUPT, _WIRE, _AUDIT_SEL, _AUDIT_NOISE, _DELAY = range(5)

_FAR = np.iinfo(np.int32).max


def _node_key(base: Array, purpose: int, rnd, node_idx) -> Array:
    k = jax.random.fold_in(base, purpose)
    k = jax.random.fold_in(k, rnd)
    return jax.random.fold_in(k, node_idx)


@dataclass(frozen=True)
class NodeSpec:
    node_id: str
    speed: float = 1.0
    byzantine: Optional[str] = None      # None|sign_flip|scale|noise|zero|inner_product
    byzantine_scale: float = 10.0
    join_round: int = 0
    leave_round: Optional[int] = None
    #: max gradient staleness (rounds) this node may run behind — only read
    #: when the config sets ``staleness_bound > 0``, and clamped to it; the
    #: *realized* per-round delay is drawn uniformly in [0, min(delay,
    #: bound, round)] from the (seed, _DELAY, round, node) key schedule.
    #: ``None`` (the default) derives the delay from ``speed`` — slow nodes
    #: are stale nodes (see :meth:`effective_delay`); an explicit value
    #: always overrides the derivation.
    delay: Optional[int] = None

    @property
    def effective_delay(self) -> int:
        """The staleness cap async rounds read.  Explicit ``delay`` wins;
        otherwise it is derived from ``speed``: a node running at 1/s of
        the reference speed needs ~s rounds per unit of work, so it may
        lag ``ceil(1/speed) - 1`` rounds (speed ≥ 1 → 0, 0.5 → 1,
        0.25 → 3) — the async twin of the ledger's speed-weighted
        minting."""
        if self.delay is not None:
            return self.delay
        return max(int(np.ceil(1.0 / max(self.speed, 1e-9))) - 1, 0)

    def active(self, rnd: int) -> bool:
        return self.join_round <= rnd and (self.leave_round is None or rnd < self.leave_round)

    @property
    def behaviour_code(self) -> int:
        kind = self.byzantine or "honest"
        if kind not in BEHAVIOUR_CODES:
            raise ValueError(f"unknown byzantine behaviour: {kind!r} "
                             f"(known: {BEHAVIOURS})")
        return BEHAVIOUR_CODES[kind]


@dataclass(frozen=True)
class SwarmConfig:
    aggregator: str = "centered_clip"
    agg_kwargs: Dict = field(default_factory=dict)
    verification: Optional[VerificationConfig] = None
    compression: Optional[str] = None    # None|"qsgd"|"topk"|"powersgd"
    compression_kwargs: Dict = field(default_factory=dict)
    seed: int = 0
    #: named communication topology (core.topology registry) — setting one
    #: switches the batched engine to the decentralized round: per-node
    #: replicas, neighborhood aggregation, gossip mixing.  None = centralized.
    topology: Optional[str] = None
    topology_kwargs: Dict = field(default_factory=dict)
    #: seed for the graph *draw* (random_regular et al.) — deliberately
    #: separate from ``seed`` so sweeping run seeds varies noise, never the
    #: graph (the same convention ``derailment.sweep`` uses for its lanes)
    topology_seed: int = 0
    #: couple the mixing matrix to the roster's join/leave schedule
    #: (topology.churn_coupled_mixing): departed or not-yet-joined nodes
    #: become isolated self-loops, so their replicas freeze instead of
    #: relaying.  False (default) keeps the graph static — every replica
    #: mixes forever, the fixed-shape contract that makes a fully-connected
    #: decentralized swarm reproduce the centralized engine even under churn.
    churn_coupled: bool = False
    #: Protocol-Model custody lane (core.unextractable.CustodyConfig):
    #: assigns the (N, S) custody matrix over this roster, traces it through
    #: the round (RoundRecord.coverage = live extraction frontier), and
    #: marks the extraction coalition for the reconstruct-attack eval.
    #: None = no custody tracking.  Never changes the training math.
    custody: Optional[CustodyConfig] = None
    #: fused hot path (kernels.masked_agg + kernels.qsgd_decode): None =
    #: auto by stack size (see make_round_fn), True = force, False = never.
    fused: Optional[bool] = None
    #: bounded-staleness async rounds (paper §3 heterogeneity): K > 0 keeps
    #: a fixed-shape ring of the last K+1 parameter snapshots in the scanned
    #: carry and lets each node gradient against a deterministically-drawn
    #: delayed snapshot (see NodeSpec.delay).  0 (default) is the
    #: bulk-synchronous round — the async machinery is not even traced, so
    #: staleness_bound=0 is bit-exact with the pre-async engine.
    staleness_bound: int = 0
    #: economy lane (core.economy.EconomyConfig): threads a device-resident
    #: economic state (stakes, balances, reward escrow, slash pool) through
    #: the scanned round — stake-gated admission, fee/reward flows, and
    #: (``adaptive=True``) the coalition's best-response inner step.  The
    #: coalition defaults to the roster's byzantine slots.  None = no
    #: economy (the round is bit-exact with the pre-economy engine).
    economy: Optional[EconomyConfig] = None


def corrupt(kind: str, grad_flat: Array, honest_mean: Array, scale: float, key) -> Array:
    """Scalar (single-node) corruption table — the reference the vectorized
    ``_corrupt_all`` table below must match branch for branch."""
    if kind == "sign_flip":
        return -scale * grad_flat
    if kind == "scale":
        return scale * grad_flat
    if kind == "noise":
        return grad_flat + scale * jax.random.normal(key, grad_flat.shape)
    if kind == "zero":
        return jnp.zeros_like(grad_flat)
    if kind == "inner_product":
        # [87]-style: oppose the honest consensus direction
        return -scale * honest_mean
    raise ValueError(kind)


def _corrupt_all(codes: Array, gf: Array, honest_mean: Array, scales: Array,
                 keys: Array) -> Array:
    """Vectorized corruption table: every behaviour evaluated on the whole
    (N, D) stack, selected per node by code — branch for branch equal to
    :func:`corrupt`.  Written as arithmetic selects rather than a vmapped
    ``lax.switch``: with per-node codes vmap evaluates every branch anyway,
    and the flat form is measurably cheaper to trace and compile inside the
    scanned campaign round (sweeps are compile-bound).

    The (N, D) normal draw is the one expensive branch input (threefry over
    the full stack, ~1s/round at N=16, D=1M on CPU), so it runs under a
    ``lax.cond`` on "any noise node in the roster": rosters without noise
    attackers skip it entirely.  Bit-exact either way — when the cond takes
    the zeros branch no select ever reads the noise values (and under vmap,
    where cond lowers to both-branches select, this is exactly the old
    unconditional draw)."""
    any_noise = jnp.any(codes == BEHAVIOUR_CODES["noise"])
    noise = jax.lax.cond(
        any_noise,
        lambda: jax.vmap(lambda k, g: jax.random.normal(k, g.shape))(keys, gf),
        lambda: jnp.zeros_like(gf))
    c, s = codes[:, None], scales[:, None]
    out = jnp.where(c == BEHAVIOUR_CODES["sign_flip"], -s * gf, gf)
    out = jnp.where(c == BEHAVIOUR_CODES["scale"], s * gf, out)
    out = jnp.where(c == BEHAVIOUR_CODES["noise"], gf + s * noise, out)
    out = jnp.where(c == BEHAVIOUR_CODES["zero"], 0.0, out)
    out = jnp.where(c == BEHAVIOUR_CODES["inner_product"],
                    -s * honest_mean[None], out)
    return out


# ============================ functional core ==================================
class LaneParams(NamedTuple):
    """Per-run traced parameters of the functional round.

    Every field is a jax array, so a *campaign* is simply a LaneParams whose
    leaves carry a leading run axis (see :func:`stack_lanes`) vmapped by
    :func:`run_campaign`.  Roster fields have shape (N,); audit fields are
    scalars (``p_check == 0`` disables auditing even when the round was built
    with ``verify=True``); ``agg_id`` selects this run's aggregator when the
    round was built with several (0 otherwise); ``agg_kwargs`` holds *traced*
    aggregator keyword arguments (e.g. a per-run krum ``f`` or centered-clip
    ``clip_tau``) — static kwargs go to :func:`make_round_fn` instead.

    ``mixing`` is the decentralized round's doubly-stochastic mixing matrix
    — (N, N), or (T, N, N) for time-varying / churn-coupled graphs (indexed
    by ``round % T``).  It is traced like every other field, so one compiled
    campaign sweeps *topologies* as a lane axis.  ``None`` (the default)
    means the round is centralized; all lanes of a campaign must agree.

    ``custody``/``coalition`` are the Protocol-Model custody lane — the
    (N, S) custody matrix and the (N,) extraction-coalition mask
    (``core.unextractable``).  Traced like ``mixing``, so one compiled
    campaign sweeps *redundancy and coalition fraction* as lane axes: the
    round records the live coverage frontier each round, and the campaign
    eval reassembles the coalition's shards next to the honest eval.
    ``None`` (the default) disables custody; all lanes must agree.

    ``delays`` is the bounded-staleness lane — (N,) int32 per-node *maximum*
    delays, only read by rounds built with ``staleness_bound > 0`` (the ring
    size is static; the delay values are traced, so one compiled campaign
    sweeps *staleness* as a lane axis).  ``None`` (the default) means the
    synchronous round; all lanes of a campaign must agree.

    ``econ`` is the economy lane — a :class:`~repro.core.economy.EconParams`
    of traced knobs (identity cost, budget, bond, fee/reward/jackpot
    schedule, adaptive flag, coalition mask).  Traced like every other
    field, so one compiled campaign sweeps the *incentive* axes; the round
    gains stake-gated admission, the per-round economy update, and (in
    adaptive lanes) the coalition's best-response inner step.  ``None``
    (the default) disables the economy; all lanes of a campaign must agree.
    """
    codes: Array          # (N,) int32 behaviour codes (BEHAVIOUR_CODES)
    scales: Array         # (N,) f32 byzantine scales
    speeds: Array         # (N,) f32 capacity -> minted shares per kept round
    joins: Array          # (N,) int32 join round (inclusive)
    leaves: Array         # (N,) int32 leave round (exclusive; _FAR = never)
    base_key: Array       # PRNG key — the per-run seed
    p_check: Array        # () f32 audit probability (0 = never audited)
    tolerance: Array      # () f32 audit relative-mismatch tolerance
    numeric_noise: Array  # () f32 simulated cross-stack nondeterminism
    agg_id: Array         # () int32 index into the round's aggregator set
    agg_kwargs: Dict[str, Array]  # traced per-run aggregator kwargs
    mixing: Optional[Array] = None  # (N, N) | (T, N, N) mixing matrix | None
    custody: Optional[Array] = None    # (N, S) bool custody matrix | None
    coalition: Optional[Array] = None  # (N,) bool extraction coalition | None
    delays: Optional[Array] = None     # (N,) int32 max staleness | None
    econ: Optional[EconParams] = None  # traced economy knobs | None


class SwarmState(NamedTuple):
    """The carry of the scanned round: everything that evolves across rounds
    lives on device, so a run never round-trips to the host."""
    params: Any           # model parameters (pytree; leading node axis when
                          # the round is decentralized — per-node replicas)
    opt_state: Any        # optimizer state (pytree; ditto)
    slashed: Array        # (N,) bool — caught by an audit in a prior round
    contrib: Array        # (N,) f32 — speed-weighted kept rounds (mint counter)
    ring: Any = None      # staleness ring: params-shaped pytree with a
                          # leading (K+1,) snapshot axis — slot r % (K+1)
                          # holds the params as of the start of round r.
                          # None in synchronous rounds (staleness_bound=0).
    econ: Any = None      # economy state (economy.EconState): stakes,
                          # balances, reward escrow, slash pool — advanced
                          # by econ_round_update each round.  None when the
                          # round has no economy lane.


class RoundRecord(NamedTuple):
    """Per-round outputs stacked by ``lax.scan`` (leading round axis)."""
    n_active: Array       # () int32
    n_byzantine: Array    # () int32
    caught: Array         # (N,) bool — slashed in *this* round
    keep: Array           # (N,) bool — active & not caught (minted this round)
    agg_norm: Array       # () f32 (decentralized: mean per-node agg norm)
    consensus_err: Array  # () f32 max *active*-replica deviation from the
                          # active-replica mean after gossip mixing
                          # (0 in centralized rounds)
    coverage: Array       # () f32 fraction of custody shards held by >= 1
                          # active node — the live extraction frontier
                          # (1.0 when the round has no custody lane)
    staleness: Array      # () f32 mean realized gradient delay (rounds) over
                          # active nodes (0 in synchronous rounds)
    coalition_stake: Optional[Array] = None  # () f32 coalition share of the
                          # kept nodes' post-round stake (economy lanes
                          # only; None otherwise — the capture trajectory)


def lane_for_nodes(nodes: Sequence[NodeSpec], cfg: SwarmConfig, *,
                   agg_kwargs: Optional[Dict] = None) -> LaneParams:
    """Build the single-run :class:`LaneParams` for a node roster + config.
    ``cfg.topology`` (if set) resolves to the named Metropolis mixing matrix
    at this roster size, drawn with ``cfg.topology_seed`` (NOT the run
    seed — reruns across seeds keep the same graph).  ``cfg.churn_coupled``
    expands it to the (T, N, N) schedule-coupled stack, T spanning the last
    membership event (the round consuming it must index with
    ``mixing_schedule="clamp"`` — the engine wires this automatically).
    ``cfg.custody`` draws the (N, S) custody matrix with ``custody.seed``
    (same convention: run seeds never reshuffle who holds what) and marks
    the coalition as the last ``ceil(coalition_fraction * N)`` roster
    slots.  ``cfg.staleness_bound > 0`` fills the ``delays`` lane with each
    node's ``NodeSpec.effective_delay`` (explicit ``delay``, else derived
    from ``speed``) clamped to the bound (0 leaves it ``None`` — the
    synchronous round).  ``cfg.economy`` fills the ``econ`` lane, with the
    roster's byzantine slots as the strategic coalition."""
    from repro.core import topology as topo  # local: keep import cycle-free
    v = cfg.verification
    custody = coalition = None
    if cfg.custody is not None:
        cc = cfg.custody
        custody = jnp.asarray(assign_matrix(
            len(nodes), cc.num_shards, cc.redundancy, cc.seed,
            cc.max_fraction))
        coalition = jnp.asarray(
            coalition_tail_mask(len(nodes), cc.coalition_fraction))
    mixing = None
    if cfg.topology is not None:
        w = topo.mixing_matrix(cfg.topology, len(nodes),
                               seed=cfg.topology_seed, **cfg.topology_kwargs)
        if cfg.churn_coupled:
            joins = np.asarray([n.join_round for n in nodes])
            leaves = np.asarray([_FAR if n.leave_round is None
                                 else n.leave_round for n in nodes])
            events = [int(t) for t in (*joins, *leaves) if 0 < t < _FAR]
            w = topo.churn_coupled_mixing(
                w, joins, leaves, rounds=(max(events) + 1) if events else 1)
        mixing = jnp.asarray(w, jnp.float32)
    delays = None
    if cfg.staleness_bound > 0:
        delays = jnp.asarray([min(n.effective_delay, cfg.staleness_bound)
                              for n in nodes], jnp.int32)
    econ = None
    if cfg.economy is not None:
        econ = cfg.economy.params_for(
            np.asarray([n.byzantine is not None for n in nodes]))
    return LaneParams(
        mixing=mixing,
        custody=custody,
        coalition=coalition,
        delays=delays,
        econ=econ,
        codes=jnp.asarray([n.behaviour_code for n in nodes], jnp.int32),
        scales=jnp.asarray([n.byzantine_scale for n in nodes], jnp.float32),
        speeds=jnp.asarray([n.speed for n in nodes], jnp.float32),
        joins=jnp.asarray([n.join_round for n in nodes], jnp.int32),
        leaves=jnp.asarray([_FAR if n.leave_round is None else n.leave_round
                            for n in nodes], jnp.int32),
        base_key=jax.random.PRNGKey(cfg.seed),
        p_check=jnp.asarray(v.p_check if v else 0.0, jnp.float32),
        tolerance=jnp.asarray(v.tolerance if v else 1.0, jnp.float32),
        numeric_noise=jnp.asarray(v.numeric_noise if v else 0.0, jnp.float32),
        agg_id=jnp.asarray(0, jnp.int32),
        agg_kwargs={k: jnp.asarray(x) for k, x in (agg_kwargs or {}).items()},
    )


def stack_lanes(lanes: Sequence[LaneParams]) -> LaneParams:
    """Stack single-run lanes into a campaign (leading run axis on every
    leaf).  All lanes must share N, the same ``agg_kwargs`` keys, and agree
    on ``mixing`` (all None = centralized, or all same-shaped matrices =
    decentralized) and on ``custody``/``coalition`` (all None = no custody
    lane, or all same-shaped matrices/masks)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)


def init_ring(params, staleness_bound: int):
    """The bounded-staleness snapshot ring: ``params`` repeated along a new
    leading (K+1,) axis (every slot starts at the initial params, which is
    exactly the round-0 snapshot any early-round delay resolves to).
    ``jnp.repeat`` (not ``broadcast_to``) so each slot owns real memory —
    the ring is donated through the scanned run and updated in place."""
    if staleness_bound <= 0:
        return None
    return jax.tree.map(
        lambda l: jnp.repeat(l[None], staleness_bound + 1, axis=0), params)


def init_state(params, optimizer, n_nodes: int, *,
               staleness_bound: int = 0, econ=None) -> SwarmState:
    return SwarmState(params=params, opt_state=optimizer.init(params),
                      slashed=jnp.zeros(n_nodes, bool),
                      contrib=jnp.zeros(n_nodes, jnp.float32),
                      ring=init_ring(params, staleness_bound),
                      econ=econ)


def init_decentralized_state(params, optimizer, n_nodes: int, *,
                             staleness_bound: int = 0) -> SwarmState:
    """Per-node replica state: every node starts from the same ``params``
    with its own (vmapped) optimizer state."""
    replicas = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_nodes,) + l.shape), params)
    return SwarmState(params=replicas,
                      opt_state=jax.vmap(optimizer.init)(replicas),
                      slashed=jnp.zeros(n_nodes, bool),
                      contrib=jnp.zeros(n_nodes, jnp.float32),
                      ring=init_ring(replicas, staleness_bound))


def consensus_params(params):
    """Collapse per-node replicas to the swarm-mean (consensus) params."""
    return jax.tree.map(lambda l: jnp.mean(l.astype(jnp.float32),
                                           axis=0).astype(l.dtype), params)


def _accepted_kwargs(name: str) -> frozenset:
    """Keyword names a masked aggregator understands (for routing the shared
    traced ``lane.agg_kwargs`` dict in multi-aggregator rounds)."""
    sig = inspect.signature(aggregation.MASKED_AGGREGATORS[name])
    return frozenset(p.name for p in sig.parameters.values()
                     if p.kind is inspect.Parameter.KEYWORD_ONLY)


def make_round_fn(loss_fn: Callable, optimizer, params_template, n_nodes: int, *,
                  aggregator, agg_kwargs: Optional[Dict] = None,
                  compression_kind: Optional[str] = None,
                  compression_kwargs: Optional[Dict] = None,
                  verify: bool = False, decentralized: bool = False,
                  mixing_schedule: str = "cycle",
                  fused: Optional[bool] = None,
                  staleness_bound: int = 0) -> Callable:
    """Build the pure round: ``round_fn(lane, state, rnd, batches) ->
    (state, RoundRecord)``.

    Static structure (aggregator choice, static agg kwargs, wire codec,
    whether the audit branch exists at all) is baked here; everything
    per-run lives in ``lane`` as traced arrays, so one trace serves every
    lane of a campaign.  ``batches`` is a pytree with leading node axis N.

    ``decentralized=True`` (static — it changes the state shapes) builds
    the no-central-aggregator round: ``state.params``/``opt_state`` carry a
    leading node axis, every node gradients its own replica, aggregates its
    ``lane.mixing``-row neighborhood (neighbor ∧ keep mask through the same
    masked aggregators), applies its own optimizer update, and replicas
    gossip-mix ``W @ params``.  Activity gates *contribution* (keep) only:
    inactive/slashed replicas keep updating from their neighborhood and keep
    mixing — the decentralized twin of the centralized engine's "inactive
    nodes still occupy a lane" fixed-shape contract, and what makes a
    fully-connected graph reproduce the centralized round exactly even
    under churn.  Nodes whose rounds should truly freeze (leavers) get that
    via a churn-coupled (T, N, N) ``lane.mixing`` stack
    (``topology.churn_coupled_mixing``; ``SwarmConfig.churn_coupled`` on
    the engine).  ``mixing_schedule`` picks how a 3-D stack is indexed:
    ``"cycle"`` (``round % T`` — periodic time-varying graphs) or
    ``"clamp"`` (``min(round, T-1)`` — a membership schedule whose graph is
    constant past its last event).

    ``aggregator`` is either one name (static ``agg_kwargs`` apply to it;
    traced ``lane.agg_kwargs`` pass through verbatim) or a sequence of
    ``(name, static_kwargs)`` pairs — then every aggregator is evaluated and
    ``lane.agg_id`` selects the result per run, which lets a whole
    multi-regime phase diagram share **one** compiled program (the gradient
    / corruption / audit machinery — the bulk of the compile cost — is
    compiled once).  In that mode each aggregator receives only the
    ``lane.agg_kwargs`` entries its signature accepts.

    ``fused`` selects the fused hot path (``kernels.masked_agg`` +
    ``kernels.qsgd_decode``): aggregators run their fused twins, and a
    qsgd wire keeps the compressed payload (int8 codes + bucket norms) live
    into aggregation instead of a decoded fp32 stack.  ``None`` (default)
    auto-enables it when the round is centralized, every aggregator has a
    fused twin, the wire is uncompressed or int8-codeable qsgd, and the
    (N, D) fp32 stack crosses ``masked_agg.ops.FUSED_MIN_BYTES``.
    ``True`` forces it (raising on unsupported combinations); ``False``
    forces the reference path.  Fused == unfused bit-for-bit except krum's
    distance arithmetic (selection-equal away from exact score ties) —
    pinned by tests/test_kernel_conformance.py.  The resolved choice is
    exposed as ``round_fn.fused``.

    ``staleness_bound`` (static — it sizes the snapshot ring) builds the
    **bounded-staleness async round**: ``state.ring`` carries the last K+1
    parameter snapshots (fixed shape — no recompiles), each round writes
    the current params into slot ``round % (K+1)``, draws a per-node
    realized delay ``~ U[0, min(lane.delays[i], round, K)]`` from the
    (seed, _DELAY, round, node) key schedule, and each node gradients
    against *its own delayed snapshot* (``vmap`` over the gathered stack).
    Everything downstream — corruption, wire, aggregation masks — consumes
    the mixed-staleness gradient stack unchanged, and the §4.2 audit stays
    sound *by construction*: the validator recomputes from the same ``gf``
    row the contributor produced, i.e. against the same stale snapshot —
    the delay is part of the claim because it is part of the shared key
    schedule.  ``staleness_bound=0`` (default) takes the literal
    synchronous code path (no ring, no extra keys): bit-exact with the
    pre-async engine by construction, pinned in tests/test_async.py.
    Note a zero-*delay* lane inside a ``staleness_bound>0`` program is only
    allclose to the synchronous program — gathering per-node snapshots
    batches the gradient matmuls differently (reduction order), exactly
    like the FC-decentralized vs centralized pinning.
    """
    leaves = jax.tree.leaves(params_template)
    treedef = jax.tree.structure(params_template)
    shapes = [(l.shape, l.dtype) for l in leaves]
    if isinstance(aggregator, str):
        agg_specs = [(aggregator, dict(agg_kwargs or {}))]
        route_kwargs = False
    else:
        if agg_kwargs:
            raise ValueError("pass per-aggregator static kwargs inside the "
                             "(name, kwargs) pairs, not via agg_kwargs")
        agg_specs = [(name, dict(kw)) for name, kw in aggregator]
        route_kwargs = True
    if mixing_schedule not in ("cycle", "clamp"):
        raise ValueError(f"unknown mixing_schedule: {mixing_schedule!r} "
                         "(known: 'cycle', 'clamp')")
    # in routed mode an aggregator's *static* kwargs win over same-named
    # traced lane kwargs (call-time kwargs would silently override the
    # functools.partial baked ones otherwise — e.g. a krum regime pinned to
    # f=4 must not pick up the per-lane f meant for the auto-f krum regime)
    ckw = dict(compression_kwargs or {})

    # -- fused hot-path resolution (static) ------------------------------------
    d_total = sum(int(np.prod(shape)) if shape else 1 for shape, _ in shapes)
    stack_bytes = n_nodes * d_total * 4
    fusable_aggs = all(name in masked_agg_ops.FUSED_MASKED_AGGREGATORS
                       for name, _ in agg_specs)
    fusable_wire = (compression_kind is None
                    or (compression_kind == "qsgd"
                        and ckw.get("levels", 16) <= 127))
    fused_ok = (not decentralized) and fusable_aggs and fusable_wire
    if fused is None:
        fused = fused_ok and stack_bytes >= masked_agg_ops.FUSED_MIN_BYTES
    elif fused and not fused_ok:
        raise ValueError(
            "fused=True unsupported here: needs a centralized round, "
            f"aggregators within {sorted(masked_agg_ops.FUSED_MASKED_AGGREGATORS)} "
            f"(got {[n for n, _ in agg_specs]}), and an uncompressed or "
            f"int8-codeable qsgd wire (got {compression_kind!r}, "
            f"levels={ckw.get('levels', 16)})")
    fused_qsgd = fused and compression_kind == "qsgd"

    # kwarg routing always reads the *reference* signatures — the fused
    # twins deliberately share names and keyword surface
    getter = (masked_agg_ops.get_fused_aggregator if fused
              else aggregation.get_masked_aggregator)
    agg_fns = [(getter(name, **kw),
                _accepted_kwargs(name) - set(kw)) for name, kw in agg_specs]
    # the adaptive coalition's model of the defense (economy lanes): always
    # the *reference* masked aggregators — the attacker scores candidate
    # attacks on the raw fp32 stack even when the round itself runs fused
    # on wire payloads
    ref_agg_fns = agg_fns if not fused else [
        (aggregation.get_masked_aggregator(name, **kw),
         _accepted_kwargs(name) - set(kw)) for name, kw in agg_specs]
    grad_fn = jax.grad(loss_fn)
    idx = jnp.arange(n_nodes)

    def flatten_stack(tree) -> Array:
        """pytree with leading node axis -> (N, D) fp32 matrix."""
        return jnp.concatenate([l.reshape(n_nodes, -1).astype(jnp.float32)
                                for l in jax.tree.leaves(tree)], axis=1)

    def unflatten(vec: Array):
        out, off = [], 0
        for shape, dtype in shapes:
            size = int(np.prod(shape)) if shape else 1
            out.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    def wire(key, g):
        return compression.roundtrip(compression_kind, key, g, **ckw)

    def wire_payload(key, g):
        """Fused qsgd wire: encode only — the int8 payload stays live into
        aggregation (decode happens inside the fused aggregator / audit)."""
        return qsgd_decode_ops.wire_encode(key, g, **ckw)

    def round_fn(lane: LaneParams, state: SwarmState, rnd, batches):
        if staleness_bound > 0 and lane.delays is None:
            raise ValueError("staleness_bound > 0 needs a LaneParams.delays "
                             "lane (build it via lane_for_nodes with "
                             "SwarmConfig.staleness_bound set)")
        active = (lane.joins <= rnd) & (rnd < lane.leaves) & (~state.slashed)
        econ = lane.econ
        if econ is not None:
            if decentralized:
                raise ValueError("economy lanes need a centralized round "
                                 "(stake-gated admission and the fee market "
                                 "assume one aggregate)")
            if state.econ is None:
                raise ValueError("economy lane without SwarmState.econ — "
                                 "init the state with "
                                 "economy.init_econ_state(lane.econ, n)")
            # stake-gated admission, derived in-program from live stakes:
            # de-admitted nodes vanish from gradients, audits, aggregation
            # masks, minting, and coverage alike
            active = active & economy.admitted_mask(econ, state.econ)
        nact = jnp.sum(active.astype(jnp.float32))

        # the whole (purpose, round, node) fold_in schedule in three batched
        # call sites — same keys as _node_key per (purpose, rnd, i), but the
        # compiler sees 3 threefry kernels instead of 12+ (sweeps are
        # compile-bound, and threefry dominates the round's compile cost).
        # Synchronous rounds don't trace the _DELAY purpose at all.
        pk = jax.vmap(lambda p: jax.random.fold_in(lane.base_key, p))(
            jnp.arange(5 if staleness_bound > 0 else 4))
        rk = jax.vmap(lambda k: jax.random.fold_in(k, rnd))(pk)
        allk = jax.vmap(lambda k: jax.vmap(
            lambda i: jax.random.fold_in(k, i))(idx))(rk)         # (P, N, 2)
        ck, wk, sk, nk = allk[_CORRUPT], allk[_WIRE], \
            allk[_AUDIT_SEL], allk[_AUDIT_NOISE]

        if staleness_bound > 0:
            # async round: snapshot first (slot r % (K+1) holds the params
            # as of the start of round r — a realized delay of 0 reads the
            # same params the synchronous round would), then per-node
            # realized delays, then gradients at the gathered snapshots.
            ring_len = jnp.int32(staleness_bound + 1)
            ring = jax.tree.map(
                lambda r, l: r.at[jnp.mod(rnd, ring_len)].set(l),
                state.ring, state.params)
            cap = jnp.minimum(jnp.minimum(lane.delays, rnd),
                              jnp.int32(staleness_bound))
            delay = jax.vmap(
                lambda k, m: jax.random.randint(k, (), 0, m + jnp.int32(1)))(
                allk[_DELAY], cap)
            slots = jnp.mod(rnd - delay, ring_len)                # (N,)
            if decentralized:
                # ring leaves are (K+1, N, ...): node i reads its OWN
                # replica as of round rnd - delay[i]
                delayed = jax.tree.map(lambda r: r[slots, idx], ring)
            else:
                delayed = jax.tree.map(lambda r: r[slots], ring)
            grads = jax.vmap(grad_fn, in_axes=(0, 0))(delayed, batches)
            staleness = (jnp.sum(delay.astype(jnp.float32)
                                 * active.astype(jnp.float32))
                         / jnp.maximum(nact, 1.0))
        else:
            # decentralized: every node gradients its OWN replica (leading
            # node axis on state.params); centralized: one shared params
            ring = state.ring
            grad_axes = (0, 0) if decentralized else (None, 0)
            grads = jax.vmap(grad_fn, in_axes=grad_axes)(state.params,
                                                         batches)
            staleness = jnp.zeros((), jnp.float32)
        gf = flatten_stack(grads)                                 # (N, D)
        maskf = active.astype(jnp.float32)[:, None]
        honest_mean = jnp.sum(gf * maskf, axis=0) / jnp.maximum(nact, 1.0)
        corrupted = _corrupt_all(lane.codes, gf, honest_mean, lane.scales, ck)

        def route_aggs(fns, stack, mask):
            if route_kwargs:
                outs = [fn(stack, mask,
                           **{k: v for k, v in sorted(lane.agg_kwargs.items())
                              if k in acc})
                        for fn, acc in fns]
                return jnp.stack(outs)[lane.agg_id] if len(outs) > 1 else outs[0]
            return fns[0][0](stack, mask, **lane.agg_kwargs)

        if econ is not None:
            # adaptive adversary (economy lanes): the coalition scores a
            # static menu of attack scales against the KNOWN aggregator —
            # the reference twin of the round's own defense, on the
            # anticipated active mask — and overrides its fixed behaviour
            # with the best response.  One traced computation, like the
            # audit recompute; fixed (adaptive=0) lanes select it away.
            coal_act = econ.coalition & active
            best = economy.best_response_scale(
                lambda s, m: route_aggs(ref_agg_fns, s, m),
                gf, honest_mean, coal_act, active)
            use_adaptive = (econ.adaptive > 0) & coal_act
            corrupted = jnp.where(use_adaptive[:, None],
                                  -best * honest_mean[None, :], corrupted)

        if fused_qsgd:
            submitted = jax.vmap(wire_payload)(wk, corrupted)
        else:
            submitted = jax.vmap(wire)(wk, corrupted)

        caught = jnp.zeros(n_nodes, bool)
        if verify:                           # static: baked at trace time
            # audit rate / tolerance / noise are *traced* (array-valued
            # VerificationConfig fields), so one program serves lanes with
            # different p_check — including p_check == 0 (never audited).
            vcfg = VerificationConfig(p_check=lane.p_check,
                                      tolerance=lane.tolerance,
                                      numeric_noise=lane.numeric_noise)
            sel = jax.vmap(jax.random.uniform)(sk)
            audited = active & (sel < lane.p_check)
            # the validator recomputes the honest gradient and re-encodes it
            # with the submitter's wire key (see SequentialSwarm.step).  In
            # async rounds gf is the *delayed* gradient stack, so the
            # recompute runs against the same stale snapshot the contributor
            # claims — the delay is reproducible from the shared key
            # schedule, which is what keeps the §4.2 audit sound under
            # asynchrony (honest-but-stale is never slashed as cheating).
            recomputed = jax.vmap(wire)(wk, gf)
            audited_view = (qsgd_decode_ops.wire_decode(submitted)
                            if fused_qsgd else submitted)
            passes, _ = audit_batch(audited_view, recomputed, nk, vcfg)
            caught = audited & (~passes)
        keep = active & (~caught)

        def run_aggs(mask):
            return route_aggs(agg_fns, submitted, mask)

        if decentralized:
            w = lane.mixing.astype(jnp.float32)
            if w.ndim == 3:              # time-varying / churn-coupled stack
                t_max = w.shape[0]
                w = w[jnp.minimum(rnd, t_max - 1)
                      if mixing_schedule == "clamp" else jnp.mod(rnd, t_max)]
            # node i robust-aggregates its neighborhood's kept submissions
            # (Metropolis W has self-loops, so i's own update is in its set)
            per_keep = (w > 0) & keep[None, :]            # (N, N)
            agg = jax.vmap(run_aggs)(per_keep)            # (N, D)
            node_any = jnp.any(per_keep, axis=1)
            agg = jnp.where(node_any[:, None], agg, jnp.zeros_like(agg))
            new_params, new_opt = jax.vmap(
                lambda ok, a, p, o: jax.lax.cond(
                    ok,
                    lambda p, o: optimizer.update(unflatten(a), o, p),
                    lambda p, o: (p, o),
                    p, o))(node_any, agg, state.params, state.opt_state)
            # gossip mix the replicas (momentum stays local — standard DSGD)
            mixed = w @ flatten_stack(new_params)         # (N, P)
            new_params = jax.vmap(unflatten)(mixed)
            # consensus over *active* replicas only: under churn-coupled
            # mixing a departed node's replica freezes (its row is e_i) and
            # would otherwise dominate the max forever
            mean_act = (jnp.sum(mixed * maskf, axis=0, keepdims=True)
                        / jnp.maximum(nact, 1.0))
            consensus_err = jnp.max(
                jnp.linalg.norm((mixed - mean_act) * maskf, axis=1))
            agg_norm = jnp.mean(jax.vmap(jnp.linalg.norm)(agg))
        else:
            agg = run_aggs(keep)
            any_keep = jnp.any(keep)
            agg = jnp.where(any_keep, agg, jnp.zeros_like(agg))
            new_params, new_opt = jax.lax.cond(
                any_keep,
                lambda p, o: optimizer.update(unflatten(agg), o, p),
                lambda p, o: (p, o),
                state.params, state.opt_state)
            consensus_err = jnp.zeros((), jnp.float32)
            agg_norm = jnp.linalg.norm(agg)

        # custody observability: the live extraction frontier — a shard is
        # available while >= 1 holder is active (custody-coupled churn:
        # departed/slashed holders zero their shards' availability)
        if lane.custody is not None:
            coverage = jnp.mean(jnp.any(lane.custody & active[:, None],
                                        axis=0).astype(jnp.float32))
        else:
            coverage = jnp.ones((), jnp.float32)

        new_econ, coalition_stake = state.econ, None
        if econ is not None:
            new_econ = economy.econ_round_update(
                econ, state.econ, active=active, keep=keep, caught=caught,
                speeds=lane.speeds)
            fkeep = keep.astype(jnp.float32)
            act_stake = jnp.sum(new_econ.stake * fkeep)
            coal_stake = jnp.sum(new_econ.stake * fkeep
                                 * econ.coalition.astype(jnp.float32))
            coalition_stake = jnp.where(
                act_stake > 0.0, coal_stake / jnp.maximum(act_stake, 1e-9),
                jnp.zeros((), jnp.float32))

        new_state = SwarmState(
            params=new_params, opt_state=new_opt,
            slashed=state.slashed | caught,
            contrib=state.contrib + lane.speeds * keep.astype(jnp.float32),
            ring=ring, econ=new_econ)
        rec = RoundRecord(
            n_active=jnp.sum(active).astype(jnp.int32),
            n_byzantine=jnp.sum(active & (lane.codes > 0)).astype(jnp.int32),
            caught=caught, keep=keep, agg_norm=agg_norm,
            consensus_err=consensus_err, coverage=coverage,
            staleness=staleness, coalition_stake=coalition_stake)
        return new_state, rec

    round_fn.fused = fused                    # resolved choice, inspectable
    round_fn.stack_bytes = stack_bytes
    round_fn.staleness_bound = staleness_bound
    return round_fn


def scan_rounds(round_fn: Callable, lane: LaneParams, state: SwarmState,
                rounds: int, batch_fn: Callable,
                eval_fn: Optional[Callable] = None):
    """``lax.scan`` the pure round over ``rounds`` — one device program per
    run.  ``batch_fn(rnd)`` must be traceable and return the leading-N batch
    stack; ``eval_fn(params)``, if given, is evaluated once on the final
    params inside the program.  Returns ``(state, RoundRecord-stacked,
    final_loss)``."""
    def body(st, rnd):
        return round_fn(lane, st, rnd, batch_fn(rnd))

    state, recs = jax.lax.scan(body, state, jnp.arange(rounds))
    final = eval_fn(state.params) if eval_fn is not None else jnp.zeros(())
    return state, recs, final


def make_scan_program(round_fn: Callable, batch_fn: Callable, rounds: int,
                      eval_fn: Optional[Callable] = None) -> Callable:
    """The batched engine's scanned-run program, with donation declared:
    ``run(lane, params, opt_state, slashed, contrib, ring=None, econ=None)
    -> (SwarmState, RoundRecord-stacked, final_loss)``.

    The engine-owned carry buffers — ``opt_state``, ``slashed``,
    ``contrib``, (async rounds) the staleness ``ring``, and (economy
    rounds) the ``econ`` state — are donated:
    they are consumed by the scan and handed back as outputs, so XLA can
    run the whole campaign in place instead of holding a dead copy of the
    optimizer state for the program's lifetime (at real model sizes the
    opt state is as large as the params, and the ring is K+1 of them).
    ``params`` is deliberately NOT donated: the initial params buffer is
    caller-owned — tests and drivers seed several engines from one
    ``params0`` — and donating it would invalidate the caller's copy.
    ``analysis.jaxpr_audit`` (JX006) checks the declared donation is
    honored in the lowered program."""
    def run(lane: LaneParams, params, opt_state, slashed, contrib,
            ring=None, econ=None):
        state = SwarmState(params=params, opt_state=opt_state,
                           slashed=slashed, contrib=contrib, ring=ring,
                           econ=econ)
        return scan_rounds(round_fn, lane, state, rounds, batch_fn, eval_fn)
    return jax.jit(run, donate_argnums=(2, 3, 4, 5, 6))


def run_campaign(loss_fn: Callable, params0, optimizer, data_fn: Callable,
                 lanes: LaneParams, *, rounds: int, aggregator,
                 agg_kwargs: Optional[Dict] = None,
                 compression_kind: Optional[str] = None,
                 compression_kwargs: Optional[Dict] = None,
                 verify: bool = False, eval_fn: Optional[Callable] = None,
                 batched_data_fn: Optional[Callable] = None,
                 fast_compile: bool = False, mixing_schedule: str = "cycle",
                 fused: Optional[bool] = None,
                 plan: Optional[MeshPlan] = None):
    """Run a whole campaign — ``vmap`` over the leading run axis of ``lanes``
    of the scanned round — as **one** jit-compiled device program.

    All lanes share the aggregator set (and its static kwargs), the wire
    codec, the data stream, and the initial params; they differ in
    everything :class:`LaneParams` carries (roster behaviour/membership,
    seed, audit rate/tolerance, aggregator id, traced agg kwargs, and — in
    decentralized campaigns — the per-lane mixing matrix, which makes
    *topology* a campaign axis).  Decentralized mode is detected from
    ``lanes.mixing`` (all lanes must agree): the round switches to per-node
    replicas + neighborhood aggregation + gossip mixing, and ``eval_fn``
    is evaluated on each lane's consensus (node-mean) params.
    Per-round data is computed once and broadcast across lanes (it does not
    depend on the lane), so a campaign costs one gradient batch per (round,
    node) per *lane* but only one data generation per (round, node).

    ``data_fn(node_idx, rnd)`` (or ``batched_data_fn(rnd)``) and ``eval_fn``
    must be jax-traceable.  ``fast_compile=True`` asks XLA for backend
    optimization level 0 — measured ~3x faster compiles with bit-identical
    results on CPU; it silently falls back to a normal jit if this
    jax/backend rejects the option.  Only use it for *tiny* models, where
    campaigns are compile-bound: on real models the unfused code pays far
    more in per-op memory traffic than it saves in compilation (measured
    ~4x slower end-to-end on the small-LM example).
    ``derailment.sweep`` picks this automatically by parameter count.

    Async mode is likewise detected from ``lanes.delays`` (all lanes must
    agree): the staleness ring is sized to the campaign-wide max delay
    (static), per-lane delay *values* stay traced — so staleness is one
    more sweep axis inside the single compiled program, and
    ``RoundRecord.staleness`` traces each round's mean realized delay.

    Custody mode is likewise detected from ``lanes.custody`` (all lanes
    must agree): every round traces ``RoundRecord.coverage`` (the live
    extraction frontier under churn/slashing), and the eval additionally
    runs the reconstruct-attack — each lane's final loss comes back as an
    ``[honest, extracted]`` pair (final losses are (R, 2) instead of (R,)),
    where ``extracted`` is the loss of the model reassembled from exactly
    the shards the lane's coalition holds.

    ``plan`` (a :class:`~repro.core.placement.MeshPlan`) makes device
    placement explicit: the stacked lane leaves are sharded over the plan's
    ``lanes`` mesh axis (bit-exact for centralized/fused/serving rounds —
    lanes are embarrassingly parallel; the decentralized mixing matmul is
    allclose only, see ``core/placement.py``), shared params over its
    within-lane ``data``/``model`` axes (allclose), and the one program
    runs under the plan's mesh with ``spmd_axis_name`` on the campaign
    vmap.  Lowering failures under a plan re-raise through
    ``plan.reraise_lowering`` — a clear error naming
    ``compat.collectives_emulated()`` on old jax instead of an XLA abort.

    Returns ``(final SwarmState, RoundRecord, final losses)`` with a leading
    run axis on every output leaf (RoundRecord leaves are (R, T, ...)).
    """
    if plan is not None:
        params0 = plan.place_params(params0)
        lanes = plan.place_lanes(lanes)
    fn = make_campaign_program(
        loss_fn, params0, optimizer, data_fn, lanes, rounds=rounds,
        aggregator=aggregator, agg_kwargs=agg_kwargs,
        compression_kind=compression_kind,
        compression_kwargs=compression_kwargs, verify=verify,
        eval_fn=eval_fn, batched_data_fn=batched_data_fn,
        mixing_schedule=mixing_schedule, fused=fused, plan=plan)

    def run_program():
        if fast_compile:
            try:
                return fn.lower(lanes).compile(
                    compiler_options={
                        "xla_backend_optimization_level": "0"})(lanes)
            except Exception:
                pass
        return fn(lanes)

    if plan is None:
        return run_program()
    with plan.mesh:
        try:
            return run_program()
        except Exception as e:
            plan.reraise_lowering(e)


def make_campaign_program(loss_fn: Callable, params0, optimizer,
                          data_fn: Callable, lanes: LaneParams, *,
                          rounds: int, aggregator,
                          agg_kwargs: Optional[Dict] = None,
                          compression_kind: Optional[str] = None,
                          compression_kwargs: Optional[Dict] = None,
                          verify: bool = False,
                          eval_fn: Optional[Callable] = None,
                          batched_data_fn: Optional[Callable] = None,
                          mixing_schedule: str = "cycle",
                          fused: Optional[bool] = None,
                          plan: Optional[MeshPlan] = None) -> Callable:
    """Build (without running) THE campaign program — the jitted
    ``fn(lanes)`` that :func:`run_campaign` executes.  ``lanes`` is
    consulted for static structure only (N, decentralized/custody mode);
    callers that place lanes on a mesh do so before/after as
    :func:`run_campaign` does.  Split out so ``analysis.jaxpr_audit`` can
    trace the *real* engine program — not a reimplementation that could
    drift — and enforce its invariants statically."""
    n = int(lanes.codes.shape[-1])
    decentralized = lanes.mixing is not None
    has_custody = lanes.custody is not None
    # economy mode is detected from the econ lane like mixing/custody: the
    # knobs stay traced (incentive axes sweep within one program); the
    # initial economy is derived per lane INSIDE the program — initial
    # stakes and the Sybil identity count depend on traced knobs
    has_econ = lanes.econ is not None
    # async mode is detected from the delays lane like mixing/custody: the
    # ring is sized to the campaign-wide max delay (static — lane *values*
    # stay traced, so staleness is a sweep axis within one program).  An
    # all-zero delays lane sizes the ring to 0 and routes through the
    # literal synchronous path.
    staleness_bound = (int(np.max(np.asarray(lanes.delays)))
                       if lanes.delays is not None else 0)
    round_fn = make_round_fn(
        loss_fn, optimizer, params0, n, aggregator=aggregator,
        agg_kwargs=agg_kwargs, compression_kind=compression_kind,
        compression_kwargs=compression_kwargs, verify=verify,
        decentralized=decentralized, mixing_schedule=mixing_schedule,
        fused=fused, staleness_bound=staleness_bound)
    if batched_data_fn is None:
        def batch_fn(rnd):
            return jax.vmap(lambda i: data_fn(i, rnd))(jnp.arange(n))
    else:
        batch_fn = batched_data_fn
    if decentralized:
        state0 = init_decentralized_state(params0, optimizer, n,
                                          staleness_bound=staleness_bound)
    else:
        state0 = init_state(params0, optimizer, n,
                            staleness_bound=staleness_bound)
    user_eval = eval_fn

    def one_run(lane):
        st0 = (state0._replace(econ=economy.init_econ_state(lane.econ, n))
               if has_econ else state0)
        efn = None
        if user_eval is not None:
            def efn(p):
                # decentralized lanes evaluate the consensus (mean) replica
                pe = consensus_params(p) if decentralized else p
                honest = user_eval(pe)
                if not has_custody:
                    return honest
                # reconstruct-attack eval: reassemble exactly the shards the
                # coalition holds (missing ones zero-filled) and price what
                # the attacker actually gets, inside the same program
                covered = shards_covered(lane.custody, lane.coalition)
                extracted = user_eval(masked_reconstruct(pe, covered))
                return jnp.stack([honest, extracted])
        return scan_rounds(round_fn, lane, st0, rounds, batch_fn, efn)

    vmapped = (jax.vmap(one_run) if plan is None
               else jax.vmap(one_run, spmd_axis_name=plan.lanes_axis))
    return jax.jit(vmapped)


def history_from_records(recs: RoundRecord, node_ids: Sequence[str], *,
                         start_round: int = 0) -> List[dict]:
    """Rebuild the per-round host history from one run's stacked records."""
    n_active = np.asarray(recs.n_active)
    n_byz = np.asarray(recs.n_byzantine)
    caught = np.asarray(recs.caught)
    agg = np.asarray(recs.agg_norm)
    cons = np.asarray(recs.consensus_err)
    cov = np.asarray(recs.coverage)
    stale = np.asarray(recs.staleness)
    coal_stake = (np.asarray(recs.coalition_stake)
                  if recs.coalition_stake is not None else None)
    out = [{
        "round": start_round + t,
        "n_active": int(n_active[t]),
        "n_byzantine": int(n_byz[t]),
        "caught": [node_ids[int(i)] for i in np.flatnonzero(caught[t])],
        "agg_norm": float(agg[t]),
        "consensus_error": float(cons[t]),
        "coverage": float(cov[t]),
        "staleness": float(stale[t]),
    } for t in range(agg.shape[0])]
    if coal_stake is not None:
        for t, row in enumerate(out):
            row["coalition_stake"] = float(coal_stake[t])
    return out


def ledger_from_run(state: SwarmState, node_ids: Sequence[str],
                    verification: Optional[VerificationConfig] = None,
                    validator: str = "validator") -> Ledger:
    """Reconstruct the ownership :class:`Ledger` from device counters.

    Equivalent to the per-round host bookkeeping of ``Swarm.step``: a node's
    balance is its speed-weighted kept rounds; a slashed node's pre-catch
    mints are forfeited (its counter froze at the catch round) and its stake
    burns, paying the validator jackpot.
    """
    led = Ledger()
    if verification is not None:
        for nid in node_ids:
            led.stake(nid, verification.stake)
    contrib = np.asarray(state.contrib)
    slashed = np.asarray(state.slashed)
    for nid, c in zip(node_ids, contrib):
        if c > 0:
            led.record_contribution(nid, float(c))
    for i in np.flatnonzero(slashed):
        led.slash(node_ids[int(i)])
        if verification is not None:
            led.pay_jackpot(validator, verification.jackpot)
    return led


# ================================ engines ======================================
class _SwarmBase:
    """State, ledger plumbing, and the run() loop shared by both engines."""

    def __init__(self, loss_fn: Callable, params, optimizer, nodes: List[NodeSpec],
                 cfg: SwarmConfig, data_fn: Callable[[int, int], dict]):
        """loss_fn(params, batch) -> scalar; data_fn(node_idx, round) -> batch."""
        self.loss_fn = loss_fn
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.nodes = list(nodes)
        self.cfg = cfg
        self.data_fn = data_fn
        self.ledger = Ledger()
        self.slashed: Set[str] = set()
        self.history: List[dict] = []
        self._base_key = jax.random.PRNGKey(cfg.seed)
        # host copy of the custody matrix (None = no custody lane) — the
        # engines read coverage from it / from the device record, and
        # callers can inspect who holds what after a run
        self.custody_matrix: Optional[np.ndarray] = (
            assign_matrix(len(self.nodes), cfg.custody.num_shards,
                          cfg.custody.redundancy, cfg.custody.seed,
                          cfg.custody.max_fraction)
            if cfg.custody is not None else None)
        if cfg.verification:
            for n in self.nodes:
                self.ledger.stake(n.node_id, cfg.verification.stake)

    def step(self, rnd: int) -> dict:
        raise NotImplementedError

    def _coverage_of(self, active_idxs: Sequence[int]) -> float:
        """Live shard coverage of the given active node indices (1.0 when
        the run has no custody lane)."""
        if self.custody_matrix is None:
            return 1.0
        if not len(active_idxs):
            return 0.0
        return float(self.custody_matrix[list(active_idxs)].any(0).mean())

    def eval_params(self):
        """The params an ``eval_fn`` should see — the decentralized engine
        overrides this with the consensus (node-mean) replica."""
        return self.params

    def _unflatten(self, vec: Array):
        """Flat fp32 vector -> params-shaped pytree.  Only SequentialSwarm
        uses this (set up lazily from its first gradient); the batched
        engine's functional core carries its own (un)flatten pair built
        from the params template in make_round_fn."""
        out, off = [], 0
        for shape, dtype in self._flat_shapes:
            size = int(np.prod(shape)) if shape else 1
            out.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self._treedef, out)

    def run(self, rounds: int, eval_fn: Optional[Callable] = None, eval_every: int = 10):
        losses = []
        for r in range(rounds):
            rec = self.step(r)
            if eval_fn and (r % eval_every == 0 or r == rounds - 1):
                rec["eval_loss"] = float(eval_fn(self.eval_params()))
                losses.append(rec["eval_loss"])
        return losses

    def _slash(self, node: NodeSpec) -> None:
        self.ledger.slash(node.node_id)
        self.ledger.pay_jackpot("validator", self.cfg.verification.jackpot)
        self.slashed.add(node.node_id)


class SequentialSwarm(_SwarmBase):
    """Per-node Python-loop engine: the readable reference oracle.

    O(N) dispatches per round; use :class:`Swarm` for anything but tests and
    equivalence checks.  Bounded staleness (``cfg.staleness_bound > 0``) is
    supported as the readable twin of the batched ring buffer: a plain dict
    of the last K+1 params snapshots, per-node delays drawn host-side from
    the identical ``(seed, _DELAY, round, node)`` key schedule (rounds must
    then be stepped consecutively from 0 — ``run`` always does).
    """

    def __init__(self, loss_fn, params, optimizer, nodes, cfg, data_fn):
        if cfg.topology is not None:
            raise ValueError("the sequential reference engine is "
                             "centralized-only; decentralized topologies "
                             "need engine='batched'")
        super().__init__(loss_fn, params, optimizer, nodes, cfg, data_fn)
        self._grad = jax.jit(jax.grad(loss_fn))
        self._flat_shapes = None
        self._snapshots: Dict[int, Any] = {}   # round -> params (async only)

    # -- helpers ----------------------------------------------------------------
    def _flatten(self, tree) -> Array:
        leaves = jax.tree.leaves(tree)
        if self._flat_shapes is None:
            self._flat_shapes = [(l.shape, l.dtype) for l in leaves]
            self._treedef = jax.tree.structure(tree)
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])

    def _apply_wire(self, gf: Array, key) -> Array:
        """Round-trip a flat gradient through the configured wire codec."""
        cfg = self.cfg
        return compression.roundtrip(cfg.compression, key, gf,
                                     **cfg.compression_kwargs)

    # -- one round ----------------------------------------------------------------
    def step(self, rnd: int) -> dict:
        cfg = self.cfg
        active = [(i, n) for i, n in enumerate(self.nodes)
                  if n.active(rnd) and n.node_id not in self.slashed]
        if not active:
            raise RuntimeError(f"round {rnd}: no active nodes")

        K = cfg.staleness_bound
        if K > 0:
            # the readable ring-buffer twin: snapshot this round's params,
            # keep the last K+1 — a node drawing delay d gradients against
            # the params as of the start of round rnd - d
            self._snapshots[rnd] = self.params
            for old in [r for r in self._snapshots if r < rnd - K]:
                del self._snapshots[old]

        honest_grads, submitted, metas, delays = [], [], [], []
        for i, node in active:
            batch = self.data_fn(i, rnd)
            d, p_node = 0, self.params
            if K > 0:
                cap = min(node.effective_delay, K, rnd)
                d = int(jax.random.randint(
                    _node_key(self._base_key, _DELAY, rnd, i), (), 0,
                    cap + 1))
                p_node = self._snapshots[rnd - d]
            g = self._grad(p_node, batch)
            gf = self._flatten(g)
            honest_grads.append(gf)
            delays.append(d)
            metas.append((i, node, batch, p_node))
        honest_mean = jnp.mean(jnp.stack(honest_grads), axis=0)

        # corruption + wire compression.  The wire key is part of the shared
        # (purpose, round, node) schedule: QSGD is deterministic given
        # (key, tensor), so a validator recomputing the gradient re-encodes
        # with the submitter's key and compares like with like (otherwise
        # honest lossy compression reads as cheating).
        wire_keys = []
        for gf, (i, node, _, _) in zip(honest_grads, metas):
            if node.byzantine:
                gf = corrupt(node.byzantine, gf, honest_mean, node.byzantine_scale,
                             _node_key(self._base_key, _CORRUPT, rnd, i))
            wk = _node_key(self._base_key, _WIRE, rnd, i)
            wire_keys.append(wk)
            submitted.append(self._apply_wire(gf, wk))

        # stake/slash audits (§4.2)
        caught = []
        keep = [True] * len(active)
        if cfg.verification:
            v = cfg.verification
            for j, (i, node, batch, p_node) in enumerate(metas):
                sel = jax.random.uniform(_node_key(self._base_key, _AUDIT_SEL, rnd, i))
                if float(sel) >= v.p_check:
                    continue
                # recompute the gradient, re-encode with the submitter's wire
                # key, and compare flat — audit_flat is the same noise/compare
                # formula the batched engine vmaps, so both engines reach the
                # same pass/slash decision even at the tolerance boundary.
                # ``p_node`` is the submitter's (possibly stale) snapshot:
                # the validator replays the delay from the shared key
                # schedule and audits against the SAME params the
                # contributor claims — stale-but-honest never slashes.
                recomputed = self._apply_wire(
                    self._flatten(self._grad(p_node, batch)), wire_keys[j])
                ok, mismatch = audit_flat(
                    submitted[j], recomputed,
                    _node_key(self._base_key, _AUDIT_NOISE, rnd, i), v)
                if not ok:
                    self._slash(node)
                    caught.append(node.node_id)
                    keep[j] = False

        kept = [g for g, k in zip(submitted, keep) if k]
        if kept:
            survivors = jnp.stack(kept)
            agg = aggregation.get_aggregator(cfg.aggregator, **cfg.agg_kwargs)(survivors)
            self.params, self.opt_state = self.optimizer.update(
                self._unflatten(agg), self.opt_state, self.params)
        else:
            agg = jnp.zeros_like(honest_grads[0])  # every update audited out

        # mint shares ∝ verified work (speed-weighted) (§4)
        for (_, node, _, _), k in zip(metas, keep):
            if k:
                self.ledger.record_contribution(node.node_id, node.speed)

        rec = {
            "round": rnd,
            "n_active": len(active),
            "n_byzantine": sum(1 for _, n in active if n.byzantine),
            "caught": caught,
            "agg_norm": float(jnp.linalg.norm(agg)),
            "consensus_error": 0.0,        # centralized: one shared params
            "coverage": self._coverage_of([i for i, _ in active]),
            # f32 division so the record equals the batched engine's exactly
            "staleness": float(np.float32(sum(delays))
                               / np.float32(max(len(active), 1))),
        }
        self.history.append(rec)
        return rec


class Swarm(_SwarmBase):
    """Batched, jit-compiled protocol-learning engine (the default).

    A thin wrapper over the functional core (:func:`make_round_fn`): one
    device program per round, fixed (N, D) shapes forever:

    - gradients: ``jax.vmap(jax.grad(loss_fn))`` over stacked per-node batches;
    - corruption: the vectorized select table over per-node behaviour codes;
    - wire codec: ``vmap`` of ``compression.roundtrip`` over per-node keys;
    - audits: ``verification.audit_batch`` on the full stack, gated by a
      per-node audit-selection mask;
    - aggregation: mask-aware aggregators (``aggregation.masked_*``) driven
      by ``keep = active & ~caught``.

    Inactive nodes still occupy a lane (their gradient is computed and then
    masked) — that is the price of a churn-proof compiled round, and it is
    why this engine is O(1) dispatches per round instead of O(N).

    ``run`` with no ``eval_fn`` dispatches the **scanned** core — the whole
    run is one ``lax.scan`` device program with zero per-round host
    round-trips; the host history and ledger are rebuilt from device
    counters afterwards.  (Requires ``data_fn``/``batched_data_fn`` to be
    jax-traceable; otherwise it falls back to the per-round ``step`` loop.
    Note the scanned path cannot raise mid-run if audits slash the last
    active node — such rounds aggregate to zero instead, exactly as a
    fully-audited-out round does.)

    ``batched_data_fn(rnd) -> batch-with-leading-N-axis`` skips the per-node
    host stacking loop when the data pipeline can produce a stacked batch
    directly (see ``core.scenarios.batched_data_fn_for``).

    ``cfg.topology`` (a ``core.topology`` registry name) switches this
    engine to the **decentralized** round: ``self.params`` becomes per-node
    replicas (leading N axis), each round every node neighborhood-aggregates
    and the replicas gossip-mix, ``history`` rows gain a nonzero
    ``consensus_error``, and ``eval_params()`` returns the consensus
    (node-mean) replica for evaluation.  Everything else — step/run/scan
    dispatch, ledger, slashing — is unchanged.
    """

    def __init__(self, loss_fn, params, optimizer, nodes, cfg, data_fn, *,
                 batched_data_fn: Optional[Callable[[int], dict]] = None):
        super().__init__(loss_fn, params, optimizer, nodes, cfg, data_fn)
        self.batched_data_fn = batched_data_fn
        n = len(self.nodes)
        self._decentralized = cfg.topology is not None
        self._lane = lane_for_nodes(self.nodes, cfg)
        self._joins_np = np.asarray([s.join_round for s in self.nodes], np.int32)
        self._leaves_np = np.asarray(
            [_FAR if s.leave_round is None else s.leave_round for s in self.nodes],
            np.int32)
        self._slashed_np = np.zeros(n, bool)
        self._core = make_round_fn(
            loss_fn, optimizer, self.params, n,
            aggregator=cfg.aggregator, agg_kwargs=cfg.agg_kwargs,
            compression_kind=cfg.compression,
            compression_kwargs=cfg.compression_kwargs,
            verify=cfg.verification is not None,
            decentralized=self._decentralized,
            mixing_schedule="clamp" if cfg.churn_coupled else "cycle",
            fused=cfg.fused, staleness_bound=cfg.staleness_bound)
        if self._decentralized:
            # per-node replicas + per-node optimizer states from round 0
            init = init_decentralized_state(self.params, optimizer, n)
            self.params, self.opt_state = init.params, init.opt_state
        # the bounded-staleness snapshot ring (None when synchronous) —
        # engine state like params/opt_state, advanced by every round
        self._ring = init_ring(self.params, cfg.staleness_bound)
        # the economy state (None without an economy lane) — ditto
        self._econ_state = (economy.init_econ_state(self._lane.econ, n)
                            if self._lane.econ is not None else None)
        self._round_fn = jax.jit(functools.partial(self._core, self._lane))
        self._scan_cache: Dict[int, Callable] = {}
        self._batches_traceable: Optional[bool] = None

    # -- helpers ----------------------------------------------------------------
    def _stack_batches(self, rnd: int):
        if self.batched_data_fn is not None:
            return self.batched_data_fn(rnd)
        per_node = [self.data_fn(i, rnd) for i in range(len(self.nodes))]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_node)

    def _traced_batch_fn(self) -> Callable:
        if self.batched_data_fn is not None:
            return self.batched_data_fn
        n = len(self.nodes)
        return lambda rnd: jax.vmap(lambda i: self.data_fn(i, rnd))(jnp.arange(n))

    def _state(self) -> SwarmState:
        return SwarmState(params=self.params, opt_state=self.opt_state,
                          slashed=jnp.asarray(self._slashed_np),
                          contrib=jnp.zeros(len(self.nodes), jnp.float32),
                          ring=self._ring, econ=self._econ_state)

    def _can_scan(self, rounds: int) -> bool:
        """Scanned run needs a traceable batch fn and a membership schedule
        that never goes empty (the step loop raises at the exact round)."""
        r = np.arange(rounds)[:, None]
        sched = ((self._joins_np[None] <= r) & (r < self._leaves_np[None])
                 & ~self._slashed_np[None])
        if not sched.any(axis=1).all():
            return False
        if self._batches_traceable is None:
            try:
                jax.eval_shape(self._traced_batch_fn(), jnp.asarray(0, jnp.int32))
                self._batches_traceable = True
            except Exception:
                self._batches_traceable = False
        return self._batches_traceable

    # -- one round ----------------------------------------------------------------
    def step(self, rnd: int) -> dict:
        active_np = ((self._joins_np <= rnd) & (rnd < self._leaves_np)
                     & ~self._slashed_np)
        if not active_np.any():
            raise RuntimeError(f"round {rnd}: no active nodes")

        batches = self._stack_batches(rnd)
        state, core_rec = self._round_fn(self._state(), rnd, batches)
        self.params, self.opt_state = state.params, state.opt_state
        self._ring = state.ring
        self._econ_state = state.econ

        caught_ids = []
        for i in np.flatnonzero(np.asarray(core_rec.caught)):
            node = self.nodes[int(i)]
            self._slash(node)
            self._slashed_np[int(i)] = True
            caught_ids.append(node.node_id)
        for i in np.flatnonzero(np.asarray(core_rec.keep)):
            node = self.nodes[int(i)]
            self.ledger.record_contribution(node.node_id, node.speed)

        rec = {
            "round": rnd,
            # economy rounds gate admission on device (stakes) — the record
            # is the authoritative count there
            "n_active": (int(core_rec.n_active) if self._econ_state is not None
                         else int(active_np.sum())),
            "n_byzantine": (int(core_rec.n_byzantine)
                            if self._econ_state is not None
                            else int(sum(1 for i in np.flatnonzero(active_np)
                                         if self.nodes[int(i)].byzantine))),
            "caught": caught_ids,
            "agg_norm": float(core_rec.agg_norm),
            "consensus_error": float(core_rec.consensus_err),
            "coverage": float(core_rec.coverage),
            "staleness": float(core_rec.staleness),
        }
        if core_rec.coalition_stake is not None:
            rec["coalition_stake"] = float(core_rec.coalition_stake)
        self.history.append(rec)
        return rec

    def eval_params(self):
        return consensus_params(self.params) if self._decentralized \
            else self.params

    # -- the scanned run ---------------------------------------------------------
    def run(self, rounds: int, eval_fn: Optional[Callable] = None,
            eval_every: int = 10):
        if eval_fn is None and self._can_scan(rounds):
            self._run_scanned(rounds)
            return []
        return super().run(rounds, eval_fn, eval_every)

    def _run_scanned(self, rounds: int) -> None:
        if rounds not in self._scan_cache:
            self._scan_cache[rounds] = make_scan_program(
                self._core, self._traced_batch_fn(), rounds)
        was_slashed = self._slashed_np.copy()
        st = self._state()
        # opt_state/slashed/contrib/ring are donated (make_scan_program) and
        # reassigned from the outputs below — never read the old buffers
        state, recs, _ = self._scan_cache[rounds](
            self._lane, st.params, st.opt_state, st.slashed, st.contrib,
            st.ring, st.econ)
        self.params, self.opt_state = state.params, state.opt_state
        self._ring = state.ring
        self._econ_state = state.econ
        # run() numbers rounds from 0 on every call (same as the step loop)
        self.history.extend(history_from_records(
            recs, [n.node_id for n in self.nodes]))
        # Ledger from device counters — mints first, then this run's slashes,
        # so a slashed node's pre-catch mints are forfeited exactly as in the
        # per-round step path (its contrib counter froze at the catch round).
        contrib = np.asarray(state.contrib)
        for i, node in enumerate(self.nodes):
            if contrib[i] > 0:
                self.ledger.record_contribution(node.node_id, float(contrib[i]))
        for i in np.flatnonzero(np.asarray(state.slashed) & ~was_slashed):
            node = self.nodes[int(i)]
            self._slash(node)
            self._slashed_np[int(i)] = True


ENGINES: Dict[str, type] = {"batched": Swarm, "sequential": SequentialSwarm}


def make_swarm(loss_fn, params, optimizer, nodes: List[NodeSpec], cfg: SwarmConfig,
               data_fn, *, engine: str = "batched",
               batched_data_fn: Optional[Callable[[int], dict]] = None) -> _SwarmBase:
    """Build a swarm with the requested engine ("batched" | "sequential")."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (known: {sorted(ENGINES)})")
    if batched_data_fn is not None:
        if engine != "batched":
            raise ValueError("batched_data_fn requires engine='batched'")
        return Swarm(loss_fn, params, optimizer, nodes, cfg, data_fn,
                     batched_data_fn=batched_data_fn)
    return ENGINES[engine](loss_fn, params, optimizer, nodes, cfg, data_fn)
