"""Swarm simulator: the paper's five §3 properties in one runnable system.

Simulates N protocol participants training one model:
  1. communication efficiency — optional on-the-wire compression (lossy,
     round-tripped through core.compression);
  2. model sharding — the model itself runs sharded under pjit in
     launch/train.py; the swarm layer treats a node as a *logical* gradient
     contributor (a node may be a whole cluster — paper §2 last paragraph);
  3. elastic membership — nodes join/leave on a schedule, aggregation only
     sees currently-active nodes;
  4. byzantine tolerance — per-node corruption behaviours + robust
     aggregation from core.aggregation;
  5. heterogeneous capacity — per-node speed scales both contributed batch
     count and minted shares.

Plus the §4 mechanisms: stake/slash verification audits and the ownership
ledger.  Runs on CPU with a real (small) model; the aggregation math is
identical at any scale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, compression
from repro.core.ledger import Ledger
from repro.core.verification import VerificationConfig, audit

Array = jax.Array


@dataclass(frozen=True)
class NodeSpec:
    node_id: str
    speed: float = 1.0
    byzantine: Optional[str] = None      # None|sign_flip|scale|noise|zero|inner_product
    byzantine_scale: float = 10.0
    join_round: int = 0
    leave_round: Optional[int] = None

    def active(self, rnd: int) -> bool:
        return self.join_round <= rnd and (self.leave_round is None or rnd < self.leave_round)


@dataclass(frozen=True)
class SwarmConfig:
    aggregator: str = "centered_clip"
    agg_kwargs: Dict = field(default_factory=dict)
    verification: Optional[VerificationConfig] = None
    compression: Optional[str] = None    # None|"qsgd"|"topk"
    compression_kwargs: Dict = field(default_factory=dict)
    seed: int = 0


def corrupt(kind: str, grad_flat: Array, honest_mean: Array, scale: float, key) -> Array:
    if kind == "sign_flip":
        return -scale * grad_flat
    if kind == "scale":
        return scale * grad_flat
    if kind == "noise":
        return grad_flat + scale * jax.random.normal(key, grad_flat.shape)
    if kind == "zero":
        return jnp.zeros_like(grad_flat)
    if kind == "inner_product":
        # [87]-style: oppose the honest consensus direction
        return -scale * honest_mean
    raise ValueError(kind)


class Swarm:
    """Protocol-learning training loop over simulated participants."""

    def __init__(self, loss_fn: Callable, params, optimizer, nodes: List[NodeSpec],
                 cfg: SwarmConfig, data_fn: Callable[[int, int], dict]):
        """loss_fn(params, batch) -> scalar; data_fn(node_idx, round) -> batch."""
        self.loss_fn = loss_fn
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.nodes = list(nodes)
        self.cfg = cfg
        self.data_fn = data_fn
        self.ledger = Ledger()
        self.slashed: set = set()
        self.rng = np.random.default_rng(cfg.seed)
        self._key = jax.random.PRNGKey(cfg.seed)
        self._grad = jax.jit(jax.grad(loss_fn))
        self._flat_shapes = None
        self.history: List[dict] = []
        if cfg.verification:
            for n in self.nodes:
                self.ledger.stake(n.node_id, cfg.verification.stake)

    # -- helpers ----------------------------------------------------------------
    def _flatten(self, tree) -> Array:
        leaves = jax.tree.leaves(tree)
        if self._flat_shapes is None:
            self._flat_shapes = [(l.shape, l.dtype) for l in leaves]
            self._treedef = jax.tree.structure(tree)
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])

    def _unflatten(self, vec: Array):
        out, off = [], 0
        for shape, dtype in self._flat_shapes:
            size = int(np.prod(shape)) if shape else 1
            out.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self._treedef, out)

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _apply_wire(self, gf: Array, key) -> Array:
        """Round-trip a flat gradient through the configured wire codec."""
        cfg = self.cfg
        if cfg.compression == "qsgd":
            c = compression.qsgd_compress(key, gf, **cfg.compression_kwargs)
            return compression.qsgd_decompress(c)
        if cfg.compression == "topk":
            c = compression.topk_compress(gf, **cfg.compression_kwargs)
            return compression.topk_decompress(c)
        return gf

    # -- one round ----------------------------------------------------------------
    def step(self, rnd: int) -> dict:
        cfg = self.cfg
        active = [n for n in self.nodes if n.active(rnd) and n.node_id not in self.slashed]
        if not active:
            raise RuntimeError(f"round {rnd}: no active nodes")

        honest_grads, submitted, metas = [], [], []
        for i, node in enumerate(active):
            batch = self.data_fn(self.nodes.index(node), rnd)
            g = self._grad(self.params, batch)
            gf = self._flatten(g)
            honest_grads.append(gf)
            metas.append((node, batch))
        honest_mean = jnp.mean(jnp.stack(honest_grads), axis=0)

        # corruption + wire compression.  The wire key is RECORDED: QSGD is
        # deterministic given (key, tensor), so a validator recomputing the
        # gradient re-encodes with the submitter's key and compares like
        # with like (otherwise honest lossy compression reads as cheating).
        wire_keys = []
        for gf, (node, _) in zip(honest_grads, metas):
            if node.byzantine:
                gf = corrupt(node.byzantine, gf, honest_mean, node.byzantine_scale,
                             self._next_key())
            wk = self._next_key()
            wire_keys.append(wk)
            submitted.append(self._apply_wire(gf, wk))

        # stake/slash audits (§4.2)
        caught = []
        keep = [True] * len(active)
        if cfg.verification:
            v = cfg.verification
            for i, (node, batch) in enumerate(metas):
                if self.rng.random() >= v.p_check:
                    continue

                def recompute(b=batch, wk=wire_keys[i]):
                    g = self._flatten(self._grad(self.params, b))
                    return self._unflatten(self._apply_wire(g, wk))

                ok, mismatch = audit(self._unflatten(submitted[i]), recompute, v,
                                     self._next_key())
                if not ok:
                    self.ledger.slash(node.node_id)
                    self.ledger.pay_jackpot("validator", v.jackpot)
                    self.slashed.add(node.node_id)
                    caught.append(node.node_id)
                    keep[i] = False

        kept = [g for g, k in zip(submitted, keep) if k]
        if kept:
            survivors = jnp.stack(kept)
            agg = aggregation.get_aggregator(cfg.aggregator, **cfg.agg_kwargs)(survivors)
            self.params, self.opt_state = self.optimizer.update(
                self._unflatten(agg), self.opt_state, self.params)
        else:
            agg = jnp.zeros_like(honest_grads[0])  # every update audited out

        # mint shares ∝ verified work (speed-weighted) (§4)
        for (node, _), k in zip(metas, keep):
            if k:
                self.ledger.record_contribution(node.node_id, node.speed)

        rec = {
            "round": rnd,
            "n_active": len(active),
            "n_byzantine": sum(1 for n in active if n.byzantine),
            "caught": caught,
            "agg_norm": float(jnp.linalg.norm(agg)),
        }
        self.history.append(rec)
        return rec

    def run(self, rounds: int, eval_fn: Optional[Callable] = None, eval_every: int = 10):
        losses = []
        for r in range(rounds):
            rec = self.step(r)
            if eval_fn and (r % eval_every == 0 or r == rounds - 1):
                rec["eval_loss"] = float(eval_fn(self.params))
                losses.append(rec["eval_loss"])
        return losses
