"""Swarm simulator: the paper's five §3 properties in one runnable system.

Simulates N protocol participants training one model:
  1. communication efficiency — optional on-the-wire compression (lossy,
     round-tripped through core.compression);
  2. model sharding — the model itself runs sharded under pjit in
     launch/train.py; the swarm layer treats a node as a *logical* gradient
     contributor (a node may be a whole cluster — paper §2 last paragraph);
  3. elastic membership — nodes join/leave on a schedule, aggregation only
     sees currently-active nodes;
  4. byzantine tolerance — per-node corruption behaviours + robust
     aggregation from core.aggregation;
  5. heterogeneous capacity — per-node speed scales both contributed batch
     count and minted shares.

Plus the §4 mechanisms: stake/slash verification audits and the ownership
ledger.  Runs on CPU with a real (small) model; the aggregation math is
identical at any scale.

Two engines share one API (``step``/``run``/``history``/``ledger``):

- :class:`Swarm` — the default **batched engine**.  One jitted round computes
  all N node gradients with ``jax.vmap(jax.grad(loss_fn))``, corruption as a
  vectorized ``lax.switch`` over per-node behaviour codes, the wire codec as a
  ``vmap`` over per-node keys, audits via ``verification.audit_batch``, and
  aggregation through the mask-aware aggregators in ``core.aggregation``.
  Membership and slashing are a boolean active-mask, so the jitted round has a
  **fixed shape across rounds** — churn never triggers recompilation.
- :class:`SequentialSwarm` — the original per-node Python loop, kept as the
  readable reference oracle the batched engine is equivalence-tested against.

Both engines draw every random number from the same per-(purpose, round,
node) ``fold_in`` schedule, so with the same seed they produce the *same*
corruption noise, wire-codec realizations, audit selections, and therefore
the same ``agg_norm`` history (within fp32 reduction-order tolerance).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, compression
from repro.core.ledger import Ledger
from repro.core.verification import VerificationConfig, audit_batch, audit_flat

Array = jax.Array

#: Byzantine behaviours, indexed by the code used in the vectorized
#: ``lax.switch`` corruption table.  Code 0 is honest (identity).
BEHAVIOURS = ("honest", "sign_flip", "scale", "noise", "zero", "inner_product")
BEHAVIOUR_CODES: Dict[str, int] = {name: i for i, name in enumerate(BEHAVIOURS)}

# Key-schedule purposes.  Every random draw in a round is keyed by
# (seed, purpose, round, node_index) via fold_in — engine-independent, which
# is what makes the sequential reference and the batched engine bit-identical
# in their randomness (and keeps the batched round free of host-side key
# chains that would serialize it).
_CORRUPT, _WIRE, _AUDIT_SEL, _AUDIT_NOISE = range(4)


def _node_key(base: Array, purpose: int, rnd, node_idx) -> Array:
    k = jax.random.fold_in(base, purpose)
    k = jax.random.fold_in(k, rnd)
    return jax.random.fold_in(k, node_idx)


@dataclass(frozen=True)
class NodeSpec:
    node_id: str
    speed: float = 1.0
    byzantine: Optional[str] = None      # None|sign_flip|scale|noise|zero|inner_product
    byzantine_scale: float = 10.0
    join_round: int = 0
    leave_round: Optional[int] = None

    def active(self, rnd: int) -> bool:
        return self.join_round <= rnd and (self.leave_round is None or rnd < self.leave_round)

    @property
    def behaviour_code(self) -> int:
        kind = self.byzantine or "honest"
        if kind not in BEHAVIOUR_CODES:
            raise ValueError(f"unknown byzantine behaviour: {kind!r} "
                             f"(known: {BEHAVIOURS})")
        return BEHAVIOUR_CODES[kind]


@dataclass(frozen=True)
class SwarmConfig:
    aggregator: str = "centered_clip"
    agg_kwargs: Dict = field(default_factory=dict)
    verification: Optional[VerificationConfig] = None
    compression: Optional[str] = None    # None|"qsgd"|"topk"
    compression_kwargs: Dict = field(default_factory=dict)
    seed: int = 0


def corrupt(kind: str, grad_flat: Array, honest_mean: Array, scale: float, key) -> Array:
    """Scalar (single-node) corruption table — the reference the vectorized
    ``lax.switch`` table below must match branch for branch."""
    if kind == "sign_flip":
        return -scale * grad_flat
    if kind == "scale":
        return scale * grad_flat
    if kind == "noise":
        return grad_flat + scale * jax.random.normal(key, grad_flat.shape)
    if kind == "zero":
        return jnp.zeros_like(grad_flat)
    if kind == "inner_product":
        # [87]-style: oppose the honest consensus direction
        return -scale * honest_mean
    raise ValueError(kind)


# Vectorized corruption: branch b is BEHAVIOURS[b]; applied per node under
# vmap as lax.switch(code, branches, grad, honest_mean, scale, key).
_CORRUPT_BRANCHES = (
    lambda g, hm, s, k: g,                                        # honest
    lambda g, hm, s, k: -s * g,                                   # sign_flip
    lambda g, hm, s, k: s * g,                                    # scale
    lambda g, hm, s, k: g + s * jax.random.normal(k, g.shape),    # noise
    lambda g, hm, s, k: jnp.zeros_like(g),                        # zero
    lambda g, hm, s, k: -s * hm,                                  # inner_product
)


class _SwarmBase:
    """State, ledger plumbing, and the run() loop shared by both engines."""

    def __init__(self, loss_fn: Callable, params, optimizer, nodes: List[NodeSpec],
                 cfg: SwarmConfig, data_fn: Callable[[int, int], dict]):
        """loss_fn(params, batch) -> scalar; data_fn(node_idx, round) -> batch."""
        self.loss_fn = loss_fn
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.nodes = list(nodes)
        self.cfg = cfg
        self.data_fn = data_fn
        self.ledger = Ledger()
        self.slashed: Set[str] = set()
        self.history: List[dict] = []
        self._base_key = jax.random.PRNGKey(cfg.seed)
        if cfg.verification:
            for n in self.nodes:
                self.ledger.stake(n.node_id, cfg.verification.stake)

    def step(self, rnd: int) -> dict:
        raise NotImplementedError

    def _unflatten(self, vec: Array):
        """Flat fp32 vector -> params-shaped pytree (set up by each engine:
        lazily from the first gradient in SequentialSwarm, from params at
        __init__ in Swarm — both structures are identical)."""
        out, off = [], 0
        for shape, dtype in self._flat_shapes:
            size = int(np.prod(shape)) if shape else 1
            out.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self._treedef, out)

    def run(self, rounds: int, eval_fn: Optional[Callable] = None, eval_every: int = 10):
        losses = []
        for r in range(rounds):
            rec = self.step(r)
            if eval_fn and (r % eval_every == 0 or r == rounds - 1):
                rec["eval_loss"] = float(eval_fn(self.params))
                losses.append(rec["eval_loss"])
        return losses

    def _slash(self, node: NodeSpec) -> None:
        self.ledger.slash(node.node_id)
        self.ledger.pay_jackpot("validator", self.cfg.verification.jackpot)
        self.slashed.add(node.node_id)


class SequentialSwarm(_SwarmBase):
    """Per-node Python-loop engine: the readable reference oracle.

    O(N) dispatches per round; use :class:`Swarm` for anything but tests and
    equivalence checks.
    """

    def __init__(self, loss_fn, params, optimizer, nodes, cfg, data_fn):
        super().__init__(loss_fn, params, optimizer, nodes, cfg, data_fn)
        self._grad = jax.jit(jax.grad(loss_fn))
        self._flat_shapes = None

    # -- helpers ----------------------------------------------------------------
    def _flatten(self, tree) -> Array:
        leaves = jax.tree.leaves(tree)
        if self._flat_shapes is None:
            self._flat_shapes = [(l.shape, l.dtype) for l in leaves]
            self._treedef = jax.tree.structure(tree)
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])

    def _apply_wire(self, gf: Array, key) -> Array:
        """Round-trip a flat gradient through the configured wire codec."""
        cfg = self.cfg
        return compression.roundtrip(cfg.compression, key, gf,
                                     **cfg.compression_kwargs)

    # -- one round ----------------------------------------------------------------
    def step(self, rnd: int) -> dict:
        cfg = self.cfg
        active = [(i, n) for i, n in enumerate(self.nodes)
                  if n.active(rnd) and n.node_id not in self.slashed]
        if not active:
            raise RuntimeError(f"round {rnd}: no active nodes")

        honest_grads, submitted, metas = [], [], []
        for i, node in active:
            batch = self.data_fn(i, rnd)
            g = self._grad(self.params, batch)
            gf = self._flatten(g)
            honest_grads.append(gf)
            metas.append((i, node, batch))
        honest_mean = jnp.mean(jnp.stack(honest_grads), axis=0)

        # corruption + wire compression.  The wire key is part of the shared
        # (purpose, round, node) schedule: QSGD is deterministic given
        # (key, tensor), so a validator recomputing the gradient re-encodes
        # with the submitter's key and compares like with like (otherwise
        # honest lossy compression reads as cheating).
        wire_keys = []
        for gf, (i, node, _) in zip(honest_grads, metas):
            if node.byzantine:
                gf = corrupt(node.byzantine, gf, honest_mean, node.byzantine_scale,
                             _node_key(self._base_key, _CORRUPT, rnd, i))
            wk = _node_key(self._base_key, _WIRE, rnd, i)
            wire_keys.append(wk)
            submitted.append(self._apply_wire(gf, wk))

        # stake/slash audits (§4.2)
        caught = []
        keep = [True] * len(active)
        if cfg.verification:
            v = cfg.verification
            for j, (i, node, batch) in enumerate(metas):
                sel = jax.random.uniform(_node_key(self._base_key, _AUDIT_SEL, rnd, i))
                if float(sel) >= v.p_check:
                    continue
                # recompute the gradient, re-encode with the submitter's wire
                # key, and compare flat — audit_flat is the same noise/compare
                # formula the batched engine vmaps, so both engines reach the
                # same pass/slash decision even at the tolerance boundary
                recomputed = self._apply_wire(
                    self._flatten(self._grad(self.params, batch)), wire_keys[j])
                ok, mismatch = audit_flat(
                    submitted[j], recomputed,
                    _node_key(self._base_key, _AUDIT_NOISE, rnd, i), v)
                if not ok:
                    self._slash(node)
                    caught.append(node.node_id)
                    keep[j] = False

        kept = [g for g, k in zip(submitted, keep) if k]
        if kept:
            survivors = jnp.stack(kept)
            agg = aggregation.get_aggregator(cfg.aggregator, **cfg.agg_kwargs)(survivors)
            self.params, self.opt_state = self.optimizer.update(
                self._unflatten(agg), self.opt_state, self.params)
        else:
            agg = jnp.zeros_like(honest_grads[0])  # every update audited out

        # mint shares ∝ verified work (speed-weighted) (§4)
        for (_, node, _), k in zip(metas, keep):
            if k:
                self.ledger.record_contribution(node.node_id, node.speed)

        rec = {
            "round": rnd,
            "n_active": len(active),
            "n_byzantine": sum(1 for _, n in active if n.byzantine),
            "caught": caught,
            "agg_norm": float(jnp.linalg.norm(agg)),
        }
        self.history.append(rec)
        return rec


class Swarm(_SwarmBase):
    """Batched, jit-compiled protocol-learning engine (the default).

    One device program per round, fixed (N, D) shapes forever:

    - gradients: ``jax.vmap(jax.grad(loss_fn))`` over stacked per-node batches;
    - corruption: vectorized ``lax.switch`` over per-node behaviour codes;
    - wire codec: ``vmap`` of ``compression.roundtrip`` over per-node keys;
    - audits: ``verification.audit_batch`` on the full stack, gated by a
      per-node audit-selection mask;
    - aggregation: mask-aware aggregators (``aggregation.masked_*``) driven
      by ``keep = active & ~caught``.

    Inactive nodes still occupy a lane (their gradient is computed and then
    masked) — that is the price of a churn-proof compiled round, and it is
    why this engine is O(1) dispatches per round instead of O(N).

    ``batched_data_fn(rnd) -> batch-with-leading-N-axis`` skips the per-node
    host stacking loop when the data pipeline can produce a stacked batch
    directly (see ``core.scenarios.batched_data_fn_for``).
    """

    def __init__(self, loss_fn, params, optimizer, nodes, cfg, data_fn, *,
                 batched_data_fn: Optional[Callable[[int], dict]] = None):
        super().__init__(loss_fn, params, optimizer, nodes, cfg, data_fn)
        self.batched_data_fn = batched_data_fn
        n = len(self.nodes)
        self._codes = jnp.asarray([s.behaviour_code for s in self.nodes], jnp.int32)
        self._scales = jnp.asarray([s.byzantine_scale for s in self.nodes], jnp.float32)
        far = np.iinfo(np.int32).max
        self._joins_np = np.asarray([s.join_round for s in self.nodes], np.int32)
        self._leaves_np = np.asarray(
            [far if s.leave_round is None else s.leave_round for s in self.nodes],
            np.int32)
        self._joins = jnp.asarray(self._joins_np)
        self._leaves = jnp.asarray(self._leaves_np)
        self._slashed_np = np.zeros(n, bool)
        leaves = jax.tree.leaves(self.params)
        self._treedef = jax.tree.structure(self.params)
        self._flat_shapes = [(l.shape, l.dtype) for l in leaves]
        self._round_fn = jax.jit(self._round)

    # -- helpers ----------------------------------------------------------------
    def _flatten_stack(self, tree) -> Array:
        """pytree with leading node axis -> (N, D) fp32 matrix."""
        n = len(self.nodes)
        return jnp.concatenate([l.reshape(n, -1).astype(jnp.float32)
                                for l in jax.tree.leaves(tree)], axis=1)

    def _stack_batches(self, rnd: int):
        if self.batched_data_fn is not None:
            return self.batched_data_fn(rnd)
        per_node = [self.data_fn(i, rnd) for i in range(len(self.nodes))]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_node)

    # -- the jitted round --------------------------------------------------------
    def _round(self, params, opt_state, batches, rnd, slashed_mask):
        cfg = self.cfg
        n = len(self.nodes)
        active = (self._joins <= rnd) & (rnd < self._leaves) & (~slashed_mask)
        nact = jnp.sum(active.astype(jnp.float32))

        grads = jax.vmap(jax.grad(self.loss_fn), in_axes=(None, 0))(params, batches)
        gf = self._flatten_stack(grads)                               # (N, D)
        maskf = active.astype(jnp.float32)[:, None]
        honest_mean = jnp.sum(gf * maskf, axis=0) / jnp.maximum(nact, 1.0)

        idx = jnp.arange(n)
        ck = jax.vmap(lambda i: _node_key(self._base_key, _CORRUPT, rnd, i))(idx)
        wk = jax.vmap(lambda i: _node_key(self._base_key, _WIRE, rnd, i))(idx)
        corrupted = jax.vmap(
            lambda c, g, s, k: jax.lax.switch(c, _CORRUPT_BRANCHES,
                                              g, honest_mean, s, k)
        )(self._codes, gf, self._scales, ck)

        def wire(key, g):
            return compression.roundtrip(cfg.compression, key, g,
                                         **cfg.compression_kwargs)

        submitted = jax.vmap(wire)(wk, corrupted)

        caught = jnp.zeros(n, bool)
        if cfg.verification:                      # static: baked at trace time
            v = cfg.verification
            sel = jax.vmap(lambda i: jax.random.uniform(
                _node_key(self._base_key, _AUDIT_SEL, rnd, i)))(idx)
            audited = active & (sel < v.p_check)
            # the validator recomputes the honest gradient and re-encodes it
            # with the submitter's wire key (see SequentialSwarm.step)
            recomputed = jax.vmap(wire)(wk, gf)
            nk = jax.vmap(lambda i: _node_key(self._base_key, _AUDIT_NOISE,
                                              rnd, i))(idx)
            passes, _ = audit_batch(submitted, recomputed, nk, v)
            caught = audited & (~passes)
        keep = active & (~caught)

        agg = aggregation.get_masked_aggregator(
            cfg.aggregator, **cfg.agg_kwargs)(submitted, keep)
        any_keep = jnp.any(keep)
        agg = jnp.where(any_keep, agg, jnp.zeros_like(agg))
        new_params, new_opt = jax.lax.cond(
            any_keep,
            lambda p, o: self.optimizer.update(self._unflatten(agg), o, p),
            lambda p, o: (p, o),
            params, opt_state)
        return new_params, new_opt, caught, keep, jnp.linalg.norm(agg)

    # -- one round ----------------------------------------------------------------
    def step(self, rnd: int) -> dict:
        active_np = ((self._joins_np <= rnd) & (rnd < self._leaves_np)
                     & ~self._slashed_np)
        if not active_np.any():
            raise RuntimeError(f"round {rnd}: no active nodes")

        batches = self._stack_batches(rnd)
        self.params, self.opt_state, caught, keep, agg_norm = self._round_fn(
            self.params, self.opt_state, batches, rnd,
            jnp.asarray(self._slashed_np))

        caught_ids = []
        for i in np.flatnonzero(np.asarray(caught)):
            node = self.nodes[int(i)]
            self._slash(node)
            self._slashed_np[int(i)] = True
            caught_ids.append(node.node_id)
        for i in np.flatnonzero(np.asarray(keep)):
            node = self.nodes[int(i)]
            self.ledger.record_contribution(node.node_id, node.speed)

        rec = {
            "round": rnd,
            "n_active": int(active_np.sum()),
            "n_byzantine": int(sum(1 for i in np.flatnonzero(active_np)
                                   if self.nodes[int(i)].byzantine)),
            "caught": caught_ids,
            "agg_norm": float(agg_norm),
        }
        self.history.append(rec)
        return rec


ENGINES: Dict[str, type] = {"batched": Swarm, "sequential": SequentialSwarm}


def make_swarm(loss_fn, params, optimizer, nodes: List[NodeSpec], cfg: SwarmConfig,
               data_fn, *, engine: str = "batched",
               batched_data_fn: Optional[Callable[[int], dict]] = None) -> _SwarmBase:
    """Build a swarm with the requested engine ("batched" | "sequential")."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (known: {sorted(ENGINES)})")
    if batched_data_fn is not None:
        if engine != "batched":
            raise ValueError("batched_data_fn requires engine='batched'")
        return Swarm(loss_fn, params, optimizer, nodes, cfg, data_fn,
                     batched_data_fn=batched_data_fn)
    return ENGINES[engine](loss_fn, params, optimizer, nodes, cfg, data_fn)
