"""Economy engine (paper §4): stake markets, Sybil pressure, and adaptive
adversaries as campaign axes.

The paper's incentive claim — rational participation sustains protocol
training — is a *dynamical* question the passive :class:`~repro.core.ledger.
Ledger` cannot answer: whether a fee/reward schedule keeps honest capital in
the swarm depends on admission, slashing, and attacker strategy interacting
over rounds.  This module makes the economy a **device-resident state**
threaded through the scanned round exactly like ``contrib``/``slashed`` are
(see ``core.swarm``), so an entire incentive phase diagram — identity cost ×
fee × reward schedule × coalition × seed — compiles to one ``jit(vmap(scan))``
program.  Three coupled pieces:

1. **Stake-weighted admission with Sybil pressure.**  Every identity costs
   ``identity_cost`` (sunk — the PoW-gated gossip admission of the SNIPPETS
   exemplar, priced in capital instead of hashes) plus a ``min_stake`` bond.
   The adversary holds one fixed ``budget``: how many identities it buys is
   *derived in-program* (``init_econ_state``), and the per-round admission
   mask is derived from live stakes (``admitted_mask``) — a node whose stake
   is drained or slashed below the bond drops out of aggregation, audits,
   and minting.  Cheap identities buy a *count* majority (breaks robust
   aggregation); expensive identities force few-but-fat stakes (a *stake*
   majority — captures the fee market instead).

2. **Fee and reward schedules.**  Each round mints ``reward_rate × speed``
   into a 1-round *pending* escrow (forfeited if the earner is caught —
   the ledger's "forfeits pending shares" made mechanical), splits a fixed
   per-round inference-fee inflow pro-rata by stake over kept nodes (the
   device twin of ``Ledger.distribute_fees``), slashes caught stakes into a
   pool, pays validator jackpots *from that pool* (never minted — the same
   conservation fix ``Ledger.pay_jackpot`` applies), and drains per-round
   operating costs from balance-then-stake.  A node that cannot cover its
   cost exits for good (``alive`` drops) — the death spiral is absorbing.
   The whole flow satisfies one conservation identity, checked on device by
   :func:`conservation_gap`.

3. **Adaptive adversaries.**  ``adaptive=1`` lanes replace the coalition's
   fixed behaviour with a best response: each round the coalition scores a
   static menu of attack scales (``ADAPTIVE_SCALES``) against the *known*
   aggregator — the same masked aggregator the round will apply, evaluated
   on the anticipated active mask — and submits the scale that pushes the
   aggregate hardest against the honest descent direction.  It is one
   traced computation (like the audit recompute), so fixed and adaptive
   lanes live in the same compiled program and the fixed-vs-adaptive gap
   is itself a phase-diagram axis.

Layering: this top half is pure (jax + numpy only) and is imported by
``core.swarm``; everything below the "host-side drivers" line imports swarm
lazily, so the module also hosts the readable :class:`SequentialEconomy`
oracle and the :class:`EconomyResult` phase-table summary without an import
cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_EPS = 1e-9

#: The adaptive coalition's static strategy menu: candidate inner-product
#: attack scales scored in-program each round.  Spans "hide inside the
#: clipping radius" (0.5) to "overwhelm a mean" (32) — well separated so the
#: per-round argmax is stable across engines/float orderings.
ADAPTIVE_SCALES: Tuple[float, ...] = (0.5, 2.0, 8.0, 32.0)

#: Lane outcomes, in classification priority order (capture trumps collapse).
OUTCOMES = ("captured", "death_spiral", "sustained")


class EconParams(NamedTuple):
    """Per-lane traced economy knobs (rides ``LaneParams.econ``).

    All scalar fields are () f32 arrays (``adaptive`` is () int32), so a
    campaign sweeps every knob as lane data inside one compiled program;
    ``coalition`` is the (N,) bool mask of strategic (adversary) slots."""
    identity_cost: Array   # () f32 sunk capital per admitted identity
    budget: Array          # () f32 total adversary capital (buys identities)
    min_stake: Array       # () f32 admission bond
    fee_income: Array      # () f32 inference-fee inflow per round (total)
    reward_rate: Array     # () f32 shares minted per unit speed per kept round
    op_cost: Array         # () f32 per-round operating cost per unit speed
    jackpot: Array         # () f32 validator payout per catch (pool-capped)
    honest_reserve: Array  # () f32 starting balance per honest node
    adaptive: Array        # () int32 — 1: coalition best-responds each round
    coalition: Array       # (N,) bool strategic-identity mask


class EconState(NamedTuple):
    """Device-resident economic state — the scanned carry's ``econ`` slot.

    Conservation identity (checked by :func:`conservation_gap`)::

        capital_in.sum() + minted + fees_in
          == stake.sum() + balance.sum() + pending.sum()
             + slash_pool + validator_income + burned
    """
    stake: Array             # (N,) f32 admission bonds at risk
    balance: Array           # (N,) f32 spendable shares/capital
    pending: Array           # (N,) f32 reward escrow (vests next round)
    capital_in: Array        # (N,) f32 external capital each node brought in
    alive: Array             # (N,) bool — funded at entry, solvent since
    minted: Array            # () f32 cumulative reward issuance
    fees_in: Array           # () f32 cumulative fee inflow
    burned: Array            # () f32 sunk identity costs + op costs + forfeits
    slash_pool: Array        # () f32 slashed stake not yet paid as jackpots
    validator_income: Array  # () f32 jackpots paid (from the pool)


def init_econ_state(econ: EconParams, n_nodes: int) -> EconState:
    """Traced initial economy: the Sybil-pressure knob resolved in-program.

    Honest slots each post the bond, sink the identity cost, and hold a
    ``honest_reserve`` float.  Coalition slots share one ``budget``: the
    first ``k = min(floor(budget / (identity_cost + min_stake)), |coalition|)``
    slots are funded (bond + identity cost), the leftover budget tops up
    their stakes equally (expensive identities ⇒ few-but-fat stakes), and
    unfunded slots are born dead — they never pass admission.  Capital that
    buys nothing stays off the books (``capital_in`` counts only what
    entered), so the conservation identity holds from round 0."""
    coal = econ.coalition
    fcoal = coal.astype(jnp.float32)
    n_coal = jnp.sum(fcoal)
    per_identity = econ.identity_cost + econ.min_stake
    n_afford = jnp.floor(econ.budget / jnp.maximum(per_identity, _EPS))
    k = jnp.minimum(n_afford, n_coal)
    # 0-based index of each slot within the coalition (garbage elsewhere —
    # masked by ``coal`` before use)
    rank = jnp.cumsum(fcoal) - 1.0
    funded = coal & (rank < k)
    leftover = jnp.maximum(econ.budget - k * per_identity, 0.0)
    top_up = leftover / jnp.maximum(k, 1.0)
    ffunded = funded.astype(jnp.float32)
    stake = jnp.where(coal, ffunded * (econ.min_stake + top_up),
                      econ.min_stake)
    sunk = jnp.where(coal, ffunded * econ.identity_cost, econ.identity_cost)
    balance = jnp.where(coal, 0.0, econ.honest_reserve)
    # distinct zero buffers per scalar — the state is donated through the
    # scanned run, and donation rejects the same buffer appearing twice
    zero = lambda: jnp.zeros((), jnp.float32)
    return EconState(
        stake=stake, balance=balance,
        pending=jnp.zeros((n_nodes,), jnp.float32),
        capital_in=stake + sunk + balance,
        alive=funded | ~coal,
        minted=zero(), fees_in=zero(), burned=jnp.sum(sunk),
        slash_pool=zero(), validator_income=zero())


def admitted_mask(econ: EconParams, state: EconState) -> Array:
    """(N,) bool — who participates this round: alive (funded at entry,
    never insolvent) and still posting the full bond.  Derived from live
    stakes, so slashing or cost-drain below ``min_stake`` de-admits
    in-program."""
    return state.alive & (state.stake >= econ.min_stake)


def econ_round_update(econ: EconParams, state: EconState, *, active: Array,
                      keep: Array, caught: Array, speeds: Array) -> EconState:
    """One round of the economy, applied after the audit verdicts.

    Order matters and mirrors the ledger: (1) caught nodes forfeit their
    pending escrow (burned), everyone else vests it; (2) this round's
    rewards are minted into escrow for kept nodes; (3) the fee inflow is
    split pro-rata by stake over kept nodes (no inflow when nobody kept);
    (4) caught stakes are slashed into the pool; (5) jackpots are paid from
    the pool, capped by it; (6) operating costs drain balance first, then
    stake — a node that cannot cover its cost exits for good."""
    f32 = lambda m: m.astype(jnp.float32)
    kept, lost, act = f32(keep), f32(caught), f32(active)

    # (1) escrow: forfeit or vest
    forfeited = jnp.sum(state.pending * lost)
    balance = state.balance + state.pending * (1.0 - lost)
    # (2) mint this round's rewards into escrow
    pending = econ.reward_rate * speeds * kept
    minted = state.minted + jnp.sum(pending)
    # (3) fee market: stake-weighted split over kept nodes
    kept_stake = state.stake * kept
    tot_stake = jnp.sum(kept_stake)
    any_kept = tot_stake > 0.0
    balance = balance + jnp.where(
        any_kept, econ.fee_income * kept_stake / jnp.maximum(tot_stake, _EPS),
        0.0)
    fees_in = state.fees_in + jnp.where(any_kept, econ.fee_income, 0.0)
    # (4) slash caught stakes into the pool
    slash_pool = state.slash_pool + jnp.sum(state.stake * lost)
    stake = state.stake * (1.0 - lost)
    # (5) jackpots, funded from (and capped by) the pool
    jackpot_due = econ.jackpot * jnp.sum(lost)
    jackpot_paid = jnp.minimum(jackpot_due, slash_pool)
    slash_pool = slash_pool - jackpot_paid
    validator_income = state.validator_income + jackpot_paid
    # (6) operating costs: balance first, then stake; insolvency is final
    cost = econ.op_cost * speeds * act
    afford = balance + stake
    paid = jnp.minimum(cost, afford)
    from_balance = jnp.minimum(cost, balance)
    balance = balance - from_balance
    stake = stake - (paid - from_balance)
    alive = state.alive & ~(active & (cost > afford + 1e-6))
    burned = state.burned + forfeited + jnp.sum(paid)
    return EconState(
        stake=stake, balance=balance, pending=pending,
        capital_in=state.capital_in, alive=alive, minted=minted,
        fees_in=fees_in, burned=burned, slash_pool=slash_pool,
        validator_income=validator_income)


def conservation_gap(state: EconState) -> Array:
    """() f32 — |inflows − holdings| for the conservation identity in the
    :class:`EconState` docstring.  Traced (usable inside a program); ~1e-4
    relative is f32 reduction noise, anything larger is a real leak."""
    inflow = jnp.sum(state.capital_in) + state.minted + state.fees_in
    held = (jnp.sum(state.stake) + jnp.sum(state.balance)
            + jnp.sum(state.pending) + state.slash_pool
            + state.validator_income + state.burned)
    return jnp.abs(inflow - held)


def payoff(state: EconState) -> Array:
    """(N,) f32 — each node's economic return to date: what it could walk
    away with (balance + stake + escrow) minus what it brought in."""
    return state.balance + state.stake + state.pending - state.capital_in


def best_response_scale(run_ref_agg, gf: Array, honest_mean: Array,
                        coalition_active: Array, anticipated_mask: Array,
                        scales: Sequence[float] = ADAPTIVE_SCALES) -> Array:
    """The adaptive coalition's in-program inner step: score each candidate
    inner-product attack scale against the known aggregator and return the
    winner (a () f32).

    ``run_ref_agg(stack, mask)`` must be the round's *reference* masked
    aggregator (the attacker's model of the defense — ``core.swarm`` passes
    the same routed aggregator set the round applies).  A candidate's score
    is how hard the anticipated aggregate opposes the honest descent
    direction when every active coalition slot submits ``-s·honest_mean``;
    the candidates are a static menu, so this is a fixed-size traced
    computation — no data-dependent control flow enters the scan."""
    def score(s):
        stack = jnp.where(coalition_active[:, None],
                          -s * honest_mean[None, :], gf)
        agg = run_ref_agg(stack, anticipated_mask)
        return -jnp.vdot(agg, honest_mean)

    scores = jnp.stack([score(s) for s in scales])
    return jnp.asarray(scales, jnp.float32)[jnp.argmax(scores)]


# ----------------------------- host-side spec ----------------------------------
@dataclass(frozen=True)
class EconomyConfig:
    """Host-side economy spec (``SwarmConfig.economy`` / sweep plumbing) —
    plain floats, turned into a traced :class:`EconParams` per lane by
    :meth:`params_for`.  ``coalition=None`` defaults to the roster's
    byzantine slots (the behaviour-code attackers ARE the strategic
    capital)."""
    identity_cost: float = 1.0
    budget: float = 50.0
    min_stake: float = 5.0
    fee_income: float = 1.0
    reward_rate: float = 0.1
    op_cost: float = 0.05
    jackpot: float = 5.0
    honest_reserve: float = 1.0
    adaptive: bool = False

    def params_for(self, coalition: np.ndarray) -> EconParams:
        f = lambda x: jnp.asarray(x, jnp.float32)
        return EconParams(
            identity_cost=f(self.identity_cost), budget=f(self.budget),
            min_stake=f(self.min_stake), fee_income=f(self.fee_income),
            reward_rate=f(self.reward_rate), op_cost=f(self.op_cost),
            jackpot=f(self.jackpot), honest_reserve=f(self.honest_reserve),
            adaptive=jnp.asarray(1 if self.adaptive else 0, jnp.int32),
            coalition=jnp.asarray(np.asarray(coalition, bool)))


def classify_outcome(*, honest_active_first: int, honest_active_last: int,
                     coalition_stake_last: float, honest_payoff_mean: float,
                     capture_threshold: float = 0.5) -> str:
    """Host classification of one lane, in :data:`OUTCOMES` priority order.

    - ``captured``: the coalition ends holding ≥ ``capture_threshold`` of
      the active stake — it owns the fee market (and, at count majority,
      the aggregate) regardless of how training went;
    - ``death_spiral``: honest participation collapsed below half its
      starting level, or honest capital ends under water — rational nodes
      would not have stayed;
    - ``sustained``: neither — the schedule retains honest capital."""
    if coalition_stake_last >= capture_threshold:
        return "captured"
    if (honest_active_last < 0.5 * honest_active_first
            or honest_payoff_mean < 0.0):
        return "death_spiral"
    return "sustained"


# ========================== host-side drivers ==================================
# Everything below imports core.swarm lazily — swarm imports this module's
# top half, and these drivers close the loop without a cycle.

@dataclass(frozen=True)
class EconomyResult:
    """One lane of an incentive phase diagram (see ``derailment.sweep`` /
    :func:`summarize_sweep`): the economy axes, the outcome, and the
    payoffs that justify it."""
    regime: str
    identity_cost: float
    fee: float
    reward_rate: float
    jackpot: float
    adaptive: bool
    coalition_size: int
    seed: int
    outcome: str                  # captured | death_spiral | sustained
    honest_payoff: float          # mean over honest slots
    coalition_payoff: float       # mean over coalition slots (0 if none)
    coalition_stake_share: float  # final share of active stake
    n_admitted_first: int
    n_admitted_last: int
    final_loss: float


def phase_table(results: Sequence[EconomyResult], *, regime: str,
                adaptive: bool = False) -> str:
    """Render the sustained/death-spiral/captured table over
    (identity_cost rows × fee columns) for one regime, majority-voting
    over seeds and reward schedules (S=sustained, D=death_spiral,
    C=captured, lowercase = split vote)."""
    rs = [r for r in results if r.regime == regime and r.adaptive == adaptive
          and r.coalition_size > 0]
    costs = sorted({r.identity_cost for r in rs})
    fees = sorted({r.fee for r in rs})
    lines = ["cost\\fee  " + "  ".join(f"{f:>7g}" for f in fees)]
    for c in costs:
        cells = []
        for f in fees:
            outs = [r.outcome for r in rs
                    if r.identity_cost == c and r.fee == f]
            if not outs:
                cells.append("      .")
                continue
            top = max(set(outs), key=outs.count)
            ch = top[0].upper()
            cells.append(f"{ch if outs.count(top) == len(outs) else ch.lower():>7}")
        lines.append(f"{c:<9g}" + "  ".join(cells))
    return "\n".join(lines)


def adaptive_gap(results: Sequence[EconomyResult]) -> Dict[str, float]:
    """The fixed-vs-adaptive phase-diagram gap: over (regime, cost, fee,
    schedule, seed) cells present in both halves, how much worse the
    adaptive coalition makes things — the shift in non-sustained area, in
    mean honest payoff, and in training damage (``loss_ratio`` is the
    median per-cell adaptive/fixed final-loss ratio: > 1 means the
    best-response coalition hurts training where the fixed-scale attack
    could not — the measurable adaptivity gap)."""
    def key(r):
        return (r.regime, r.identity_cost, r.fee, r.reward_rate, r.jackpot,
                r.coalition_size, r.seed)
    fixed = {key(r): r for r in results
             if not r.adaptive and r.coalition_size > 0}
    adapt = {key(r): r for r in results
             if r.adaptive and r.coalition_size > 0}
    common = sorted(set(fixed) & set(adapt))
    if not common:
        return {"cells": 0, "bad_frac_fixed": 0.0, "bad_frac_adaptive": 0.0,
                "gap": 0.0, "honest_payoff_drop": 0.0, "loss_ratio": 1.0}
    bad = lambda r: r.outcome != "sustained"
    bf = sum(bad(fixed[k]) for k in common) / len(common)
    ba = sum(bad(adapt[k]) for k in common) / len(common)
    drop = (sum(fixed[k].honest_payoff - adapt[k].honest_payoff
                for k in common) / len(common))
    ratios = sorted(adapt[k].final_loss / max(fixed[k].final_loss, 1e-9)
                    for k in common)
    return {"cells": len(common), "bad_frac_fixed": bf,
            "bad_frac_adaptive": ba, "gap": ba - bf,
            "honest_payoff_drop": drop,
            "loss_ratio": ratios[len(ratios) // 2]}


class SequentialEconomy:
    """The readable per-node host oracle for the economy round — the
    ``SequentialSwarm``-style reference the batched engine is pinned
    against (tests/test_economy.py).

    A plain Python loop over nodes with explicit if/else bookkeeping:
    admission checks, escrow vesting, fee splits, pool-funded jackpots,
    and cost drains all happen in host float32, drawing every random
    number from the *same* ``(seed, purpose, round, node)`` fold_in
    schedule as the batched engine.  Centralized, unfused rounds only —
    it is an oracle, not an engine."""

    def __init__(self, loss_fn, params, optimizer, nodes, cfg, data_fn):
        from repro.core import swarm as _swarm
        if cfg.topology is not None or cfg.staleness_bound:
            raise ValueError("the economy oracle is centralized+synchronous")
        if cfg.economy is None:
            raise ValueError("SequentialEconomy needs SwarmConfig.economy")
        self._swarm = _swarm
        self.loss_fn, self.params = loss_fn, params
        self.optimizer, self.opt_state = optimizer, optimizer.init(params)
        self.nodes, self.cfg, self.data_fn = list(nodes), cfg, data_fn
        self._grad = jax.jit(jax.grad(loss_fn))
        self._flat_shapes = None
        self.slashed = np.zeros(len(self.nodes), bool)
        self.history: List[dict] = []
        self._base_key = jax.random.PRNGKey(cfg.seed)
        coalition = np.asarray([n.byzantine is not None for n in self.nodes])
        self.econ_params = cfg.economy.params_for(coalition)
        self.econ = jax.tree.map(np.asarray,
                                 init_econ_state(self.econ_params,
                                                 len(self.nodes)))
        from repro.core import aggregation
        self._agg = aggregation.get_masked_aggregator(cfg.aggregator,
                                                      **cfg.agg_kwargs)

    def _flatten(self, tree):
        leaves = jax.tree.leaves(tree)
        if self._flat_shapes is None:
            self._flat_shapes = [(l.shape, l.dtype) for l in leaves]
            self._treedef = jax.tree.structure(tree)
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                for l in leaves])

    def _unflatten(self, vec):
        out, off = [], 0
        for shape, dtype in self._flat_shapes:
            size = int(np.prod(shape)) if shape else 1
            out.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self._treedef, out)

    def step(self, rnd: int) -> dict:
        sw, cfg, ep = self._swarm, self.cfg, self.econ_params
        n = len(self.nodes)
        econ = self.econ
        key = self._base_key

        # -- admission: roster-active ∧ not slashed ∧ alive ∧ bonded -----------
        min_stake = float(ep.min_stake)
        active = np.zeros(n, bool)
        for i, node in enumerate(self.nodes):
            active[i] = (node.active(rnd) and not self.slashed[i]
                         and bool(econ.alive[i])
                         and econ.stake[i] >= min_stake)

        # -- gradients (each node, its own batch) ------------------------------
        gfs = [None] * n
        for i in np.flatnonzero(active):
            gfs[i] = self._flatten(self._grad(self.params,
                                              self.data_fn(int(i), rnd)))
        acts = [gfs[i] for i in np.flatnonzero(active)]
        honest_mean = (jnp.mean(jnp.stack(acts), axis=0) if acts
                       else jnp.zeros_like(self._flatten(self.params)))

        # -- corruption: fixed behaviours, or the best-response inner step -----
        from repro.core import compression
        coalition = np.asarray(jax.device_get(ep.coalition), bool)
        submitted, wire_keys = dict(), dict()
        adaptive = int(ep.adaptive) > 0
        chosen_scale = None
        if adaptive and acts:
            coal_act = jnp.asarray(coalition & active)
            stack = jnp.stack([gfs[i] if active[i]
                               else jnp.zeros_like(honest_mean)
                               for i in range(n)])
            chosen_scale = float(best_response_scale(
                self._agg, stack, honest_mean, coal_act,
                jnp.asarray(active)))
        for i in np.flatnonzero(active):
            node, gf = self.nodes[int(i)], gfs[i]
            if coalition[i]:
                if adaptive:
                    gf = -chosen_scale * honest_mean
                elif node.byzantine:
                    gf = sw.corrupt(node.byzantine, gf, honest_mean,
                                    node.byzantine_scale,
                                    sw._node_key(key, sw._CORRUPT, rnd, int(i)))
            wk = sw._node_key(key, sw._WIRE, rnd, int(i))
            wire_keys[int(i)] = wk
            submitted[int(i)] = compression.roundtrip(
                cfg.compression, wk, gf, **cfg.compression_kwargs)

        # -- audits (§4.2) ------------------------------------------------------
        from repro.core.verification import audit_flat
        caught = np.zeros(n, bool)
        if cfg.verification:
            v = cfg.verification
            for i in np.flatnonzero(active):
                sel = jax.random.uniform(
                    sw._node_key(key, sw._AUDIT_SEL, rnd, int(i)))
                if float(sel) >= v.p_check:
                    continue
                recomputed = compression.roundtrip(
                    cfg.compression, wire_keys[int(i)], gfs[i],
                    **cfg.compression_kwargs)
                ok, _ = audit_flat(
                    submitted[int(i)], recomputed,
                    sw._node_key(key, sw._AUDIT_NOISE, rnd, int(i)), v)
                if not ok:
                    caught[i] = True
                    self.slashed[i] = True
        keep = active & ~caught

        # -- aggregate + update (masked, same fn as the batched round) ---------
        if keep.any():
            stack = jnp.stack([submitted.get(int(i), jnp.zeros_like(honest_mean))
                               for i in range(n)])
            agg = self._agg(stack, jnp.asarray(keep))
            self.params, self.opt_state = self.optimizer.update(
                self._unflatten(agg), self.opt_state, self.params)
        else:
            agg = jnp.zeros_like(honest_mean)

        # -- the economy round, in explicit host arithmetic --------------------
        f32 = np.float32
        stake = np.asarray(econ.stake, f32).copy()
        balance = np.asarray(econ.balance, f32).copy()
        pending = np.asarray(econ.pending, f32).copy()
        alive = np.asarray(econ.alive, bool).copy()
        minted, fees_in = f32(econ.minted), f32(econ.fees_in)
        burned, pool = f32(econ.burned), f32(econ.slash_pool)
        validator = f32(econ.validator_income)
        speeds = np.asarray([nd.speed for nd in self.nodes], f32)
        # (1) escrow: forfeit if caught, vest otherwise
        for i in range(n):
            if caught[i]:
                burned = f32(burned + pending[i])
            else:
                balance[i] = f32(balance[i] + pending[i])
            pending[i] = f32(0.0)
        # (2) mint this round's rewards into escrow
        for i in np.flatnonzero(keep):
            pending[i] = f32(f32(ep.reward_rate) * speeds[i])
            minted = f32(minted + pending[i])
        # (3) fee split pro-rata by stake over kept nodes
        tot_stake = f32(sum(stake[i] for i in np.flatnonzero(keep)))
        if tot_stake > 0:
            for i in np.flatnonzero(keep):
                balance[i] = f32(balance[i] + f32(ep.fee_income)
                                 * f32(stake[i] / tot_stake))
            fees_in = f32(fees_in + f32(ep.fee_income))
        # (4) slash caught stakes into the pool
        for i in np.flatnonzero(caught):
            pool = f32(pool + stake[i])
            stake[i] = f32(0.0)
        # (5) jackpots from the pool, capped by it
        due = f32(f32(ep.jackpot) * caught.sum())
        paid_jackpot = min(due, pool)
        pool = f32(pool - paid_jackpot)
        validator = f32(validator + paid_jackpot)
        # (6) operating costs: balance, then stake; insolvency is final
        for i in np.flatnonzero(active):
            cost = f32(f32(ep.op_cost) * speeds[i])
            afford = f32(balance[i] + stake[i])
            if cost > afford + 1e-6:
                alive[i] = False
            paid = min(cost, afford)
            from_bal = min(cost, balance[i])
            balance[i] = f32(balance[i] - from_bal)
            stake[i] = f32(stake[i] - f32(paid - from_bal))
            burned = f32(burned + paid)
        self.econ = EconState(
            stake=stake, balance=balance, pending=pending,
            capital_in=np.asarray(econ.capital_in, f32), alive=alive,
            minted=minted, fees_in=fees_in, burned=burned, slash_pool=pool,
            validator_income=validator)

        act_stake = float((stake * keep).sum())
        coal_stake = float((stake * (keep & coalition)).sum())
        rec = {
            "round": rnd, "n_active": int(active.sum()),
            "n_byzantine": int((active & coalition).sum()),
            "caught": [self.nodes[int(i)].node_id
                       for i in np.flatnonzero(caught)],
            "keep": keep.copy(), "admitted": active.copy(),
            "agg_norm": float(jnp.linalg.norm(agg)),
            "coalition_stake": coal_stake / act_stake if act_stake > 0 else 0.0,
            "chosen_scale": chosen_scale,
        }
        self.history.append(rec)
        return rec

    def run(self, rounds: int) -> List[dict]:
        return [self.step(r) for r in range(rounds)]


def ledger_view(econ: EconState, node_ids: Sequence[str],
                validator: str = "validator"):
    """Project a final device :class:`EconState` onto the host
    :class:`~repro.core.ledger.Ledger` vocabulary: balances (vested +
    escrow), stakes, pools — so ledger-level invariants (`can_infer`,
    conservation) can be asserted against engine output."""
    from repro.core.ledger import Ledger
    led = Ledger()
    stake = np.asarray(econ.stake, np.float64)
    balance = np.asarray(econ.balance, np.float64)
    pending = np.asarray(econ.pending, np.float64)
    capital = np.asarray(econ.capital_in, np.float64)
    for i, nid in enumerate(node_ids):
        if capital[i] > 0:
            led.stake(nid, float(capital[i]))
            # capital beyond the live stake has been spent or re-classed:
            # move it out of the stake bucket into balance/burn mirrors
            led.stakes[nid] = float(stake[i])
        if balance[i] + pending[i] > 0:
            led.balances[nid] = float(balance[i] + pending[i])
    led.balances[validator] = float(econ.validator_income)
    led.slash_pool = float(econ.slash_pool)
    led.fee_pool = 0.0
    led.burned = float(econ.burned)
    # mint events so check_conservation's inflow side matches: rewards and
    # fees entered the economy as issuance, not staked capital
    led.history.append(("mint", "rewards", float(econ.minted)))
    led.history.append(("mint", "fees", float(econ.fees_in)))
    return led
