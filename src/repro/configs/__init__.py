"""Config registry: ``get_config("mixtral-8x7b")`` / ``--arch mixtral-8x7b``."""
from __future__ import annotations

from repro.configs.base import (
    AUDIO,
    DENSE,
    FAMILIES,
    HYBRID,
    INPUT_SHAPES,
    MOE,
    SSM,
    VLM,
    ModelConfig,
    ShapeConfig,
)

from repro.configs.stablelm_3b import CONFIG as _stablelm_3b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.h2o_danube_1_8b import CONFIG as _h2o_danube
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.protocol_125m import CONFIG as _protocol_125m

REGISTRY = {
    c.name: c
    for c in (
        _stablelm_3b,
        _mixtral_8x7b,
        _h2o_danube,
        _zamba2,
        _rwkv6,
        _qwen2_vl,
        _granite,
        _tinyllama,
        _qwen3_moe,
        _seamless,
        _protocol_125m,
    )
}

ASSIGNED_ARCHS = [n for n in REGISTRY if n != "protocol-125m"]


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}") from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}") from None


def applicable_shapes(cfg: ModelConfig) -> list:
    """The assigned input shapes this architecture runs (DESIGN.md §3)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_decode:
        out.append("long_500k")
    return out


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "REGISTRY",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "get_config",
    "get_shape",
    "applicable_shapes",
    "DENSE",
    "MOE",
    "HYBRID",
    "SSM",
    "VLM",
    "AUDIO",
    "FAMILIES",
]
