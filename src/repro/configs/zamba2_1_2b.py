"""zamba2-1.2b — hybrid 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Mamba2 backbone with a shared attention block applied between
groups of mamba layers.

[arXiv:2411.15242]
"""
from repro.configs.base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family=HYBRID,
    source="arXiv:2411.15242",
    num_layers=38,            # mamba2 layers
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state_size=64,
    ssm_head_dim=64,
    ssm_expand=2,
    mamba_per_group=6,        # shared attn block after every 6 mamba layers
)
