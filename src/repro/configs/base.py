"""Model / run configuration for the Protocol Learning framework.

One ``ModelConfig`` describes any architecture in the assigned pool (dense,
MoE, SSM, hybrid, VLM backbone, audio enc-dec backbone).  Configs are plain
frozen dataclasses — no I/O, no jax imports — so importing a config never
touches device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Architecture families ------------------------------------------------------
DENSE = "dense"          # decoder-only transformer (GQA, optionally SWA)
MOE = "moe"              # decoder-only transformer with MoE FFN
HYBRID = "hybrid"        # Mamba2 blocks + shared attention blocks (zamba2)
SSM = "ssm"              # attention-free recurrent (rwkv6)
VLM = "vlm"              # decoder-only transformer consuming patch embeddings (M-RoPE)
AUDIO = "audio"          # encoder-decoder consuming frame embeddings (seamless)

FAMILIES = (DENSE, MOE, HYBRID, SSM, VLM, AUDIO)


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str
    source: str = ""                    # citation for the architecture

    # core transformer dims
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4               # GQA; ==1 is MQA
    head_dim: int = 0                   # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    tie_embeddings: bool = False

    # attention variants
    sliding_window: Optional[int] = None   # SWA window (tokens); None = full attention
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE (t, h, w)

    # MoE
    num_experts: int = 0                # 0 = dense FFN
    experts_per_token: int = 0          # top-k
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01

    # SSM / Mamba2 (hybrid + zamba2)
    ssm_state_size: int = 0             # d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    mamba_per_group: int = 6            # zamba2: mamba layers per shared-attn block

    # RWKV6
    rwkv_head_dim: int = 64

    # enc-dec (audio)
    num_encoder_layers: int = 0         # >0 -> encoder-decoder
    encoder_frames: int = 4096          # fixed encoder memory length at decode

    # multimodal stubs
    num_media_tokens: int = 0           # VLM: patch embeddings prepended (train/prefill)

    # normalization / misc
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"             # activations/params compute dtype

    # Pallas kernel compute paths (INFERENCE-ONLY: the kernels define no
    # custom VJP, so jax.grad through them fails — the training path keeps
    # the pure-jnp twins).  On CPU the kernels run in interpret mode.
    use_pallas_kernels: bool = False

    # training
    max_seq_len: int = 4096
    xent_chunk: int = 512               # sequence-chunked cross entropy

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def supports_long_decode(self) -> bool:
        """True if decode cost/state is sub-quadratic in context length."""
        if self.family in (SSM, HYBRID):
            return True
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (matches the model zoo's actual trees)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            return d * hd * n_q + 2 * d * hd * n_kv + hd * n_q * d

        def dense_ffn() -> int:
            return 3 * d * self.d_ff          # SwiGLU: gate, up, down

        def moe_ffn() -> int:
            return self.num_experts * 3 * d * self.d_ff + d * self.num_experts

        def mamba_block() -> int:
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            in_proj = d * (2 * d_in + 2 * self.ssm_state_size + nheads)
            conv = self.ssm_conv_width * (d_in + 2 * self.ssm_state_size)
            out = d_in * d
            return in_proj + conv + out + 2 * nheads  # + A, D

        def rwkv_block() -> int:
            # time-mix (r,k,v,g,w,o) + lora decay + channel-mix (k,r,v)
            tm = 5 * d * d + d * d            # r,k,v,g,o + w low-rank approx as full
            cm = d * self.d_ff * 2 + self.d_ff * 0 + d * self.d_ff
            return tm + cm

        norms = 2 * d
        if self.family in (DENSE, VLM):
            per_layer = attn_params() + dense_ffn() + norms
            total = emb + self.num_layers * per_layer + d
        elif self.family == MOE:
            per_layer = attn_params() + moe_ffn() + norms
            total = emb + self.num_layers * per_layer + d
        elif self.family == HYBRID:
            n_groups = self.num_layers // self.mamba_per_group
            total = (emb + self.num_layers * (mamba_block() + d)
                     + (attn_params() + dense_ffn() + norms)  # one shared block
                     + n_groups * 0 + d)
        elif self.family == SSM:
            total = emb + self.num_layers * (rwkv_block() + norms) + d
        elif self.family == AUDIO:
            dec = self.num_layers * (2 * attn_params() + dense_ffn() + 3 * d)
            enc = self.num_encoder_layers * (attn_params() + dense_ffn() + norms)
            total = emb + enc + dec + d
        else:
            raise ValueError(self.family)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != MOE:
            return self.param_count()
        full = self.param_count()
        ffn_all = self.num_layers * self.num_experts * 3 * self.d_model * self.d_ff
        ffn_active = self.num_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return int(full - ffn_all + ffn_active)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            dtype="float32",
            num_layers=2,
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            max_seq_len=128,
            xent_chunk=64,
            encoder_frames=32,
        )
        if self.num_experts:
            small.update(num_experts=4, experts_per_token=min(2, self.experts_per_token))
        if self.ssm_state_size:
            small.update(ssm_state_size=16, ssm_head_dim=32, mamba_per_group=1)
        if self.family == SSM:
            small.update(rwkv_head_dim=32, d_ff=256)
        if self.num_encoder_layers:
            small.update(num_encoder_layers=2)
        if self.sliding_window:
            small.update(sliding_window=32)
        if self.num_media_tokens:
            small.update(num_media_tokens=8)
        if self.mrope_sections:
            small.update(mrope_sections=(8, 4, 4))  # sums to head_dim//2 = 16
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
