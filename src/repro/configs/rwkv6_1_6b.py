"""rwkv6-1.6b (Finch) — attention-free 24L d_model=2048 d_ff=7168 vocab=65536,
data-dependent decay WKV recurrence.

[arXiv:2404.05892]
"""
from repro.configs.base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family=SSM,
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=32,             # wkv heads = d_model // rwkv_head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
)
