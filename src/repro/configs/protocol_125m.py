"""protocol-125m — the paper's own end-to-end demonstrator: a ~125M dense
model trained across a simulated incentivized swarm (examples/
swarm_byzantine_training.py).  Sized so a few hundred steps run on CPU.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="protocol-125m",
    family=DENSE,
    source="this paper (Protocol Learning demonstrator)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    max_seq_len=1024,
)
