"""seamless-m4t-medium — audio enc-dec backbone, 12L enc + 12L dec,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  The mel-spectrogram /
conv feature-extractor frontend is STUBBED per the assignment: input_specs
provides precomputed frame embeddings.

[arXiv:2308.11596]
"""
from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=AUDIO,
    source="arXiv:2308.11596",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    encoder_frames=4096,
)
