"""qwen2-vl-2b — VLM backbone 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE (t/h/w sections), dynamic-resolution vision frontend
STUBBED per the assignment (input_specs provides patch embeddings).

[arXiv:2409.12191]
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=VLM,
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope_sections=(16, 24, 24),   # sums to head_dim // 2 = 64
    num_media_tokens=256,
    rope_theta=1e6,
)
