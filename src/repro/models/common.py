"""Shared building blocks for the model zoo (pure JAX, functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# -- initialisation ----------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# -- norms -------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# -- RoPE --------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (..., S, hd/2)
    angles = angles[..., None, :]                                    # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float, sections) -> Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (3, B, S) — (temporal, height, width) ids.
    ``sections`` partitions the hd/2 rotary frequencies into (t, h, w) groups;
    each group rotates by its own position id. [arXiv:2409.12191]
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)          # (hd/2,)
    # angle per section: pick which of the 3 position streams drives each freq
    sec_id = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos_sel = positions[sec_id]                                      # (hd/2, B, S)
    angles = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32) * freqs  # (B, S, hd/2)
    angles = angles[..., None, :]                                    # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- FFN ---------------------------------------------------------------------
def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def init_swiglu(key, d: int, f: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype),
    }


# -- losses ------------------------------------------------------------------
def chunked_softmax_xent(
    h: Array,            # (B, S, d) final hidden states
    unembed: Array,      # (d, V)
    labels: Array,       # (B, S) int32
    mask: Array,         # (B, S) float — 1 where the label counts
    chunk: int,
) -> Array:
    """Cross-entropy without materializing (B, S, V) logits.

    The sequence axis is processed in chunks under jax.checkpoint so the peak
    live logits tensor is (B, chunk, V).  This is the big-vocab trick that
    makes 151k-vocab training fit (DESIGN.md §4).
    """
    B, S, _ = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_loss(args):
        hc, yc, mc = args
        logits = jnp.einsum("bsd,dv->bsv", hc.astype(jnp.float32), unembed.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mc)

    chunk_loss = jax.checkpoint(chunk_loss)

    hs = h[:, : n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
    ys = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, args):
        return carry + chunk_loss(args), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys, ms))
    if rem:
        total = total + chunk_loss((h[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:]))
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return total / denom


def full_softmax_xent(h, unembed, labels, mask):
    """Reference (materializes logits) — used by tests to validate chunking."""
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32), unembed.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return jnp.sum((logz - gold) * mask) / denom
