"""Decoder-only transformer backbone (dense / MoE / VLM families).

Layers are stacked (leading axis L) and applied with lax.scan so the HLO is
depth-independent; each layer body is rematerialized in the loss path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MOE, VLM, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.common import (
    apply_mrope,
    apply_rope,
    chunked_softmax_xent,
    dense_init,
    dtype_of,
    embed_init,
    rms_norm,
)

Array = jax.Array


# -- parameter init ----------------------------------------------------------
def init_attn(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.num_heads, hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, hd), dtype),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, d), dtype),
    }


def init_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "attn": init_attn(ks[0], cfg, dtype),
        "ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_ffn": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family == MOE:
        p["moe"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, dtype)
    else:
        from repro.models.common import init_swiglu

        p["ffn"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_out, (cfg.d_model, cfg.vocab_size), dtype)
    return params


def unembed_of(params):
    return params.get("unembed", params["embed"].T)


# -- layer application -------------------------------------------------------
def _qkv(layer, cfg, x, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, layer["attn"]["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, layer["attn"]["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, layer["attn"]["wv"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def layer_apply(layer, cfg: ModelConfig, x: Array, positions) -> tuple[Array, Array]:
    """Full-sequence layer.  Returns (x, moe_aux_loss)."""
    h = rms_norm(x, layer["ln_attn"], cfg.norm_eps)
    q, k, v = _qkv(layer, cfg, h, positions)
    o = attn.attention(q, k, v, causal=True, window=cfg.sliding_window,
                       use_pallas=cfg.use_pallas_kernels)
    x = x + jnp.einsum("bshe,hed->bsd", o, layer["attn"]["wo"])

    h = rms_norm(x, layer["ln_ffn"], cfg.norm_eps)
    if cfg.family == MOE:
        f, aux = moe_lib.moe_ffn(
            h, layer["moe"], top_k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor)
    else:
        from repro.models.common import swiglu

        f = swiglu(h, layer["ffn"]["w_gate"], layer["ffn"]["w_up"], layer["ffn"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return x + f, aux


def layer_decode(layer, cfg: ModelConfig, x: Array, kcache, vcache, pos) -> tuple[Array, Array, Array]:
    """One-token layer step.  x: (B, 1, d); kcache/vcache: (B, L, Hkv, hd)."""
    ring = cfg.sliding_window is not None
    h = rms_norm(x, layer["ln_attn"], cfg.norm_eps)
    positions = jnp.full((x.shape[0], 1), pos)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos, (3, x.shape[0], 1))
    q, k, v = _qkv(layer, cfg, h, positions)
    kcache, vcache = attn.cache_insert(kcache, vcache, k, v, pos, ring=ring)
    o = attn.decode_attention(q, kcache, vcache, pos, ring=ring)
    x = x + jnp.einsum("bshe,hed->bsd", o, layer["attn"]["wo"])

    h = rms_norm(x, layer["ln_ffn"], cfg.norm_eps)
    if cfg.family == MOE:
        f, _ = moe_lib.moe_ffn(
            h, layer["moe"], top_k=cfg.experts_per_token,
            capacity_factor=float(cfg.num_experts) / max(cfg.experts_per_token, 1))
    else:
        from repro.models.common import swiglu

        f = swiglu(h, layer["ffn"]["w_gate"], layer["ffn"]["w_up"], layer["ffn"]["w_down"])
    return x + f, kcache, vcache


# -- full model --------------------------------------------------------------
def _positions_for(cfg, batch, seq):
    if cfg.mrope_sections is not None:
        return batch["positions"]                    # (3, B, S) provided (M-RoPE)
    b = batch["tokens"].shape[0]
    return jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))


def embed_inputs(params, cfg: ModelConfig, batch) -> Array:
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.family == VLM and cfg.num_media_tokens:
        media = batch["media"].astype(tok.dtype)     # (B, M, d) stubbed frontend
        tok = jnp.concatenate([media, tok], axis=1)
    return tok


def forward(params, cfg: ModelConfig, batch, *, remat: bool = True):
    """Returns final hidden states (B, S, d) and total moe aux loss."""
    x = embed_inputs(params, cfg, batch)
    positions = _positions_for(cfg, batch, x.shape[1])

    def body(carry, layer):
        x, aux = carry
        x2, a = layer_apply(layer, cfg, x, positions)
        return (x2, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux


def loss_fn(params, cfg: ModelConfig, batch):
    h, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
        if cfg.family == VLM and cfg.num_media_tokens:
            mask = mask.at[:, : cfg.num_media_tokens].set(0.0)
    xent = chunked_softmax_xent(h, unembed_of(params), labels, mask, cfg.xent_chunk)
    return xent + cfg.router_aux_loss_coef * aux, {"xent": xent, "moe_aux": aux}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or dtype_of(cfg)
    lc = attn.cache_length(seq_len, cfg.sliding_window)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, lc, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, tokens: Array, cache):
    """tokens: (B, 1) -> logits (B, 1, V), new cache."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(x, inputs):
        layer, kc, vc = inputs
        x, kc, vc = layer_decode(layer, cfg, x, kc, vc, pos)
        return x, (kc, vc)

    x, (knew, vnew) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        unembed_of(params).astype(jnp.float32))
    return logits, {"k": knew, "v": vnew, "pos": pos + 1}


def prefill(params, cfg: ModelConfig, batch):
    """Teacher-forced full forward returning last-position logits (serving path)."""
    h, _ = forward(params, cfg, batch, remat=False)
    logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                        unembed_of(params).astype(jnp.float32))
    return logits
