"""Attention: GQA with optional sliding window; blockwise-exact prefill and
ring-buffer KV-cache decode.

The model-level implementation is pure jnp and memory-bounded (online-softmax
over KV blocks, never materializing an (S, S) score matrix).  The
perf-critical SWA path has a Pallas kernel twin in ``repro.kernels.swa_attention``
validated against ``ref.py`` == this module's math.

FLOPs note for the roofline: the full-attention path computes all (q, kv)
blocks and masks above the diagonal, so HLO FLOPs count the non-causal 2x —
the same convention as a dense softmax(QK^T)V baseline.  The SWA path is
banded (linear in S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def _grouped(q, hkv):
    """(B, S, Hq, hd) -> (B, S, Hkv, G, hd)."""
    b, s, hq, hd = q.shape
    return q.reshape(b, s, hkv, hq // hkv, hd)


def attention(
    q: Array,              # (B, Sq, Hq, hd)
    k: Array,              # (B, Skv, Hkv, hd)
    v: Array,              # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 1024,
    kv_block: int = 1024,
    use_pallas: bool = False,
) -> Array:
    """Blockwise-exact attention; O(S·w) for sliding window, else O(S²)."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = hd ** -0.5
    qg = _grouped(q, hkv)

    q_block = min(q_block, sq)
    while sq % q_block:
        q_block //= 2
    nq = sq // q_block

    if window is not None:
        if use_pallas and sq == skv:
            import jax as _jax
            from repro.kernels.swa_attention.ops import swa_attention
            return swa_attention(
                q, k, v, window=window, block_q=min(q_block, 128),
                interpret=_jax.default_backend() != "tpu")
        return _swa(qg, k, v, window=window, q_block=q_block, scale=scale)

    kv_block = min(kv_block, skv)
    while skv % kv_block:
        kv_block //= 2
    nkv = skv // kv_block

    kb = k.reshape(b, nkv, kv_block, hkv, hd)
    vb = v.reshape(b, nkv, kv_block, hkv, hd)
    qb = qg.reshape(b, nq, q_block, hkv, hq // hkv, hd)

    def per_q_block(qi, qcur):
        # online softmax over kv blocks
        m0 = jnp.full((b, hkv, hq // hkv, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, hq // hkv, q_block), jnp.float32)
        a0 = jnp.zeros((b, q_block, hkv, hq // hkv, hd), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kcur, vcur = inputs
            s = jnp.einsum("bqkgd,bskd->bkgqs", qcur.astype(jnp.float32),
                           kcur.astype(jnp.float32)) * scale
            if causal:
                qpos = qi * q_block + jnp.arange(q_block)
                kpos = ki * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p, vcur.astype(jnp.float32))
            acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        ks_in = (jnp.arange(nkv), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), ks_in)
        out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1), 1e-30)[..., None]
        return out

    def q_step(_, inputs):
        qi, qcur = inputs
        return None, per_q_block(qi, qcur)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


def _swa(qg, k, v, *, window: int, q_block: int, scale: float):
    """Banded causal attention: each q block sees the previous `window` keys."""
    b, sq, hkv, g, hd = qg.shape
    skv = k.shape[1]
    nq = sq // q_block
    span = window + q_block          # kv needed per q block
    # pad kv on the left by `window` so the slice start is always >= 0
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    qb = qg.reshape(b, nq, q_block, hkv, g, hd)

    def q_step(_, inputs):
        qi, qcur = inputs
        start = qi * q_block         # in padded coords == qpos - window
        kcur = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vcur = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qcur.astype(jnp.float32),
                       kcur.astype(jnp.float32)) * scale
        qpos = qi * q_block + jnp.arange(q_block)              # absolute
        kpos = start + jnp.arange(span) - window               # absolute (may be <0)
        mask = (qpos[:, None] >= kpos[None, :]) \
            & (qpos[:, None] - kpos[None, :] < window) \
            & (kpos[None, :] >= 0)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, vcur.astype(jnp.float32))
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv * g, hd)
    return out.astype(k.dtype)


# -- decode ------------------------------------------------------------------
def cache_length(seq_len: int, window: int | None) -> int:
    return seq_len if window is None else min(seq_len, window)


def decode_attention(
    q: Array,              # (B, 1, Hq, hd)
    k_cache: Array,        # (B, L, Hkv, hd)  (already includes the new token)
    v_cache: Array,
    pos: Array,            # scalar int32: absolute position of the new token
    *,
    ring: bool,
) -> Array:
    b, _, hq, hd = q.shape
    l, hkv = k_cache.shape[1], k_cache.shape[2]
    qg = _grouped(q, hkv)[:, 0]                                  # (B, Hkv, G... ) -> (B, Hkv? )
    # qg: (B, Hkv, G, hd) after dropping seq axis
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    slots = jnp.arange(l)
    if ring:
        valid = jnp.where(pos + 1 >= l, jnp.ones((l,), bool), slots <= pos)
    else:
        valid = slots <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def cache_insert(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array, pos: Array, *, ring: bool):
    """Insert one token's K/V at slot pos (ring: pos % L)."""
    l = k_cache.shape[1]
    slot = pos % l if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache


def reference_attention(q, k, v, *, causal=True, window=None):
    """Naive O(S^2) oracle used by tests (and kernels/swa_attention/ref.py)."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    qg = _grouped(q, hkv)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, hd).astype(q.dtype)
