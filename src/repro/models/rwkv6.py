"""RWKV6 ("Finch") block — attention-free, data-dependent per-channel decay.
[arXiv:2404.05892]

Time-mix recurrence per head (K = V = head dim):
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ          S ∈ R^{K×V}
    y_t = (S_{t-1} + diag(u) k_t v_tᵀ)ᵀ r_t
with w_t ∈ (0,1)^K data-dependent (low-rank projection of the shifted input).

Train/prefill uses a chunked formulation (same shape of algorithm as SSD):
within-chunk banded matmul with cumulative log-decay, state carried across
chunks by lax.scan.  The Pallas kernel in ``repro.kernels.rwkv6_wkv``
implements the per-chunk computation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

Array = jax.Array


def rwkv_dims(cfg):
    nheads = cfg.d_model // cfg.rwkv_head_dim
    return nheads, cfg.rwkv_head_dim


def init_rwkv_block(key, cfg, dtype):
    d = cfg.d_model
    nheads, hd = rwkv_dims(cfg)
    lora = max(32, d // 16)
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)),   # r,k,v,g,w shifts
        "w_r": dense_init(ks[1], (d, d), dtype),
        "w_k": dense_init(ks[2], (d, d), dtype),
        "w_v": dense_init(ks[3], (d, d), dtype),
        "w_g": dense_init(ks[4], (d, d), dtype),
        "w_o": dense_init(ks[5], (d, d), dtype),
        "w_decay_a": dense_init(ks[6], (d, lora), dtype),
        "w_decay_b": dense_init(ks[7], (lora, d), dtype, scale=0.1),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "u_bonus": jax.random.normal(ks[8], (d,), jnp.float32) * 0.1,
        "ln_x": jnp.ones((d,), jnp.float32),
        # channel-mix
        "mu_cm": jax.random.uniform(ks[9], (2, d), jnp.float32),
        "cm_k": dense_init(ks[10], (d, cfg.d_ff), dtype),
        "cm_v": dense_init(ks[11], (cfg.d_ff, d), dtype),
        "cm_r": dense_init(jax.random.fold_in(key, 99), (d, d), dtype),
    }


def _token_shift(x: Array, x_prev: Array | None = None):
    """x: (B, S, d) -> previous token's x (zeros / x_prev at position 0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev is not None:
        shifted = shifted.at[:, 0].set(x_prev.astype(shifted.dtype))
    return shifted


def wkv_chunked(r, k, v, w, u, *, chunk: int, s0=None):
    """Chunked WKV.  r,k,v,w: (B, S, H, K); u: (H, K); w = per-step decay in (0,1).

    Returns y (B, S, H, K) and final state (B, H, K, K) [k-dim, v-dim].
    """
    bsz, s, h, dk = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    logw = jnp.log(w.astype(jnp.float32))                        # ≤ 0
    rr = r.reshape(bsz, nc, chunk, h, dk)
    kk = k.reshape(bsz, nc, chunk, h, dk)
    vv = v.reshape(bsz, nc, chunk, h, dk)
    ww = logw.reshape(bsz, nc, chunk, h, dk)

    if s0 is None:
        s0 = jnp.zeros((bsz, h, dk, dk), jnp.float32)

    def chunk_step(sprev, inputs):
        rc, kc, vc, wc = inputs                                  # (B,c,H,K)
        cs = jnp.cumsum(wc, axis=1)                              # inclusive cumulative log decay
        excl = cs - wc                                           # exclusive (Π up to t-1)
        rf = rc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        # intra-chunk, strictly lower triangular (s < t):
        # k_s v_sᵀ reaches y_t decayed by steps s+1..t-1 = exp(excl_t - cs_s)
        att = jnp.einsum("bthk,bshk->bhts",
                         rf * jnp.exp(excl), kf * jnp.exp(-cs))
        c = rc.shape[1]
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y = jnp.einsum("bhts,bshv->bthv", att, vf)
        # diagonal (current token) with u bonus:
        y = y + jnp.sum(rf * u[None, None] * kf, axis=-1, keepdims=True) * vf
        # inter-chunk: y_t += r_t · (exp(excl_t) S_prev)
        y = y + jnp.einsum("bthk,bhkv->bthv", rf * jnp.exp(excl), sprev)
        # state update: S_new = diag(Πw) S_prev + Σ_s exp(cs_end - cs_s) k_s v_sᵀ
        end = cs[:, -1]                                          # (B,H,K)
        snew = sprev * jnp.exp(end)[..., None] + jnp.einsum(
            "bshk,bshv->bhkv", kf * jnp.exp(end[:, None] - cs), vf)
        return snew, y

    ins = tuple(jnp.moveaxis(t, 1, 0) for t in (rr, kk, vv, ww))
    s_final, ys = jax.lax.scan(chunk_step, s0, ins)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, dk)
    return y.astype(r.dtype), s_final


def wkv_reference(r, k, v, w, u, s0=None):
    """Token-by-token oracle."""
    bsz, s, h, dk = r.shape
    if s0 is None:
        s0 = jnp.zeros((bsz, h, dk, dk), jnp.float32)

    def step(sprev, inputs):
        rt, kt, vt, wt = (t.astype(jnp.float32) for t in inputs)  # (B,H,K)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, sprev) + \
            jnp.sum(rt * u[None] * kt, axis=-1, keepdims=True) * vt
        snew = sprev * wt[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return snew, yt

    ins = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sf, ys = jax.lax.scan(step, s0, ins)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sf


def _time_mix_inputs(params, x, shifted):
    mu = params["mu"]
    mix = [x + (shifted - x) * jax.nn.sigmoid(mu[i])[None, None].astype(x.dtype)
           for i in range(5)]
    xr, xk, xv, xg, xw = mix
    r = jnp.einsum("bsd,de->bse", xr, params["w_r"])
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"])
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"])
    g = jnp.einsum("bsd,de->bse", xg, params["w_g"])
    lora = jnp.einsum("bsd,dl,le->bse", xw, params["w_decay_a"], params["w_decay_b"])
    w = jnp.exp(-jnp.exp(params["decay_base"][None, None] + lora.astype(jnp.float32)))
    return r, k, v, g, w


def time_mix(params, cfg, x: Array, *, chunk: int = 256):
    nheads, hd = rwkv_dims(cfg)
    b, s, d = x.shape
    shifted = _token_shift(x)
    r, k, v, g, w = _time_mix_inputs(params, x, shifted)
    to_h = lambda t: t.reshape(b, s, nheads, hd)
    u = params["u_bonus"].reshape(nheads, hd)
    if cfg.use_pallas_kernels:
        import jax as _jax
        from repro.kernels.rwkv6_wkv.ops import wkv_chunked_pallas
        y, _ = wkv_chunked_pallas(
            to_h(r), to_h(k), to_h(v), to_h(w.astype(x.dtype)), u,
            chunk=chunk, interpret=_jax.default_backend() != "tpu")
    else:
        y, _ = wkv_chunked(to_h(r), to_h(k), to_h(v), to_h(w.astype(x.dtype)),
                           u, chunk=chunk)
    y = y.reshape(b, s, d)
    # group norm per head (ln_x)
    yh = y.reshape(b, s, nheads, hd).astype(jnp.float32)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, s, d) * params["ln_x"][None, None]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    return jnp.einsum("bsd,de->bse", y, params["w_o"])


def channel_mix(params, cfg, x: Array):
    mu = params["mu_cm"]
    shifted = _token_shift(x)
    xk = x + (shifted - x) * jax.nn.sigmoid(mu[0])[None, None].astype(x.dtype)
    xr = x + (shifted - x) * jax.nn.sigmoid(mu[1])[None, None].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["cm_v"])
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"])) * kv


def init_rwkv_cache(cfg, batch: int, dtype):
    nheads, hd = rwkv_dims(cfg)
    return {
        "s": jnp.zeros((batch, nheads, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "x_cm": jnp.zeros((batch, cfg.d_model), dtype),
    }


def time_mix_decode(params, cfg, x: Array, cache):
    """x: (B, 1, d)."""
    nheads, hd = rwkv_dims(cfg)
    b, _, d = x.shape
    shifted = cache["x_tm"][:, None]
    r, k, v, g, w = _time_mix_inputs(params, x, shifted)
    to_h = lambda t: t[:, 0].reshape(b, nheads, hd).astype(jnp.float32)
    rt, kt, vt, wt = to_h(r), to_h(k), to_h(v), to_h(w)
    u = params["u_bonus"].reshape(nheads, hd)
    sprev = cache["s"]
    yt = jnp.einsum("bhk,bhkv->bhv", rt, sprev) + \
        jnp.sum(rt * u[None] * kt, axis=-1, keepdims=True) * vt
    snew = sprev * wt[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
    yh = (yt - yt.mean(-1, keepdims=True)) * jax.lax.rsqrt(yt.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, 1, d) * params["ln_x"][None, None]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, params["w_o"])
    return out, {"s": snew, "x_tm": x[:, 0]}


def channel_mix_decode(params, cfg, x: Array, cache):
    mu = params["mu_cm"]
    shifted = cache["x_cm"][:, None].astype(x.dtype)
    xk = x + (shifted - x) * jax.nn.sigmoid(mu[0])[None, None].astype(x.dtype)
    xr = x + (shifted - x) * jax.nn.sigmoid(mu[1])[None, None].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["cm_v"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"])) * kv
    return out, {"x_cm": x[:, 0]}


# -- full model ---------------------------------------------------------------
def init_params(key, cfg):
    from repro.models.common import dtype_of, embed_init
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)

    def init_layer(k):
        return {
            "block": init_rwkv_block(k, cfg, dtype),
            "ln_tm": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_cm": jnp.ones((cfg.d_model,), jnp.float32),
        }

    return {
        "embed": embed_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype),
        "ln_in": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": jax.vmap(init_layer)(layer_keys),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype),
    }


def forward(params, cfg, batch, *, remat: bool = True):
    from repro.models.common import rms_norm
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = rms_norm(x, params["ln_in"], cfg.norm_eps)

    def body(x, lp):
        x = x + time_mix(lp["block"], cfg, rms_norm(x, lp["ln_tm"], cfg.norm_eps))
        x = x + channel_mix(lp["block"], cfg, rms_norm(x, lp["ln_cm"], cfg.norm_eps))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    from repro.models.common import chunked_softmax_xent
    h, _ = forward(params, cfg, batch)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["labels"].shape, jnp.float32)
    xent = chunked_softmax_xent(h, params["unembed"], batch["labels"], mask, cfg.xent_chunk)
    return xent, {"xent": xent}


def init_cache(cfg, batch: int, seq_len: int, dtype=None):
    from repro.models.common import dtype_of
    dtype = dtype or dtype_of(cfg)
    one = init_rwkv_cache(cfg, batch, dtype)
    stacked = jax.tree.map(
        lambda t: jnp.zeros((cfg.num_layers, *t.shape), t.dtype), one)
    stacked["pos"] = jnp.zeros((), jnp.int32)
    return stacked


def decode_step(params, cfg, tokens, cache):
    from repro.models.common import rms_norm
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rms_norm(x, params["ln_in"], cfg.norm_eps)

    def body(x, inputs):
        lp, c = inputs
        o, tm_new = time_mix_decode(
            lp["block"], cfg, rms_norm(x, lp["ln_tm"], cfg.norm_eps),
            {"s": c["s"], "x_tm": c["x_tm"]})
        x = x + o
        o, cm_new = channel_mix_decode(
            lp["block"], cfg, rms_norm(x, lp["ln_cm"], cfg.norm_eps),
            {"x_cm": c["x_cm"]})
        x = x + o
        return x, {"s": tm_new["s"], "x_tm": tm_new["x_tm"], "x_cm": cm_new["x_cm"]}

    layer_cache = {k: cache[k] for k in ("s", "x_tm", "x_cm")}
    x, new_lc = jax.lax.scan(body, x, (params["layers"], layer_cache))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    new_lc["pos"] = pos + 1
    return logits, new_lc


def prefill(params, cfg, batch):
    h, _ = forward(params, cfg, batch, remat=False)
    logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    return logits
