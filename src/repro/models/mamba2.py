"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode.  [arXiv:2405.21060 as used by zamba2, arXiv:2411.15242]

State: h ∈ (B, H, P, N) with P = head dim, N = ssm state size.
    h_t = exp(a_h Δ_t) h_{t-1} + Δ_t B_t ⊗ x_t
    y_t = C_t · h_t + D x_t
B_t, C_t shared across heads (ngroups = 1), a_h scalar per head.

The chunked algorithm (chunk c): within a chunk the contribution is an
attention-like banded matmul M[t,s] = C_t·B_s · exp(cs_t − cs_s) · Δ_s (s ≤ t),
across chunks the state is carried by a short lax.scan.  The Pallas kernel in
``repro.kernels.mamba2_scan`` implements the same math per chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

Array = jax.Array


def mamba_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads


def init_mamba_block(key, cfg, dtype):
    d = cfg.d_model
    d_in, nheads = mamba_dims(cfg)
    n = cfg.ssm_state_size
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + nheads), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), dtype, scale=0.5),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": (jax.random.uniform(ks[3], (nheads,), jnp.float32) * 2 - 4.0),
    }


def _split_proj(zxbcdt, cfg):
    d_in, nheads = mamba_dims(cfg)
    n = cfg.ssm_state_size
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, conv_w: Array) -> Array:
    """Depthwise causal conv over time.  xbc: (B, S, C); conv_w: (W, C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * conv_w[i] for i in range(w))
    return jax.nn.silu(out)


def ssd_chunked(x, dt, a, b, c, d_skip, *, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative; b, c: (B, S, N).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    adt = a[None, None, :] * dt                                  # (B,S,H) ≤ 0
    xr = (x * dt[..., None]).reshape(bsz, nc, chunk, h, p)       # Δ-weighted input
    ar = adt.reshape(bsz, nc, chunk, h)
    br = b.reshape(bsz, nc, chunk, n)
    cr = c.reshape(bsz, nc, chunk, n)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def chunk_step(hprev, inputs):
        xc, ac, bc, cc = inputs                                  # (B,c,H,P) (B,c,H) (B,c,N)
        cs = jnp.cumsum(ac, axis=1)                              # (B,c,H) inclusive
        # intra-chunk: M[t,s] = (C_t·B_s) exp(cs_t - cs_s) for s<=t
        cb = jnp.einsum("btn,bsn->bts", cc, bc)                  # (B,c,c)
        decay = cs[:, :, None, :] - cs[:, None, :, :]            # (B,t,s,H)
        mask = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        m = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0) * cb[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", m, xc.astype(jnp.float32))
        # inter-chunk: y_t += C_t · (exp(cs_t) h_prev)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cc, hprev, jnp.exp(cs))
        # state update: h = exp(cs_end) h_prev + Σ_s exp(cs_end - cs_s) B_s x_s
        end = cs[:, -1:, :]                                      # (B,1,H)
        w = jnp.exp(end - cs)                                    # (B,c,H)
        h_new = hprev * jnp.exp(end)[:, 0, :, None, None] + jnp.einsum(
            "bsh,bsn,bshp->bhpn", w, bc, xc.astype(jnp.float32))
        return h_new, y_intra + y_inter

    ins = tuple(jnp.moveaxis(t, 1, 0) for t in (xr, ar, br, cr))
    h_final, ys = jax.lax.scan(chunk_step, h0, ins)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, a, b, c, d_skip, h0=None):
    """Token-by-token oracle (lax.scan over time)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inputs):
        xt, dtt, bt, ct = inputs                                 # (B,H,P) (B,H) (B,N)
        decay = jnp.exp(a[None] * dtt)                           # (B,H)
        hnew = hprev * decay[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt.astype(jnp.float32), bt, dtt)
        yt = jnp.einsum("bn,bhpn->bhp", ct, hnew)
        return hnew, yt

    ins = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, b, c))
    hf, ys = jax.lax.scan(step, h0, ins)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), hf


def mamba_block_apply(params, cfg, x: Array, *, chunk: int = 256):
    """Full-sequence mamba2 block.  x: (B, S, d) -> (B, S, d)."""
    d_in, nheads = mamba_dims(cfg)
    n = cfg.ssm_state_size
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc, params["conv_w"])
    xin, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = xin.reshape(*xin.shape[:2], nheads, cfg.ssm_head_dim)
    if cfg.use_pallas_kernels:
        import jax as _jax
        from repro.kernels.mamba2_scan.ops import ssd_chunked_pallas
        y, _ = ssd_chunked_pallas(xh, dt, a, b, c, params["d_skip"],
                                  chunk=chunk,
                                  interpret=_jax.default_backend() != "tpu")
    else:
        y, _ = ssd_chunked(xh, dt, a, b, c, params["d_skip"], chunk=chunk)
    y = y.reshape(*x.shape[:2], d_in) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


def init_mamba_cache(cfg, batch: int, dtype):
    d_in, nheads = mamba_dims(cfg)
    n = cfg.ssm_state_size
    return {
        "h": jnp.zeros((batch, nheads, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in + 2 * n), dtype),
    }


def mamba_block_decode(params, cfg, x: Array, cache):
    """One-token step.  x: (B, 1, d) -> (B, 1, d), new cache."""
    d_in, nheads = mamba_dims(cfg)
    n = cfg.ssm_state_size
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    # conv over the rolling buffer
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, params["conv_w"])[:, None]
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    xh = xin[:, 0].reshape(x.shape[0], nheads, cfg.ssm_head_dim)
    decay = jnp.exp(a[None] * dt)
    h = cache["h"] * decay[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh.astype(jnp.float32), b[:, 0], dt)
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0], h)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    new_cache = {"h": h, "conv": hist[:, 1:]}
    return out, new_cache
