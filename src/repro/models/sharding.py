"""Sharding rules: map every parameter / cache / batch leaf to a PartitionSpec.

Baseline layout (2-D mesh ``(data, model)``; multi-pod adds a leading ``pod``
axis used for pure data parallelism — the Protocol Learning axis):

- weights are fully sharded over BOTH axes (tensor-parallel over ``model``,
  FSDP-style over ``data``) so optimizer state fits:  train state is
  ~12 bytes/param spread over all chips of a pod.
- batch shards over ``data`` (and ``pod`` when present), heads/ffn/experts
  over ``model``.
- KV caches: kv-heads over ``model`` when divisible, otherwise the cache
  *sequence* axis shards over ``model`` (MQA, e.g. granite kv=1).
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


# -- activation sharding hints -------------------------------------------------
# Set by the launch layer (dryrun/train) while tracing under a mesh context;
# None (the default, used by CPU tests/examples) means "no constraints".
# When set, models pin their activation batch dim to these axes so XLA's
# SPMD propagation can never silently un-shard the batch (observed: the
# vmap'd MoE dispatch scatter replicated the global batch on every device —
# EXPERIMENTS.md §Perf mixtral iteration 1).
_ACT_BATCH_AXES = None
_ACT_MODEL_AXIS = None
_ACT_MODEL_SIZE = 1


class activation_sharding:
    """Context manager: ``with activation_sharding(("pod", "data")): ...``"""

    def __init__(self, batch_axes, model_axis: str = "model",
                 model_axis_size: int = 1):
        self.batch_axes = tuple(batch_axes) if batch_axes else None
        self.model_axis = model_axis
        self.model_axis_size = model_axis_size

    def __enter__(self):
        global _ACT_BATCH_AXES, _ACT_MODEL_AXIS, _ACT_MODEL_SIZE
        self._prev = (_ACT_BATCH_AXES, _ACT_MODEL_AXIS, _ACT_MODEL_SIZE)
        _ACT_BATCH_AXES = self.batch_axes
        _ACT_MODEL_AXIS = self.model_axis
        _ACT_MODEL_SIZE = self.model_axis_size
        return self

    def __exit__(self, *exc):
        global _ACT_BATCH_AXES, _ACT_MODEL_AXIS, _ACT_MODEL_SIZE
        _ACT_BATCH_AXES, _ACT_MODEL_AXIS, _ACT_MODEL_SIZE = self._prev
        return False


def model_axis_size() -> int:
    return _ACT_MODEL_SIZE


def constrain_batch(x, ndim_batch: int = 1):
    """Pin the leading batch dim(s) of an activation to the configured axes."""
    if _ACT_BATCH_AXES is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _ACT_BATCH_AXES
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_ffn(x):
    """Pin the trailing FFN dim to the model axis (keeps f-sharded expert
    weights resident — without it XLA all-gathers 10.9 GB of expert
    weights PER DECODE TOKEN on mixtral; EXPERIMENTS.md §Perf pair A3)."""
    if _ACT_MODEL_AXIS is None or _ACT_BATCH_AXES is None:
        return x
    spec = [None] * (x.ndim - 1) + [_ACT_MODEL_AXIS]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _keystr(path) -> list:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "idx"):
            out.append(str(e.idx))
    return out


# base (unstacked) spec per parameter name; d->data, m->model, .->None
_BASE_RULES = {
    "embed": ("m", "d"),
    "unembed": ("d", "m"),
    "wq": ("d", "m?h", "."),
    "wk": ("d", "m?h", "."),
    "wv": ("d", "m?h", "."),
    "wo": ("m?h", ".", "d"),
    "router": ("d", "."),
    "in_proj": ("d", "m"),
    "out_proj": ("m", "d"),
    "conv_w": (".", "m"),
    "w_r": ("d", "m"),
    "w_k": ("d", "m"),
    "w_v": ("d", "m"),
    "w_g": ("d", "m"),
    "w_o": ("m", "d"),
    "cm_k": ("d", "m"),
    "cm_v": ("m", "d"),
    "cm_r": ("d", "m"),
    "w_decay_a": ("d", "."),
    "w_decay_b": (".", "m"),
}
_DENSE_FFN = {"w_gate": ("d", "m"), "w_up": ("d", "m"), "w_down": ("m", "d")}
_MOE_FFN_E = {"w_gate": ("m", "d", "."), "w_up": ("m", "d", "."), "w_down": ("m", ".", "d")}
_MOE_FFN_F = {"w_gate": (".", "d", "m"), "w_up": (".", "d", "m"), "w_down": (".", "m", "d")}


def _resolve(rule, shape, sizes, data_axis, model_axis):
    """Turn a symbolic rule into a PartitionSpec, honouring divisibility."""
    spec = []
    for sym, dim in zip(rule, shape):
        if sym == "d":
            spec.append(data_axis if dim % sizes[data_axis] == 0 else None)
        elif sym == "m":
            spec.append(model_axis if dim % sizes[model_axis] == 0 else None)
        elif sym == "m?h":  # heads: shard only when divisible
            spec.append(model_axis if dim % sizes[model_axis] == 0 else None)
        else:
            spec.append(None)
    return spec


def param_pspecs(shapes_tree, cfg: ModelConfig, sizes: Dict[str, int],
                 data_axis: str = "data", model_axis: str = "model"):
    """shapes_tree: pytree of ShapeDtypeStruct (from Model.param_shapes())."""
    moe_rule = (
        _MOE_FFN_E if cfg.num_experts and cfg.num_experts % sizes[model_axis] == 0
        else _MOE_FFN_F
    )

    def leaf_spec(path, leaf):
        keys = _keystr(path)
        name = keys[-1]
        shape = leaf.shape
        if name in ("w_gate", "w_up", "w_down"):
            rule = moe_rule[name] if "moe" in keys else _DENSE_FFN[name]
        elif name in _BASE_RULES:
            rule = _BASE_RULES[name]
        else:
            rule = ()
        if not rule:
            return P()                                   # replicate (norms, scalars)
        base_rank = len(rule)
        lead = len(shape) - base_rank                    # stacked layer axes
        spec = [None] * lead + _resolve(rule, shape[lead:], sizes, data_axis, model_axis)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes_tree)


def batch_pspecs(batch_tree, sizes: Dict[str, int], data_axis: str = "data",
                 extra_batch_axes: tuple = ()):
    """Shard the leading batch dim over data (+pod) axes when divisible.
    ``extra_batch_axes`` may name ``data_axis`` itself (callers that build
    the full axis tuple up front) — deduped, since a PartitionSpec must
    not mention a mesh axis twice."""
    axes = tuple(dict.fromkeys((*extra_batch_axes, data_axis)))
    total = 1
    for a in axes:
        total *= sizes[a]

    def leaf_spec(path, leaf):
        keys = _keystr(path)
        shape = leaf.shape
        if keys and keys[-1] == "positions":             # (3, B, S)
            ok = shape[1] % total == 0
            return P(None, axes if ok else None, None)
        bdim = shape[0] if shape else 1
        ok = shape and bdim % total == 0
        spec = [axes if ok else None] + [None] * (len(shape) - 1)
        return P(*spec) if shape else P()

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


def cache_pspecs(cache_tree, cfg: ModelConfig, sizes: Dict[str, int],
                 data_axis: str = "data", model_axis: str = "model",
                 extra_batch_axes: tuple = ()):
    """KV caches / recurrent state sharding for decode.  ``extra_batch_axes``
    is deduped against ``data_axis`` like :func:`batch_pspecs`."""
    baxes = tuple(dict.fromkeys((*extra_batch_axes, data_axis)))
    btotal = 1
    for a in baxes:
        btotal *= sizes[a]
    m = sizes[model_axis]

    def leaf_spec(path, leaf):
        keys = _keystr(path)
        name = keys[-1]
        shape = leaf.shape
        if name == "pos" or not shape:
            return P()
        if name in ("k", "v", "cross_k", "cross_v", "attn_k", "attn_v"):
            # (..., B, S, Hkv, hd) with 1-2 leading stack axes
            lead = len(shape) - 4
            b, s, h, _ = shape[lead:]
            bspec = baxes if b % btotal == 0 else None
            if h % m == 0:
                spec = [None] * lead + [bspec, None, model_axis, None]
            elif s % m == 0:
                spec = [None] * lead + [bspec, model_axis, None, None]
            else:
                spec = [None] * lead + [bspec, None, None, None]
            return P(*spec)
        if name == "h":                                  # mamba state (..., B, H, P, N)
            lead = len(shape) - 4
            b, h = shape[lead], shape[lead + 1]
            spec = [None] * lead + [baxes if b % btotal == 0 else None,
                                    model_axis if h % m == 0 else None, None, None]
            return P(*spec)
        if name == "s":                                  # rwkv state (L, B, H, K, K)
            lead = len(shape) - 4
            b, h = shape[lead], shape[lead + 1]
            spec = [None] * lead + [baxes if b % btotal == 0 else None,
                                    model_axis if h % m == 0 else None, None, None]
            return P(*spec)
        if name == "conv":                               # (..., B, W-1, C)
            lead = len(shape) - 3
            b, _, c = shape[lead:]
            spec = [None] * lead + [baxes if b % btotal == 0 else None, None,
                                    model_axis if c % m == 0 else None]
            return P(*spec)
        if name in ("x_tm", "x_cm"):                     # (L, B, d)
            b, d = shape[-2], shape[-1]
            spec = [None] * (len(shape) - 2) + [baxes if b % btotal == 0 else None,
                                                model_axis if d % m == 0 else None]
            return P(*spec)
        # fallback: shard nothing
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
