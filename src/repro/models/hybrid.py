"""Zamba2-style hybrid: Mamba2 backbone with a single SHARED attention block
applied between groups of mamba layers.  [arXiv:2411.15242]

38 mamba layers with mamba_per_group=6 → 6 groups of 6 (shared attn after
each group) + 2 remainder mamba layers.  The shared block's weights are the
same at every application (scan closure), faithful to zamba2's
parameter-sharing design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.common import (
    chunked_softmax_xent,
    dtype_of,
    embed_init,
    dense_init,
    init_swiglu,
    rms_norm,
    swiglu,
)
from repro.models.transformer import init_attn, unembed_of

Array = jax.Array


def group_counts(cfg: ModelConfig):
    g = cfg.num_layers // cfg.mamba_per_group
    rem = cfg.num_layers - g * cfg.mamba_per_group
    return g, rem


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    g, rem = group_counts(cfg)
    ks = jax.random.split(key, 6)

    def init_m(k):
        return {
            "mamba": mamba2.init_mamba_block(k, cfg, dtype),
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
        }

    group_keys = jax.random.split(ks[0], g * cfg.mamba_per_group)
    groups = jax.vmap(init_m)(group_keys)
    groups = jax.tree.map(lambda t: t.reshape(g, cfg.mamba_per_group, *t.shape[1:]), groups)
    params = {
        "embed": embed_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype),
        "groups": groups,
        "shared": {
            "attn": init_attn(ks[2], cfg, dtype),
            "ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ffn": init_swiglu(ks[3], cfg.d_model, cfg.d_ff, dtype),
            "ln_ffn": jnp.ones((cfg.d_model,), jnp.float32),
        },
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": dense_init(ks[4], (cfg.d_model, cfg.vocab_size), dtype),
    }
    if rem:
        rem_keys = jax.random.split(ks[5], rem)
        params["rem"] = jax.vmap(init_m)(rem_keys)
    return params


def _mamba_layer(lp, cfg, x, chunk=256):
    return x + mamba2.mamba_block_apply(
        lp["mamba"], cfg, rms_norm(x, lp["ln"], cfg.norm_eps), chunk=chunk)


def _shared_attn(shared, cfg, x, positions):
    from repro.models.transformer import _qkv

    h = rms_norm(x, shared["ln_attn"], cfg.norm_eps)
    q, k, v = _qkv({"attn": shared["attn"]}, cfg, h, positions)
    o = attn.attention(q, k, v, causal=True, window=None)
    x = x + jnp.einsum("bshe,hed->bsd", o, shared["attn"]["wo"])
    h = rms_norm(x, shared["ln_ffn"], cfg.norm_eps)
    f = swiglu(h, shared["ffn"]["w_gate"], shared["ffn"]["w_up"], shared["ffn"]["w_down"])
    return x + f


def forward(params, cfg: ModelConfig, batch, *, remat: bool = True):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    shared = params["shared"]

    def inner(carry, lp):
        return _mamba_layer(lp, cfg, carry), None

    def group_body(x, gp):
        x, _ = jax.lax.scan(inner, x, gp)
        x = _shared_attn(shared, cfg, x, positions)
        return x, None

    if remat:
        group_body = jax.checkpoint(group_body)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "rem" in params:
        body = jax.checkpoint(inner) if remat else inner
        x, _ = jax.lax.scan(body, x, params["rem"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ModelConfig, batch):
    h, _ = forward(params, cfg, batch)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["labels"].shape, jnp.float32)
    xent = chunked_softmax_xent(h, unembed_of(params), batch["labels"], mask, cfg.xent_chunk)
    return xent, {"xent": xent}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or dtype_of(cfg)
    g, rem = group_counts(cfg)
    hd = cfg.resolved_head_dim
    m = mamba2.init_mamba_cache(cfg, batch, dtype)
    stack = lambda t, n: jnp.zeros((n, *t.shape), t.dtype)
    cache = {
        "mamba_g": jax.tree.map(lambda t: stack(t, g * cfg.mamba_per_group).reshape(
            g, cfg.mamba_per_group, *t.shape), m),
        "attn_k": jnp.zeros((g, batch, seq_len, cfg.num_kv_heads, hd), dtype),
        "attn_v": jnp.zeros((g, batch, seq_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if rem:
        cache["mamba_rem"] = jax.tree.map(lambda t: stack(t, rem), m)
    return cache


def decode_step(params, cfg: ModelConfig, tokens: Array, cache):
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    shared = params["shared"]
    from repro.models.transformer import _qkv

    def inner(x, inputs):
        lp, c = inputs
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        o, c_new = mamba2.mamba_block_decode(lp["mamba"], cfg, h, c)
        return x + o, c_new

    def shared_decode(x, kc, vc):
        h = rms_norm(x, shared["ln_attn"], cfg.norm_eps)
        positions = jnp.full((x.shape[0], 1), pos)
        q, k, v = _qkv({"attn": shared["attn"]}, cfg, h, positions)
        kc, vc = attn.cache_insert(kc, vc, k, v, pos, ring=False)
        o = attn.decode_attention(q, kc, vc, pos, ring=False)
        x = x + jnp.einsum("bshe,hed->bsd", o, shared["attn"]["wo"])
        h = rms_norm(x, shared["ln_ffn"], cfg.norm_eps)
        f = swiglu(h, shared["ffn"]["w_gate"], shared["ffn"]["w_up"], shared["ffn"]["w_down"])
        return x + f, kc, vc

    def group_body(x, inputs):
        gp, gc, kc, vc = inputs
        x, gc_new = jax.lax.scan(inner, x, (gp, gc))
        x, kc, vc = shared_decode(x, kc, vc)
        return x, (gc_new, kc, vc)

    x, (mg_new, k_new, v_new) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["mamba_g"], cache["attn_k"], cache["attn_v"]))
    new_cache = {"mamba_g": mg_new, "attn_k": k_new, "attn_v": v_new, "pos": pos + 1}
    if "rem" in params:
        x, rem_new = jax.lax.scan(inner, x, (params["rem"], cache["mamba_rem"]))
        new_cache["mamba_rem"] = rem_new
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        unembed_of(params).astype(jnp.float32))
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch):
    h, _ = forward(params, cfg, batch, remat=False)
    logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                        unembed_of(params).astype(jnp.float32))
    return logits
