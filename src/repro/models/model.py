"""Unified model API: ``build_model(cfg)`` returns a ``Model`` with the same
functional surface for every family — init / loss / prefill / decode_step /
init_cache / input-spec builders for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AUDIO,
    DENSE,
    HYBRID,
    MOE,
    SSM,
    VLM,
    ModelConfig,
    ShapeConfig,
)
from repro.models import encdec, hybrid, rwkv6, transformer

Array = jax.Array


def _family_module(cfg: ModelConfig):
    return {
        DENSE: transformer,
        MOE: transformer,
        VLM: transformer,
        HYBRID: hybrid,
        SSM: rwkv6,
        AUDIO: encdec,
    }[cfg.family]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- construction ---------------------------------------------------------
    def init(self, rng: Array):
        return _family_module(self.cfg).init_params(rng, self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.PRNGKey(0))

    # -- training -------------------------------------------------------------
    def loss(self, params, batch) -> tuple[Array, Dict[str, Array]]:
        return _family_module(self.cfg).loss_fn(params, self.cfg, batch)

    # -- serving --------------------------------------------------------------
    def prefill(self, params, batch) -> Array:
        return _family_module(self.cfg).prefill(params, self.cfg, batch)

    def decode_step(self, params, tokens, cache):
        return _family_module(self.cfg).decode_step(params, self.cfg, tokens, cache)

    def init_cache(self, batch: int, seq_len: int):
        return _family_module(self.cfg).init_cache(self.cfg, batch, seq_len)

    def decode_scan(self, params, tokens: Array, cache):
        """Scanned multi-token decode (the serving engine's prefill hook):
        feed ``tokens`` (B, T) one position at a time through
        ``decode_step`` inside a single ``lax.scan``, returning the stacked
        per-position logits (B, T, V) and the advanced cache.  Exact for
        every family (recurrent ones included) — it is the same math as the
        per-token python loop, compiled into one program."""
        def body(c, tok):
            logits, c = self.decode_step(params, tok[:, None], c)
            return c, logits[:, 0]

        cache, logits = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
        return jnp.moveaxis(logits, 0, 1), cache

    # -- dry-run input specs (no allocation) -----------------------------------
    def batch_specs(self, shape: ShapeConfig, *, with_labels: bool = True) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if cfg.family == VLM:
            m = cfg.num_media_tokens
            out = {
                "tokens": sds((b, s - m), i32),
                "media": sds((b, m, cfg.d_model), jnp.dtype(cfg.dtype)),
                "positions": sds((3, b, s), i32),
            }
        elif cfg.family == AUDIO:
            out = {
                "frames": sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": sds((b, s), i32),
            }
        else:
            out = {"tokens": sds((b, s), i32)}
        if with_labels:
            out["labels"] = sds((b, s), i32)
        return out

    def decode_specs(self, shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        cache = jax.eval_shape(lambda: self.init_cache(b, s))
        return tokens, cache

    def concrete_batch(self, rng: Array, batch: int, seq: int) -> Dict[str, Array]:
        """Small concrete batch for smoke tests / examples."""
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        tok_len = seq - cfg.num_media_tokens if cfg.family == VLM else seq
        out: Dict[str, Array] = {
            "tokens": jax.random.randint(ks[0], (batch, tok_len), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
        }
        if cfg.family == VLM:
            m = cfg.num_media_tokens
            out["media"] = jax.random.normal(ks[2], (batch, m, cfg.d_model), jnp.dtype(cfg.dtype))
            pos_t = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
            out["positions"] = jnp.stack([pos_t, pos_t // 4, pos_t % 4])
        if cfg.family == AUDIO:
            out["frames"] = jax.random.normal(ks[3], (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return out


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
