"""Mixture-of-Experts FFN with capacity-based scatter/gather dispatch.

Dispatch is sort-free: per group (= batch row) we compute each routed
token-slot's rank within its expert via a scatter-counted prefix, then
scatter hidden states into an (E, C, d) buffer, run all experts as one
batched einsum, and gather back with the gate weights.  Tokens overflowing
an expert's capacity are dropped (standard capacity-factor semantics).

Expert-parallel sharding: the E axis shards over the mesh "model" axis when
divisible (qwen3: 128/16), otherwise the per-expert FFN dim shards
(mixtral: 8 experts on 16-way model parallelism).  See models/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

Array = jax.Array


def init_moe(key, d: int, f: int, num_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (num_experts, d, f), dtype),
        "w_up": dense_init(ks[2], (num_experts, d, f), dtype),
        "w_down": dense_init(ks[3], (num_experts, f, d), dtype),
    }


def capacity(seq: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(seq * top_k / num_experts * factor)
    return max(8, ((c + 7) // 8) * 8)  # round up to 8 for clean tiling


def route(x: Array, router: Array, top_k: int):
    """x: (..., d) -> (gates (..., k), experts (..., k) int32, aux_loss)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    e = router.shape[-1]
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    counts = jax.nn.one_hot(experts, e, dtype=jnp.float32).sum(axis=-2)  # (..., E)
    ce = jnp.mean(counts.reshape(-1, e), axis=0) / top_k
    aux = e * jnp.sum(me * ce)
    return gates, experts, aux


def _dispatch_one_group(x, experts, gates, num_experts: int, cap: int):
    """x: (S, d); experts/gates: (S, k).  Returns buffer (E, C, d), meta."""
    s, d = x.shape
    k = experts.shape[-1]
    flat_e = experts.reshape(-1)                      # (S*k,)
    flat_g = gates.reshape(-1)

    # rank of each routed slot within its expert, in token order
    # prefix count: rank[i] = #{j < i : e_j == e_i}
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)   # (S*k, E)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)                    # exclusive
    rank = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    keep = rank < cap
    slot_e = jnp.where(keep, flat_e, 0)
    slot_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((num_experts, cap, d), x.dtype)
    src = jnp.repeat(x, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[slot_e, slot_c].add(src)
    return buf, (slot_e, slot_c, flat_g * keep.astype(flat_g.dtype))


def _combine_one_group(buf_out, meta, s: int, k: int):
    slot_e, slot_c, g = meta
    gathered = buf_out[slot_e, slot_c]                # (S*k, d)
    gathered = gathered * g[:, None].astype(gathered.dtype)
    return gathered.reshape(s, k, -1).sum(axis=1)


def moe_ffn(x: Array, params, *, top_k: int, capacity_factor: float):
    """x: (B, S, d) -> (B, S, d), aux_loss.  Group = batch row."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    cap = capacity(s, top_k, e, capacity_factor)

    gates, experts, aux = route(x, params["router"], top_k)

    from repro.models.sharding import (constrain_batch, constrain_ffn,
                                       model_axis_size)

    def dispatch_group(xg, eg, gg):
        return _dispatch_one_group(xg, eg, gg, e, cap)

    buf, meta = jax.vmap(dispatch_group)(x, experts, gates.astype(x.dtype))
    # keep the capacity buffer batch-sharded: without this pin, SPMD
    # propagation replicates the vmap'd scatter across the data axis and
    # every device computes the GLOBAL batch's expert FFNs (§Perf mixtral)
    buf = constrain_batch(buf)

    # pin f only when experts are f-sharded (same rule as param_pspecs:
    # experts shard over `model` when E divides it, else d_ff does)
    shard_f = e % max(model_axis_size(), 1) != 0

    def experts_group(h_in):
        g_act = jnp.einsum("ecd,edf->ecf", h_in, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", h_in, params["w_up"])
        if shard_f:
            g_act, u = constrain_ffn(g_act), constrain_ffn(u)
        return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g_act) * u,
                          params["w_down"])

    h_out = jax.vmap(experts_group)(buf)
    h_out = constrain_batch(h_out)

    out = jax.vmap(lambda ho, m: _combine_one_group(ho, m, s, top_k))(
        h_out, meta)
    return constrain_batch(out).astype(x.dtype), aux


def moe_ffn_reference(x: Array, params, *, top_k: int):
    """Oracle: every expert on every token, masked combine (no capacity drops).

    Tests compare moe_ffn against this with capacity_factor large enough that
    nothing is dropped.
    """
    gates, experts, _ = route(x, params["router"], top_k)
    e = params["router"].shape[-1]
    g_act = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    h = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g_act) * u, params["w_down"])
    onehot = jax.nn.one_hot(experts, e, dtype=h.dtype)                # (B,S,k,E)
    w = jnp.einsum("bske,bsk->bse", onehot, gates.astype(h.dtype))
    return jnp.einsum("bsed,bse->bsd", h, w).astype(x.dtype)
