"""Encoder-decoder backbone for seamless-m4t-medium.  [arXiv:2308.11596]

The audio frontend (mel-spectrogram + conv feature extractor) is STUBBED per
the assignment carve-out: the encoder consumes precomputed frame embeddings
(B, S_enc, d) supplied by input_specs().  Everything downstream — conformer-
style encoder stack, text decoder with causal self-attention + cross
attention, KV-cached decode — is fully implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    chunked_softmax_xent,
    dense_init,
    dtype_of,
    embed_init,
    init_swiglu,
    rms_norm,
    swiglu,
    apply_rope,
)
from repro.models.transformer import init_attn

Array = jax.Array


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 5)

    def init_enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn": init_attn(k1, cfg, dtype),
            "ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ffn": init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
            "ln_ffn": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def init_dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_attn": init_attn(k1, cfg, dtype),
            "ln_self": jnp.ones((cfg.d_model,), jnp.float32),
            "cross_attn": init_attn(k2, cfg, dtype),
            "ln_cross": jnp.ones((cfg.d_model,), jnp.float32),
            "ffn": init_swiglu(k3, cfg.d_model, cfg.d_ff, dtype),
            "ln_ffn": jnp.ones((cfg.d_model,), jnp.float32),
        }

    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype),
        "enc_layers": jax.vmap(init_enc_layer)(enc_keys),
        "ln_enc_f": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_layers": jax.vmap(init_dec_layer)(dec_keys),
        "ln_dec_f": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype),
    }


def _proj_qkv(p, x):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    return q, k, v


def encode(params, cfg: ModelConfig, frames: Array, *, remat: bool = True) -> Array:
    """frames: (B, S_enc, d) stubbed frontend output -> memory (B, S_enc, d)."""
    x = frames.astype(dtype_of(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        q, k, v = _proj_qkv(lp["attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn.attention(q, k, v, causal=False)             # bidirectional
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["attn"]["wo"])
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["ln_enc_f"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens: Array, memory: Array,
                 *, remat: bool = True) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
        q, k, v = _proj_qkv(lp["self_attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn.attention(q, k, v, causal=True)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["self_attn"]["wo"])

        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, lp["cross_attn"]["wq"])
        km = jnp.einsum("bsd,dhe->bshe", memory, lp["cross_attn"]["wk"])
        vm = jnp.einsum("bsd,dhe->bshe", memory, lp["cross_attn"]["wv"])
        o = attn.attention(q, km, vm, causal=False)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["cross_attn"]["wo"])

        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return rms_norm(x, params["ln_dec_f"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch):
    memory = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, batch["tokens"], memory)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["labels"].shape, jnp.float32)
    xent = chunked_softmax_xent(h, params["unembed"], batch["labels"], mask, cfg.xent_chunk)
    return xent, {"xent": xent}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    """Self-attn KV cache + precomputed cross-attention K/V from the memory."""
    dtype = dtype or dtype_of(cfg)
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    return {
        "k": jnp.zeros((l, batch, seq_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((l, batch, seq_len, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((l, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((l, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def precompute_cross(params, cfg: ModelConfig, memory: Array):
    """Fill the cross-attention part of the cache from encoder output."""
    def per_layer(lp):
        km = jnp.einsum("bsd,dhe->bshe", memory, lp["cross_attn"]["wk"])
        vm = jnp.einsum("bsd,dhe->bshe", memory, lp["cross_attn"]["wv"])
        return km, vm

    km, vm = jax.vmap(per_layer)(params["dec_layers"])
    return km.astype(dtype_of(cfg)), vm.astype(dtype_of(cfg))


def decode_step(params, cfg: ModelConfig, tokens: Array, cache):
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(x, inputs):
        lp, kc, vc, ck, cv = inputs
        h = rms_norm(x, lp["ln_self"], cfg.norm_eps)
        positions = jnp.full((x.shape[0], 1), pos)
        q, k, v = _proj_qkv(lp["self_attn"], h)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc, vc = attn.cache_insert(kc, vc, k, v, pos, ring=False)
        o = attn.decode_attention(q, kc, vc, pos, ring=False)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["self_attn"]["wo"])

        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, lp["cross_attn"]["wq"])
        o = attn.decode_attention(q, ck, cv, jnp.asarray(ck.shape[1] - 1), ring=False)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["cross_attn"]["wo"])

        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return x, (kc, vc)

    x, (knew, vnew) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["ln_dec_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    return logits, {"k": knew, "v": vnew, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}


def prefill(params, cfg: ModelConfig, batch):
    memory = encode(params, cfg, batch["frames"], remat=False)
    h = decode_train(params, cfg, batch["tokens"], memory, remat=False)
    logits = jnp.einsum("bd,dv->bv", h[:, -1].astype(jnp.float32),
                        params["unembed"].astype(jnp.float32))
    return logits
