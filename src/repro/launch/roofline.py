"""Roofline terms from a compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), in seconds (DESIGN.md / task spec):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` runs on the post-SPMD-partitioning per-device
module, so flops/bytes are already per-chip.  Collective bytes are NOT in
cost_analysis — we parse the optimized HLO text and sum a per-op wire-byte
model over every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (ring-algorithm byte counts, per participating device).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# -- TPU v5e ------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_PER_CHIP = 16e9          # v5e HBM capacity
VMEM_BYTES = 16 * 2 ** 20    # ~16 MiB VMEM per core — the budget a kernel's
                             # double-buffered tile set must fit
                             # (analysis.pallas_check audits this statically)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[a-z0-9]+\[[0-9,]*\][^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[total]
        return int(m.group(2))
    return default


@dataclass
class CollectiveOp:
    op: str
    result_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm bytes on the wire per participating device."""
        n, b = self.group_size, self.result_bytes
        if n <= 1:
            return 0.0
        return {
            "all-gather": b * (n - 1) / n,
            "all-reduce": 2 * b * (n - 1) / n,
            "reduce-scatter": b * (n - 1),          # result is 1/n of input
            "all-to-all": b * (n - 1) / n,
            "collective-permute": float(b),
        }[self.op]


def parse_collectives(hlo_text: str, total_devices: int) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        ops.append(CollectiveOp(
            op=m.group("op"),
            result_bytes=_shape_bytes(m.group("shapes")),
            group_size=_group_size(line, total_devices),
        ))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = out.setdefault(op.op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += op.result_bytes
        d["wire_bytes"] += op.wire_bytes
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    model_flops_global: float = 0.0
    num_chips: int = 1
    xla_flops: float = 0.0               # raw cost_analysis (loop bodies ×1)
    xla_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops × chips) — remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.num_chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "num_chips": self.num_chips,
            "collectives": self.collectives,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
        }


def analyze(compiled, hlo_text: str, *, num_chips: int,
            model_flops_global: float) -> Roofline:
    """Preferred path: trip-count-aware HLO cost model (hlo_cost.py).

    ``compiled.cost_analysis()`` counts while bodies once (a 52-layer scan
    contributes one layer), so its numbers are kept only as a cross-check
    (``xla_*`` fields in the record).
    """
    from repro.launch import hlo_cost

    cost = hlo_cost.analyze_hlo(hlo_text, total_devices=num_chips)
    from repro import compat
    xla = compat.cost_analysis_dict(compiled)
    r = Roofline(
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes_accessed,
        wire_bytes_per_device=cost.wire_bytes,
        collectives=cost.collectives,
        model_flops_global=model_flops_global,
        num_chips=num_chips,
    )
    r.xla_flops = float(xla.get("flops", 0.0))
    r.xla_bytes = float(xla.get("bytes accessed", 0.0))
    return r


def model_flops(cfg, shape, *, active: bool = True) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (one decode tick)."""
    n = cfg.active_param_count() if active else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:7.2f}s "
    if s >= 1e-3:
        return f"{s * 1e3:7.2f}ms"
    return f"{s * 1e6:7.2f}us"
