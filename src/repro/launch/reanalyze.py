"""Re-run the HLO cost model over saved dry-run artifacts (no recompile).

    PYTHONPATH=src python -m repro.launch.reanalyze experiments/dryrun ...
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs import get_config, get_shape
from repro.launch import hlo_cost, roofline as rl


def reanalyze_dir(out_dir: str) -> int:
    n = 0
    for jpath in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        hpath = jpath[:-5] + ".hlo.txt"
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with open(hpath) as f:
            hlo = f.read()
        cost = hlo_cost.analyze_hlo(hlo, total_devices=rec["num_chips"])
        cfg, shape = get_config(rec["arch"]), get_shape(rec["shape"])
        roof = rl.Roofline(
            flops_per_device=cost.flops,
            bytes_per_device=cost.bytes_accessed,
            wire_bytes_per_device=cost.wire_bytes,
            collectives=cost.collectives,
            model_flops_global=rl.model_flops(cfg, shape),
            num_chips=rec["num_chips"],
        )
        roof.xla_flops = rec["roofline"].get("xla_flops", 0.0)
        roof.xla_bytes = rec["roofline"].get("xla_bytes", 0.0)
        rec["roofline"] = roof.to_dict()
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    return n


if __name__ == "__main__":
    for d in sys.argv[1:] or ["experiments/dryrun"]:
        print(f"{d}: {reanalyze_dir(d)} records re-analyzed")
