"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a layer scan
lowered to a ``while`` with known_trip_count=52 contributes its body only
once, undercounting FLOPs/bytes/collectives by ~the layer count.  XLA does
annotate ``backend_config={"known_trip_count":{"n":...}}`` on while ops, so
this module rebuilds the call graph from the HLO text and propagates
execution multiplicity:

  mult(ENTRY) = 1
  while body/condition:  mult ×= known_trip_count (default 1)
  fusion calls / conditionals / other calls: mult ×= 1

Per-op costs (× multiplicity):
  - dot:           2 · numel(result) · prod(lhs contracting dims)
  - convolution:   2 · numel(result) · prod(kernel dims) / out_features
  - bytes:         operands + result, for materializing ops in non-inlined
                   computations (fusion bodies are counted at the call site)
  - collectives:   ring-model wire bytes per device (see roofline.py)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMMENT = re.compile(r"/\*.*?\*/")
# first lowercase token followed by '(' that isn't a dtype — dtypes are
# always followed by '['.  Tuple results / layouts / index comments are
# stripped or never match this pattern.
_OP_NAME = re.compile(r"([a-z][a-z0-9\-]*)\(")
_CALL_REFS = re.compile(r"(?:calls=|to_apply=|condition=|body=)%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no bytes (aliases / metadata)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops that materialize HBM traffic on TPU.  The CPU-lowered HLO we analyze
# has far less fusion than the TPU pipeline would produce — pure elementwise
# chains (add/mul/convert/exp/...) would be fused into their producers on
# TPU — so counting every op's operands+results overstates the memory term
# ~5-10×.  Instead only these op kinds are charged; elementwise/broadcast/
# reshape/slice traffic is treated as fused.
_MATERIALIZING_OPS = {
    "dot", "convolution", "fusion", "copy", "reduce", "reduce-window",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "sort", "rng", "rng-bit-generator", "custom-call",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "cholesky", "triangular-solve", "fft",
}


def _parse_shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES[dt] for dt, dims in shapes)


@dataclass
class Op:
    name: str
    kind: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    params: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    # symbol table: op/param name -> result shapes
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(default_factory=dict)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        if raw.startswith(("ENTRY", "%")) and "{" in raw and "->" in raw:
            m = _COMP_HEADER.match(raw)
            if not m:
                continue
            cur = Computation(name=m.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            # parameters: "pname: f32[2,3], pname2: ..."
            for pm in re.finditer(r"([\w\.\-]+):\s*(\(?[a-z0-9\[\],\s]+\)?)",
                                  m.group(2)):
                shapes = _parse_shape_list(pm.group(2))
                cur.params[pm.group(1)] = shapes
                cur.symbols[pm.group(1)] = shapes
            continue
        if cur is None:
            continue
        if raw.startswith("}"):
            cur = None
            continue
        m = _OP_LINE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), _COMMENT.sub("", m.group(2))
        km = _OP_NAME.search(rhs)
        kind = km.group(1) if km else "unknown"
        # result shapes: everything before the op kind token
        head = rhs[:km.start(1)] if km else rhs
        result_shapes = _parse_shape_list(head)
        # operands: %refs inside the first (...) after the op name
        operands = []
        if km:
            depth = 0
            start = rhs.find("(", km.end(1) - 1)
            if start >= 0:
                for i in range(start, len(rhs)):
                    if rhs[i] == "(":
                        depth += 1
                    elif rhs[i] == ")":
                        depth -= 1
                        if depth == 0:
                            operands = _OPERANDS.findall(rhs[start:i])
                            break
        op = Op(name=name, kind=kind, result_shapes=result_shapes,
                operands=operands, line=raw)
        cur.ops.append(op)
        cur.symbols[name] = result_shapes
    return comps, entry


def _multiplicities(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Execution count of each computation, propagated from ENTRY."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return mult
    mult[entry] = 1.0
    # call edges: (caller, callee, factor)
    edges: List[Tuple[str, str, float]] = []
    for cname, comp in comps.items():
        for op in comp.ops:
            trip = 1.0
            if op.kind == "while":
                tm = _TRIP.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
            for ref in _CALL_REFS.findall(op.line):
                factor = trip if op.kind == "while" else 1.0
                edges.append((cname, ref, factor))
            bm = _BRANCHES.search(op.line)
            if bm:
                for ref in _OPERANDS.findall(bm.group(1)):
                    edges.append((cname, ref, 1.0))
    # propagate to fixpoint — Jacobi sweeps reading the PREVIOUS sweep's
    # values (reading the in-progress sweep would make the result depend on
    # edge order; HLO defines callees before callers, the worst case).
    # The call graph is a DAG, so this converges in ≤ depth sweeps.
    for _ in range(64):
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for caller, callee, factor in edges:
            new[callee] = new.get(callee, 0.0) + mult.get(caller, 0.0) * factor
        if new == mult:
            break
        mult = new
    return mult


@dataclass
class HloCost:
    flops: float = 0.0                   # per device
    bytes_accessed: float = 0.0          # per device
    wire_bytes: float = 0.0              # per device
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    dots: int = 0
    notes: List[str] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "wire_bytes": self.wire_bytes,
            "collectives": self.collectives,
            "dots": self.dots,
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    return {
        "all-gather": result_bytes * (n - 1) / n,
        "all-reduce": 2.0 * result_bytes * (n - 1) / n,
        "reduce-scatter": float(result_bytes) * (n - 1),
        "all-to-all": result_bytes * (n - 1) / n,
        "collective-permute": float(result_bytes),
    }[kind]


def analyze_hlo(hlo_text: str, *, total_devices: int) -> HloCost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return HloCost(notes=["no ENTRY computation found"])
    mult = _multiplicities(comps, entry)

    # computations whose op bytes are accounted at the call site (fusions /
    # reduction lambdas)
    inlined: set = set()
    for comp in comps.values():
        for op in comp.ops:
            for m in re.finditer(r"(?:calls=|to_apply=)%([\w\.\-]+)", op.line):
                inlined.add(m.group(1))

    cost = HloCost()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        is_inlined = cname in inlined
        for op in comp.ops:
            base = op.kind.removesuffix("-start").removesuffix("-done")
            # flops: dots count anywhere (incl. fusion bodies)
            if base == "dot":
                k = 1
                lm = _LHS_CONTRACT.search(op.line)
                if lm and op.operands:
                    lhs_shapes = comp.symbols.get(op.operands[0]) or []
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        for ci in (int(x) for x in lm.group(1).split(",") if x):
                            if ci < len(dims):
                                k *= dims[ci]
                cost.flops += m * 2.0 * sum(
                    _numel(d) for _, d in op.result_shapes) * k
                cost.dots += 1
            elif base == "convolution":
                # rough: 2 · numel(out) · numel(kernel) / out_features
                rhs_shapes = (comp.symbols.get(op.operands[1])
                              if len(op.operands) > 1 else None) or []
                kn = _numel(rhs_shapes[0][1]) if rhs_shapes else 1
                out_n = sum(_numel(d) for _, d in op.result_shapes)
                ofeat = op.result_shapes[0][1][-1] if op.result_shapes and \
                    op.result_shapes[0][1] else 1
                cost.flops += m * 2.0 * out_n * kn / max(ofeat, 1)

            if op.kind.endswith("-done"):
                continue                       # counted at -start
            # collectives (only in non-inlined comps; fusions can't hold them)
            if base in COLLECTIVES:
                rb = _bytes_of(op.result_shapes)
                if base == "all-to-all" and len(op.operands) > 1:
                    # tuple all-to-all: result == inputs
                    pass
                n = _group_size(op.line, total_devices)
                wb = _wire_bytes(base, rb, n)
                cost.wire_bytes += m * wb
                d = cost.collectives.setdefault(
                    f"{base}@g{n}",
                    {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
                d["count"] += m
                d["result_bytes"] += m * rb
                d["wire_bytes"] += m * wb

            # bytes: materializing ops in non-inlined computations
            if not is_inlined and base in _MATERIALIZING_OPS:
                rb = _bytes_of(op.result_shapes)
                # XLA names fusions after the ops they contain: a
                # "...dynamic-update-slice_fusion" IS a cache update
                eff = base
                if base == "fusion":
                    if "dynamic-update-slice" in op.name or "scatter" in op.name:
                        eff = "dynamic-update-slice"
                    elif "dynamic-slice" in op.name or "gather" in op.name:
                        eff = "dynamic-slice"
                    elif "convert" in op.name:
                        # bf16<->f32 converts are an XLA:CPU lowering
                        # artifact (no native bf16 dot on CPU); on TPU the
                        # MXU consumes bf16 and the convert fuses away.
                        # Observed: 87% of mixtral decode bytes.
                        continue
                if eff in ("dynamic-slice", "gather"):
                    # reads only the sliced region, not the whole operand
                    # (a layer scan dynamic-slicing stacked params would
                    # otherwise be charged L x the full parameter tree)
                    bytes_moved = 2 * rb
                elif eff in ("dynamic-update-slice", "scatter"):
                    # reads+writes only the update region (result aliases
                    # the operand).  For a raw op the update is operand 1;
                    # for a DUS-rooted fusion take the smallest tensor
                    # operand as the update-size proxy.
                    if base == "fusion":
                        cand = [_bytes_of(comp.symbols.get(o) or [])
                                for o in op.operands]
                        cand = [c for c in cand if c > 64]
                        upd = min(cand) if cand else rb
                    else:
                        upd = (_bytes_of(comp.symbols.get(op.operands[1]) or [])
                               if len(op.operands) > 1 else rb)
                    bytes_moved = 2 * upd
                else:
                    ob = 0
                    for o in op.operands:
                        ob += _bytes_of(comp.symbols.get(o) or [])
                    bytes_moved = rb + ob
                cost.bytes_accessed += m * bytes_moved
    return cost
