"""Distributed train-step builder + a runnable CPU trainer.

Two regimes, selected by the mesh and ``TrainOptions.pod_sync``:

- ``dense`` (or no pod axis): one global pjit program; the batch shards
  over (pod, data), XLA inserts the exact gradient all-reduces.  This is
  the centralized baseline the paper compares against.
- ``qsgd`` / ``gossip`` / ``centered_clip``: the Protocol Learning regime.
  The step is a ``shard_map`` manual over the ``pod`` axis only
  (``axis_names={"pod"}``) — data/model sharding inside each pod stays
  automatic (pjit), while gradients crossing the pod boundary go through
  the explicit ``core.hierarchical`` collectives: int8-on-the-wire
  quantized all-gather, ring gossip (exact at 2 pods), or byzantine-robust
  CenteredClip.  The dry-run HLO shows the wire dtype/schedule directly.

Also provides grad-accumulation microbatching (perf knob for the memory
roofline term) and the ``python -m repro.launch.train`` CPU driver used by
the examples.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.hierarchical import get_pod_sync
from repro.launch import mesh as mesh_lib
from repro.models import sharding as shrules
from repro.models.model import Model


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


@dataclass(frozen=True)
class TrainOptions:
    pod_sync: str = "dense"              # dense|qsgd|gossip|centered_clip
    sync_kwargs: Dict = field(default_factory=dict)
    microbatches: int = 1                # grad accumulation steps
    donate: bool = True
    # FSDP-style compute gather: weights are STORED (data, model)-sharded
    # (so optimizer state fits) but gathered over ``data`` for the forward/
    # backward.  Without this, XLA sharding propagation keeps weights
    # d_model-sharded over ``data`` and instead un-shards the *activations*
    # over the batch — materializing full-batch O(S²) attention residuals
    # (observed: 124 GB/device temps on tinyllama train_4k).  See
    # EXPERIMENTS.md §Perf iteration 0.
    param_gather: str = "fsdp"           # fsdp|none


# -- sharding trees -----------------------------------------------------------
def state_pspecs(model: Model, mesh: Mesh):
    sizes = mesh_lib.axis_sizes(mesh)
    return shrules.param_pspecs(model.param_shapes(), model.cfg, sizes)


def _strip_axes(spec: P, drop=("data",)) -> P:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a not in drop)
            out.append(kept if kept else None)
        else:
            out.append(None if e in drop else e)
    return P(*out)


def compute_pspecs(pspec_tree):
    """Model-axis-only specs: the FSDP gather target for the forward pass."""
    return jax.tree.map(lambda s: _strip_axes(s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_param_gather(model: Model, mesh: Mesh, mode: str, *,
                      bare_specs: bool = False):
    """params -> params resharded for compute (identity when mode='none').

    ``bare_specs=True`` constrains with raw PartitionSpecs (resolved against
    the context mesh) — required inside the partial-manual pod shard_map,
    where a NamedSharding built on the fully-Auto mesh would not match the
    Manual-pod context mesh.
    """
    if mode == "none" or mesh_lib.axis_sizes(mesh).get("data", 1) == 1:
        return lambda p: p
    gathered = compute_pspecs(state_pspecs(model, mesh))
    if not bare_specs:
        gathered = jax.tree.map(lambda s: NamedSharding(mesh, s), gathered,
                                is_leaf=lambda x: isinstance(x, P))

    def gather(params):
        return jax.tree.map(jax.lax.with_sharding_constraint, params, gathered,
                            is_leaf=lambda x: isinstance(x, P))
    return gather


def train_state_shardings(model: Model, optimizer, mesh: Mesh):
    """NamedShardings for TrainState(params, opt_state)."""
    pspec = state_pspecs(model, mesh)
    opt_state_shapes = jax.eval_shape(
        lambda: optimizer.init(model.param_shapes()))
    opt_pspec = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _match_param_spec(path, leaf, pspec),
        opt_state_shapes)
    to_ns = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    return TrainState(params=to_ns(pspec), opt_state=to_ns(opt_pspec))


def _match_param_spec(path, leaf, pspec):
    """Optimizer-state leaf -> spec of the parameter it mirrors (or P())."""
    # AdamState paths look like ('m', <param path...>) / ('v', ...) / ('step',)
    keys = [getattr(e, "key", getattr(e, "idx", getattr(e, "name", None)))
            for e in path]
    sub = pspec
    try:
        for k in keys[1:]:
            if isinstance(sub, (dict,)):
                sub = sub[k]
            elif isinstance(sub, (list, tuple)):
                sub = sub[int(k)]
            else:
                return P()
        if isinstance(sub, P):
            return sub
    except (KeyError, IndexError, TypeError, ValueError):
        pass
    return P()


def batch_shardings(model: Model, shape: ShapeConfig, mesh: Mesh):
    sizes = mesh_lib.axis_sizes(mesh)
    extra = ("pod",) if mesh_lib.has_pod_axis(mesh) else ()
    specs = shrules.batch_pspecs(model.batch_specs(shape), sizes,
                                 extra_batch_axes=extra)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _pod_batch_specs(batch_tree):
    """Batch specs naming ONLY the pod axis (for partial-manual shard_map)."""
    def leaf(path, l):
        keys = [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]
        if keys and keys[-1] == "positions":
            return P(None, "pod")
        return P("pod")
    return jax.tree_util.tree_map_with_path(leaf, batch_tree)


# -- microbatching ------------------------------------------------------------
def _split_micro(batch, m: int):
    """(B, ...) -> (m, B/m, ...) on the batch axis of every leaf.

    The reshape breaks SPMD batch-sharding propagation (observed: granite
    train_4k with mb=8 compiled to 8× the FLOPs — every device ran the
    full global batch), so when the launch layer has declared activation
    batch axes we re-pin the new batch dim explicitly.
    """
    from repro.models import sharding as shrules
    axes = shrules._ACT_BATCH_AXES

    def leaf(path, l):
        keys = [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]
        if keys and keys[-1] == "positions":        # (3, B, S) -> (m, 3, B/m, S)
            b = l.shape[1]
            out = jnp.moveaxis(
                l.reshape(l.shape[0], m, b // m, *l.shape[2:]), 1, 0)
            if axes is not None:
                out = jax.lax.with_sharding_constraint(
                    out, P(None, None, axes, *([None] * (out.ndim - 3))))
            return out
        b = l.shape[0]
        out = l.reshape(m, b // m, *l.shape[1:])
        if axes is not None:
            out = jax.lax.with_sharding_constraint(
                out, P(None, axes, *([None] * (out.ndim - 2))))
        return out
    return jax.tree_util.tree_map_with_path(leaf, batch)


def _grad_fn(model: Model, microbatches: int, gather=lambda p: p):
    """Returns grad_fn(params, batch) -> (loss, grads) with accumulation."""
    def loss_of(params, batch):
        loss, _ = model.loss(gather(params), batch)
        return loss

    vg = jax.value_and_grad(loss_of)

    if microbatches == 1:
        return vg

    def accum(params, batch):
        micro = _split_micro(batch, microbatches)

        def body(carry, mb):
            loss_sum, gsum = carry
            l, g = vg(params, mb)
            gsum = jax.tree.map(jnp.add, gsum, g)
            return (loss_sum + l, gsum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    return accum


# -- the train step ------------------------------------------------------------
def make_train_step(model: Model, optimizer, mesh: Mesh,
                    opts: TrainOptions = TrainOptions()):
    """Returns ``step(state, batch) -> (state, metrics)`` (un-jitted)."""
    use_pod_sync = mesh_lib.has_pod_axis(mesh) and opts.pod_sync != "dense"
    gather = make_param_gather(model, mesh, opts.param_gather,
                               bare_specs=use_pod_sync)
    grad_fn = _grad_fn(model, opts.microbatches, gather)

    def apply_update(state, loss, grads):
        params, opt_state = optimizer.update(grads, state.opt_state, state.params)
        from repro.optim.optimizer import global_norm
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return TrainState(params, opt_state), metrics

    if not use_pod_sync:
        def step(state, batch):
            loss, grads = grad_fn(state.params, batch)
            return apply_update(state, loss, grads)
        return step

    sync = get_pod_sync(opts.pod_sync, **opts.sync_kwargs)
    # Inside the manual-pod region the batch's pod dim is already local, so
    # activation constraints must not name "pod": old jax's partitioner
    # hard-aborts (IsManualSubgroup) on constraints over manual axes.
    inner_batch_axes = tuple(a for a in mesh_lib.batch_axes(mesh)
                             if a != "pod")

    def per_pod(state, batch, pod_ids):
        # batch is this pod's local shard; data/model axes remain automatic.
        # pod_ids is an arange sharded over "pod", so pod_ids[0] is this
        # pod's index — the data-derived identity compat's emulated
        # collectives need where axis_index/all_gather can't lower (old jax
        # partial-manual mode).
        with shrules.activation_sharding(
                inner_batch_axes,
                model_axis_size=mesh_lib.axis_sizes(mesh).get("model", 1)):
            loss, grads = grad_fn(state.params, batch)
        grads = sync(grads, "pod", pod_index=pod_ids[0])
        loss = jax.lax.pmean(loss, "pod")
        return apply_update(state, loss, grads)

    def step(state, batch):
        batch_specs = _pod_batch_specs(batch)
        state_specs = jax.tree.map(lambda _: P(), state)
        pod_ids = jnp.arange(mesh.shape["pod"], dtype=jnp.int32)
        return compat.shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(state_specs, batch_specs, P("pod")),
            out_specs=(state_specs, {"loss": P(), "grad_norm": P()}),
            axis_names={"pod"},
            check=False,
        )(state, batch, pod_ids)

    return step


# -- serving step (decode shapes) ----------------------------------------------
def make_serve_step(model: Model):
    """One decode tick: (params, tokens(B,1), cache) -> (logits, cache)."""
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return serve_step


def serve_param_shardings(model: Model, mesh: Mesh):
    """Serving weight layout: replicated over `data`, sharded over `model`
    (Megatron TP).  There is no optimizer state to amortize at inference,
    and keeping d_model sharded over `data` makes XLA all-gather expert/
    attention weights PER DECODE TOKEN (mixtral: 10.9 GB/token —
    EXPERIMENTS.md §Perf pair A3)."""
    pspec = compute_pspecs(state_pspecs(model, mesh))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                        is_leaf=lambda x: isinstance(x, P))


def serve_shardings(model: Model, shape: ShapeConfig, mesh: Mesh):
    sizes = mesh_lib.axis_sizes(mesh)
    extra = ("pod",) if mesh_lib.has_pod_axis(mesh) else ()
    tokens_sds, cache_sds = model.decode_specs(shape)
    b = tokens_sds.shape[0]
    btotal = 1
    for a in (*extra, "data"):
        btotal *= sizes[a]
    tok_spec = P((*extra, "data")) if b % btotal == 0 else P()
    cache_spec = shrules.cache_pspecs(cache_sds, model.cfg, sizes,
                                      extra_batch_axes=extra)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    return ns(tok_spec), ns(cache_spec)


# -- CPU driver -----------------------------------------------------------------
def main(argv=None):
    import argparse

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, model_batch
    from repro.models.model import build_model
    from repro.optim.optimizer import AdamW, cosine_schedule

    ap = argparse.ArgumentParser(description="CPU trainer (reduced configs)")
    ap.add_argument("--arch", default="protocol-125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    cfg = cfg.reduced(max_seq_len=args.seq) if args.reduced else cfg
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps), weight_decay=0.01)

    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    host = mesh_lib.make_host_mesh()
    step_fn = jax.jit(make_train_step(
        model, opt, host, TrainOptions(microbatches=args.microbatches)))

    import time
    t0 = time.time()
    for step in range(args.steps):
        batch = model_batch(cfg, dcfg, step)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):8.3f}  "
                  f"({time.time() - t0:6.1f}s)", flush=True)
    return state


if __name__ == "__main__":
    main()
