"""Serving driver over the unified Model API.

The token loops live in ``core.serving`` now: :func:`greedy_decode` is the
jitted *scanned* decoder (prefill = ``Model.decode_scan``, decode =
``lax.scan`` over ``decode_step``), and :func:`greedy_decode_loop` is the
replaced per-token python loop, kept as the reference oracle and benchmark
baseline.  This module is the CLI:

- ``--driver scan``   : the scanned greedy decoder (default);
- ``--driver loop``   : the old python loop (reference / baseline);
- ``--driver engine`` : the continuous-batching engine
  (``core.serving.ServingEngine``) — fixed decode slots, arrival-ordered
  admission, per-slot KV caches, custody-gated availability — serving a
  queue of requests in one compiled scan.
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.serving import (  # noqa: F401  (re-exported API)
    ServeStats,
    ServingConfig,
    ServingEngine,
    build_lane,
    greedy_decode,
    greedy_decode_loop,
)
from repro.models.model import build_model


def main(argv=None):
    import numpy as np

    import argparse
    ap = argparse.ArgumentParser(description="CPU serving driver")
    ap.add_argument("--arch", default="protocol-125m")
    ap.add_argument("--driver", default="scan",
                    choices=("scan", "loop", "engine"))
    ap.add_argument("--batch", type=int, default=4,
                    help="batch (scan/loop) or request count (engine)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="engine: decode slot-pool size")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)

    if args.driver == "engine":
        scfg = ServingConfig(
            slots=args.slots, max_new=args.max_new,
            steps=args.prompt_len + args.max_new
            + (args.prompt_len + args.max_new)
            * ((args.batch + args.slots - 1) // args.slots))
        lane = build_lane(
            n_requests=args.batch,
            prompt_lens=np.full(args.batch, args.prompt_len, np.int32),
            max_new=args.max_new,
            steps=scfg.steps, n_nodes=8, balances=[float(args.batch)] * 4,
            fee=1.0, load=1.0)
        engine = ServingEngine(model, scfg, prompts)
        engine.run(params, lane)                     # warm the program
        res = engine.run(params, lane)
        print(f"arch={cfg.name} engine slots={scfg.slots} "
              f"requests={args.batch} served={int(res.done.sum())} "
              f"tokens={res.tokens_served} ({res.tok_per_s:.1f} tok/s, "
              f"availability {res.availability:.2f})")
        print("sample:", res.tokens[0, :16].tolist())
        return

    decode = greedy_decode if args.driver == "scan" else greedy_decode_loop
    gen, stats = decode(model, params, prompts, args.max_new)
    print(f"arch={cfg.name} driver={args.driver} batch={stats.batch} "
          f"prefill={stats.prefill_s:.2f}s decode={stats.decode_s:.2f}s "
          f"({stats.tok_per_s:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
