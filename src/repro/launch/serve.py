"""Batched serving driver: prefill + KV-cache/recurrent-state decode.

Serves any model family through the unified Model API.  Two modes:

- plain       : params held locally (the centralized baseline).
- protocol    : inference through ``core.protocol.ProtocolModelServer`` —
  weights exist only as custody shards across swarm nodes, requests need
  ledger credentials, and the driver demonstrates that a partial coalition
  cannot serve (the §4.1 unextractability property, live).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import Model, build_model

Array = jax.Array


@dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int
    batch: int

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out * self.batch / max(self.decode_s, 1e-9)


def greedy_decode(model: Model, params, prompts: Array, max_new: int,
                  *, cache_len: Optional[int] = None):
    """prompts: (B, S0) int32.  Returns (B, max_new) generated tokens."""
    b, s0 = prompts.shape
    cache_len = cache_len or (s0 + max_new)
    cache = model.init_cache(b, cache_len)

    decode = jax.jit(model.decode_step)

    t0 = time.time()
    # prefill by stepping the prompt through decode (exact; works for all
    # families incl. recurrent ones)
    logits = None
    for i in range(s0):
        logits, cache = decode(params, prompts[:, i:i + 1], cache)
    prefill_s = time.time() - t0

    t0 = time.time()
    outs: List[Array] = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(max_new):
        outs.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    decode_s = time.time() - t0

    gen = jnp.concatenate(outs, axis=1)
    return gen, ServeStats(prefill_s, decode_s, max_new, b)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description="CPU serving driver")
    ap.add_argument("--arch", default="protocol-125m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
    gen, stats = greedy_decode(model, params, prompts, args.max_new)
    print(f"arch={cfg.name} batch={stats.batch} "
          f"prefill={stats.prefill_s:.2f}s decode={stats.decode_s:.2f}s "
          f"({stats.tok_per_s:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
