"""Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture × input shape × mesh) — no hardware, no allocation.

MUST be run as a module entry point:  PYTHONPATH=src python -m repro.launch.dryrun
The first two lines create 512 placeholder host devices BEFORE any jax
import (jax locks the device count at first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402

from repro.configs import (     # noqa: E402
    ASSIGNED_ARCHS,
    applicable_shapes,
    get_config,
    get_shape,
)
from repro.launch import mesh as mesh_lib                     # noqa: E402
from repro.launch import roofline as rl                      # noqa: E402
from repro.launch.train import (                              # noqa: E402
    TrainOptions,
    TrainState,
    batch_shardings,
    make_serve_step,
    make_train_step,
    serve_shardings,
    train_state_shardings,
)
from repro.models.model import build_model                   # noqa: E402
from repro.optim.optimizer import AdamW                      # noqa: E402


def _state_sds(model, optimizer):
    """ShapeDtypeStructs for TrainState without allocating."""
    return jax.eval_shape(
        lambda k: TrainState(*_init_state(model, optimizer, k)),
        jax.random.PRNGKey(0))


def _init_state(model, optimizer, key):
    params = model.init(key)
    return params, optimizer.init(params)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              pod_sync: str = "dense", microbatches: int = 1,
              param_gather: str = "fsdp", verbose: bool = True,
              keep_hlo: str = "") -> dict:
    """Lower + compile one (arch × shape × mesh) combination; return record."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.devices.size
    mesh_name = "multi_pod" if multi_pod else "single_pod"

    from repro.launch.mesh import axis_sizes
    from repro.models.sharding import activation_sharding
    batch_axes = ("pod", "data") if multi_pod else ("data",)

    t0 = time.time()
    with mesh, activation_sharding(batch_axes,
                                   model_axis_size=axis_sizes(mesh)["model"]):
        if shape.kind == "train":
            opt = AdamW(lr=1e-4)
            opts = TrainOptions(pod_sync=pod_sync, microbatches=microbatches,
                                param_gather=param_gather)
            step = make_train_step(model, opt, mesh, opts)
            state_ns = train_state_shardings(model, opt, mesh)
            batch_ns = batch_shardings(model, shape, mesh)
            state_sds = _state_sds(model, opt)
            batch_sds = model.batch_specs(shape)
            jitted = jax.jit(step, in_shardings=(state_ns, batch_ns),
                             out_shardings=(state_ns, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            from repro.launch.train import make_param_gather
            params_ns = train_state_shardings(model, AdamW(), mesh).params
            batch_ns = batch_shardings(model, shape, mesh)
            batch_ns = {k: v for k, v in batch_ns.items() if k != "labels"}
            batch_sds = model.batch_specs(shape, with_labels=False)
            gather = make_param_gather(model, mesh, param_gather)

            def prefill(params, batch):
                return model.prefill(gather(params), batch)

            jitted = jax.jit(prefill, in_shardings=(params_ns, batch_ns))
            lowered = jitted.lower(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)), batch_sds)
        else:  # decode
            from repro.launch.train import serve_param_shardings
            params_ns = serve_param_shardings(model, mesh)
            tok_ns, cache_ns = serve_shardings(model, shape, mesh)
            tok_sds, cache_sds = model.decode_specs(shape)
            serve = make_serve_step(model)
            jitted = jax.jit(serve,
                             in_shardings=(params_ns, tok_ns, cache_ns),
                             out_shardings=(None, cache_ns),
                             donate_argnums=(2,))
            lowered = jitted.lower(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                tok_sds, cache_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    if keep_hlo:
        with open(keep_hlo, "w") as f:
            f.write(hlo)
    mem = compiled.memory_analysis()
    mem_rec = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "peak_memory_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    roof = rl.analyze(compiled, hlo, num_chips=num_chips,
                      model_flops_global=rl.model_flops(cfg, shape))

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "num_chips": num_chips,
        "pod_sync": pod_sync,
        "microbatches": microbatches,
        "param_gather": param_gather,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "roofline": roof.to_dict(),
        "status": "ok",
    }
    if verbose:
        _print_record(rec)
    return rec


def _print_record(rec: dict) -> None:
    r = rec["roofline"]
    mem = rec["memory"]
    live = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
    print(f"[{rec['mesh']}/{rec['pod_sync']}] {rec['arch']:22s} {rec['shape']:12s} "
          f"compute={rl.fmt_seconds(r['compute_s'])} "
          f"memory={rl.fmt_seconds(r['memory_s'])} "
          f"coll={rl.fmt_seconds(r['collective_s'])} "
          f"dom={r['dominant']:10s} "
          f"useful={r['useful_flops_ratio']:6.3f} "
          f"mem/dev={live / 1e9:7.2f}GB "
          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
          flush=True)


def run_all(archs, *, multi_pod: bool, pod_sync: str, out_dir: str,
            microbatches: int = 1, shapes: Optional[list] = None,
            param_gather: str = "fsdp") -> list:
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in (shapes or applicable_shapes(cfg)):
            if shape_name not in applicable_shapes(cfg):
                print(f"SKIP {arch} {shape_name} (DESIGN.md §3: "
                      f"quadratic attention at 500k)", flush=True)
                continue
            tag = f"{arch}__{shape_name}__" \
                  f"{'multi' if multi_pod else 'single'}__{pod_sync}" \
                  + (f"__mb{microbatches}" if microbatches != 1 else "") \
                  + (f"__{param_gather}" if param_gather != "fsdp" else "")
            path = os.path.join(out_dir, tag + ".json")
            try:
                rec = lower_one(arch, shape_name, multi_pod=multi_pod,
                                pod_sync=pod_sync, microbatches=microbatches,
                                param_gather=param_gather,
                                keep_hlo=os.path.join(out_dir, tag + ".hlo.txt"))
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "multi_pod" if multi_pod else "single_pod",
                       "pod_sync": pod_sync, "status": "fail",
                       "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {arch} {shape_name}: {type(e).__name__}: {e}",
                      flush=True)
                traceback.print_exc()
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            records.append(rec)
    return records


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pod-sync", default="dense",
                    choices=["dense", "qsgd", "gossip", "centered_clip", "median"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-gather", default="fsdp", choices=["fsdp", "none"])
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    if args.all:
        recs = run_all(ASSIGNED_ARCHS, multi_pod=args.multi_pod,
                       pod_sync=args.pod_sync, out_dir=args.out_dir,
                       microbatches=args.microbatches,
                       param_gather=args.param_gather,
                       shapes=[args.shape] if args.shape else None)
        bad = [r for r in recs if r.get("status") != "ok"]
        print(f"\n{len(recs) - len(bad)}/{len(recs)} combinations compiled")
        return 1 if bad else 0

    if not args.arch:
        ap.error("--arch or --all required")
    archs = args.arch.split(",")
    shapes = args.shape.split(",") if args.shape else None
    recs = run_all(archs, multi_pod=args.multi_pod, pod_sync=args.pod_sync,
                   out_dir=args.out_dir, microbatches=args.microbatches,
                   param_gather=args.param_gather, shapes=shapes)
    return 1 if any(r.get("status") != "ok" for r in recs) else 0


if __name__ == "__main__":
    raise SystemExit(main())
