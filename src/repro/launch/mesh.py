"""Production meshes (DESIGN.md §4).

Functions, not module constants — importing this module never touches jax
device state.  The dry-run creates 512 host-platform placeholder devices
(XLA_FLAGS set in dryrun.py before any jax import); everything else sees
the container's single real device.

Target hardware: TPU v5e.  Mesh axes:
  single pod : (16, 16)        ``(data, model)``     = 256 chips
  multi-pod  : (2, 16, 16)     ``(pod, data, model)`` = 512 chips

``pod`` is the Protocol Learning axis — the slow, inter-pod "internet"
boundary where the paper's techniques (compression / gossip / robust
aggregation, core/hierarchical.py) apply.  ``data``/``model`` are the
fast intra-pod ICI axes driven by ordinary pjit.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh


SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1×1 mesh over the container's real device(s) — smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), SINGLE_POD_AXES)


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> tuple:
    """Axes the global batch shards over (pod first when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def has_pod_axis(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names
