"""Production meshes (DESIGN.md §4).

Functions, not module constants — importing this module never touches jax
device state.  The dry-run creates 512 host-platform placeholder devices
(XLA_FLAGS set in dryrun.py before any jax import); everything else sees
the container's single real device.

Target hardware: TPU v5e.  Mesh axes:
  single pod : (16, 16)        ``(data, model)``     = 256 chips
  multi-pod  : (2, 16, 16)     ``(pod, data, model)`` = 512 chips

``pod`` is the Protocol Learning axis — the slow, inter-pod "internet"
boundary where the paper's techniques (compression / gossip / robust
aggregation, core/hierarchical.py) apply.  ``data``/``model`` are the
fast intra-pod ICI axes driven by ordinary pjit.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh


SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")
# the campaign mesh (core/placement.MeshPlan): lanes = the embarrassingly
# parallel run axis of a sweep; data/model = the within-lane axes the
# models/sharding.py rules partition over
CAMPAIGN_AXES = ("lanes", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Mesh over the container's real device(s) — smoke tests/examples.

    Zero-arg: ``(n, 1)`` over ``("data", "model")``, as before.  ``model``
    splits a model axis off the host devices — ``(n // model, model)`` —
    so fake-device tests (``--xla_force_host_platform_device_count=8``)
    can build ``(4, 2)``-style meshes; it must divide the device count."""
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"model-axis factor {model} must be >= 1 and divide the "
            f"{n} available device(s)")
    return jax.make_mesh((n // model, model), SINGLE_POD_AXES)


def make_campaign_mesh(lanes: Optional[int] = None, *, data: int = 1,
                       model: int = 1) -> Mesh:
    """The ``("lanes", "data", "model")`` mesh for a MeshPlan, over the
    first ``lanes * data * model`` devices (a campaign may deliberately use
    a divisor of the host's devices so its lane count shards evenly —
    ``jax.make_mesh`` would insist on all of them).  Zero-arg: every
    device on the lane axis."""
    devs = jax.devices()
    if data < 1 or model < 1:
        raise ValueError(f"data/model factors must be >= 1, got "
                         f"data={data} model={model}")
    if lanes is None:
        if len(devs) % (data * model):
            raise ValueError(
                f"data={data} x model={model} must divide the "
                f"{len(devs)} available device(s) when lanes is unset")
        lanes = len(devs) // (data * model)
    if lanes < 1:
        raise ValueError(f"lane-axis extent must be >= 1, got {lanes}")
    need = lanes * data * model
    if need > len(devs):
        raise ValueError(
            f"campaign mesh ({lanes}, {data}, {model}) needs {need} "
            f"devices, have {len(devs)}")
    arr = np.asarray(devs[:need]).reshape(lanes, data, model)
    return Mesh(arr, CAMPAIGN_AXES)


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> tuple:
    """Axes the global batch shards over (pod first when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def has_pod_axis(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names
