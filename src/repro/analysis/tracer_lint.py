"""PL-rules: an AST lint for tracer hazards jaxprs cannot show.

A jaxpr only exists after tracing succeeded, so the jaxpr auditor is blind
to the class of bug where tracing itself goes wrong — python ``if`` on a
tracer raises at the worst possible lane, ``np.`` silently constant-folds
a value that should have been traced, dict iteration reorders a pytree
between two programs that must agree leaf-for-leaf, ``lru_cache`` pins
device buffers and retraces per array identity.  Those live in the source,
so this analyzer walks the AST of every file under ``src/``.

Traced-function detection is necessarily heuristic; it is tuned to this
repo's idioms and errs toward *fewer* false positives (the jaxpr auditor
backstops what this misses):

- a function is **traced** when (a) it is decorated with a jax transform,
  (b) its *name* is passed to a jax transform (``jax.jit(f)``,
  ``lax.scan(body, …)``, ``pl.pallas_call(kern, …)``) — including through
  a tracked ``functools.partial`` assignment — or (c) it is *nested*
  inside another function and its body touches ``jnp.``/``lax.``/
  ``jax.random`` (the repo's round/step closures are all built this way);
- anything defined inside a traced function is traced too.

Suppression: ``# noqa`` or ``# noqa: PL004`` on the offending line (policy
in docs/analysis.md); suppressed hits are still reported, as "suppressed".
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Violation

#: callables that trace their function-valued arguments
TRANSFORMS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "scan", "cond",
    "while_loop", "switch", "fori_loop", "associative_scan", "map",
    "checkpoint", "remat", "custom_vjp", "custom_jvp", "pallas_call",
    "named_call", "make_jaxpr", "eval_shape",
}
#: attribute roots whose calls mean "this expression is traced-valued"
TRACED_MODULES = {"jnp", "lax"}
#: np.* helpers that are legitimate *static* host math on shapes/dtypes
NP_STATIC_SAFE = {
    "prod", "ceil", "floor", "log2", "sqrt", "dtype", "iinfo", "finfo",
    "float32", "float64", "int32", "int64", "bool_", "pi", "inf", "nan",
    "ndarray", "integer", "floating",
}
HOST_ESCAPES = {"float", "int", "bool"}


def _attr_root(node: ast.AST) -> Optional[str]:
    """'jnp' for jnp.sum, 'jax' for jax.lax.scan, None for bare names."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _attr_path(node: ast.AST) -> str:
    """Dotted path of an Attribute/Name chain ('jax.lax.scan')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_traced_value(node: ast.AST) -> bool:
    """Does this expression subtree *call into* jnp/lax/jax.random — i.e.
    is it tracer-valued beyond reasonable doubt?  (Attribute reads like
    ``x.ndim`` and bare names stay un-flagged: shapes and python values
    flow through the same source.)"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            path = _attr_path(sub.func)
            root = path.split(".")[0] if path else None
            if root in TRACED_MODULES:
                return True
            if path.startswith(("jax.numpy.", "jax.lax.", "jax.random.",
                                "jax.nn.")):
                return True
    return False


def _is_transform(func: ast.AST) -> bool:
    path = _attr_path(func)
    return bool(path) and path.split(".")[-1] in TRANSFORMS


class _FileLint(ast.NodeVisitor):
    """One file's lint pass: two sweeps — mark traced functions, then
    check their bodies."""

    def __init__(self, tree: ast.Module, rel: str, source: str):
        self.tree = tree
        self.rel = rel
        self.lines = source.splitlines()
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.partial_of: Dict[str, str] = {}   # var name -> wrapped fn name
        self.traced_names: Set[str] = set()
        self.hits: List[Violation] = []
        self.suppressed: List[Violation] = []

    # -- pass 1: which functions are traced ----------------------------------
    def collect_traced(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                path = _attr_path(node.value.func)
                if path.split(".")[-1] == "partial" and node.value.args:
                    inner = node.value.args[0]
                    if isinstance(inner, ast.Name):
                        for tgt in node.targets:
                            if isinstance(tgt, ast.Name):
                                self.partial_of[tgt.id] = inner.id
            if isinstance(node, ast.Call) and _is_transform(node.func):
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        self.traced_names.add(
                            self.partial_of.get(arg.id, arg.id))
                    elif (isinstance(arg, ast.Call)
                          and _attr_path(arg.func).split(".")[-1] == "partial"
                          and arg.args and isinstance(arg.args[0], ast.Name)):
                        self.traced_names.add(arg.args[0].id)

    def _is_traced_fn(self, fn: ast.FunctionDef) -> bool:
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _is_transform(target):
                return True
            # functools.partial(jax.jit, ...) as a decorator
            if (isinstance(dec, ast.Call)
                    and _attr_path(dec.func).split(".")[-1] == "partial"
                    and dec.args and _is_transform(dec.args[0])):
                return True
        if fn.name in self.traced_names:
            return True
        parent = self.parents.get(fn)
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_traced_value(fn):     # nested + touches jnp/lax => traced
                return True
            if self._is_traced_fn(parent):
                return True
        return False

    # -- pass 2: rules ---------------------------------------------------------
    def run(self) -> None:
        self.collect_traced()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.FunctionDef):
                self._check_lru_cache(node)
                if self._is_traced_fn(node):
                    self._check_traced_body(node)

    def _emit(self, code: str, fn_name: str, lineno: int, msg: str) -> None:
        v = Violation(code, f"{self.rel}::{fn_name}",
                      f"{self.rel}:{lineno}: {msg}")
        line = self.lines[lineno - 1] if lineno - 1 < len(self.lines) else ""
        if "# noqa" in line:
            tail = line.split("# noqa", 1)[1]
            if ":" not in tail or code in tail:
                self.suppressed.append(v)
                return
        self.hits.append(v)

    def _walk_own(self, fn: ast.FunctionDef):
        """fn's body without nested def subtrees — nested functions are
        traced by inheritance and get their own pass (no double-reports).
        Lambdas stay in: they never get a pass of their own."""
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(node))

    def _check_traced_body(self, fn: ast.FunctionDef) -> None:
        sorted_wrapped: Set[int] = set()
        for node in self._walk_own(fn):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id == "sorted"):
                for a in node.args:
                    sorted_wrapped.add(id(a))
        for node in self._walk_own(fn):
            # PL001 — python control flow on a traced test
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                if _is_traced_value(node.test):
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "conditional expression"}[type(node)]
                    self._emit("PL001", fn.name, node.lineno,
                               f"python {kind} on a traced expression in "
                               f"traced fn '{fn.name}' — use jnp.where/"
                               "lax.cond/lax.while_loop")
            if not isinstance(node, ast.Call):
                continue
            path = _attr_path(node.func)
            leaf = path.split(".")[-1] if path else ""
            # PL002 — host escapes
            if (isinstance(node.func, ast.Name)
                    and node.func.id in HOST_ESCAPES
                    and any(_is_traced_value(a) for a in node.args)):
                self._emit("PL002", fn.name, node.lineno,
                           f"{node.func.id}() on a traced value in traced "
                           f"fn '{fn.name}' forces a host sync (fails "
                           "under jit)")
            if leaf == "item" and isinstance(node.func, ast.Attribute):
                self._emit("PL002", fn.name, node.lineno,
                           f".item() in traced fn '{fn.name}' forces a "
                           "host sync (fails under jit)")
            # PL003 — numpy in traced code
            if (path.startswith("np.") or path == "np"
                    or path.startswith("numpy.")):
                attr = path.split(".", 1)[1] if "." in path else ""
                if attr.split(".")[0] not in NP_STATIC_SAFE:
                    self._emit("PL003", fn.name, node.lineno,
                               f"{path}(...) in traced fn '{fn.name}' "
                               "computes on host — constant-folds (wrong "
                               "under vmap/scan) or crashes on tracers; "
                               "use jnp")
            # PL004 — unordered dict iteration
            if (leaf in ("items", "values", "keys")
                    and isinstance(node.func, ast.Attribute)
                    and not node.args and not node.keywords
                    and id(node) not in sorted_wrapped):
                parent = self.parents.get(node)
                iterated = (
                    (isinstance(parent, ast.comprehension)
                     and parent.iter is node)
                    or (isinstance(parent, ast.For) and parent.iter is node))
                if iterated:
                    self._emit("PL004", fn.name, node.lineno,
                               f".{leaf}() iteration in traced fn "
                               f"'{fn.name}': dict order decides pytree "
                               "leaf order here — wrap in sorted(...)")

    def _check_lru_cache(self, fn: ast.FunctionDef) -> None:
        # PL005 — lru_cache over arrays
        cached = any(
            _attr_path(d.func if isinstance(d, ast.Call) else d)
            .split(".")[-1] in ("lru_cache", "cache")
            for d in fn.decorator_list)
        if not cached:
            return
        argnames = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            root = _attr_root(node.func)
            if root in TRACED_MODULES or _attr_path(node.func).startswith(
                    ("jax.numpy.", "jax.lax.")):
                used = {a.id for a in node.args if isinstance(a, ast.Name)}
                hit = used & argnames
                if hit:
                    self._emit(
                        "PL005", fn.name, fn.lineno,
                        f"lru_cache on '{fn.name}' whose arg(s) "
                        f"{sorted(hit)} feed jnp directly: caching by "
                        "array identity pins device buffers and defeats "
                        "the cache")
                    return
        annotated = [a for a in fn.args.args + fn.args.kwonlyargs
                     if a.annotation is not None
                     and ("Array" in ast.dump(a.annotation)
                          or "ndarray" in ast.dump(a.annotation))]
        if annotated:
            self._emit("PL005", fn.name, fn.lineno,
                       f"lru_cache on '{fn.name}' with array-annotated "
                       f"arg(s) {[a.arg for a in annotated]}")


def lint_file(path: Path, root: Path) -> Tuple[List[Violation], List[Violation]]:
    source = path.read_text()
    rel = str(path.relative_to(root))
    lint = _FileLint(ast.parse(source), rel, source)
    lint.run()
    return lint.hits, lint.suppressed


def lint_tree(src_root) -> Tuple[List[Violation], List[Violation], int]:
    """Lint every .py under ``src_root`` (the analyzers themselves included
    — protolint is host-side code and must pass its own rules).  Returns
    (violations, suppressed, files_scanned)."""
    root = Path(src_root).resolve()
    hits: List[Violation] = []
    suppressed: List[Violation] = []
    files = sorted(root.rglob("*.py"))
    for f in files:
        h, s = lint_file(f, root)
        hits.extend(h)
        suppressed.extend(s)
    return hits, suppressed, len(files)


def lint_source(source: str, name: str = "<snippet>") -> List[Violation]:
    """Lint a source string — the golden-test entry point."""
    lint = _FileLint(ast.parse(source), name, source)
    lint.run()
    return lint.hits
