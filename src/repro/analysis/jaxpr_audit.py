"""Static jaxpr audit of the engine programs (rules JX001-JX007).

Works on the :class:`~repro.analysis.programs.TracedProgram` registry —
the engine's real entry-point programs traced (never run) to ClosedJaxprs
— and walks every equation recursively (pjit / scan / cond / while /
pallas_call sub-jaxprs included) enforcing:

JX001  no 64-bit value anywhere on the hot path (an f64 sneaking in
       doubles wire and memory cost silently and breaks kernel tiling).
JX002  no weak-type hazard: a weak python constant materialized into a
       rank>=1 buffer (``jnp.maximum(x, 1e-30)`` and friends — the classic
       source of avoidable retraces and silent upcasts), or a weak program
       output escaping to callers.
JX003  no host callback / debug print compiled into a program (a stray
       ``jax.debug.print`` serializes the scan on every round).
JX004  no dynamic or data-dependent shapes (every dim a python int).
JX005  collectives only on mesh axes the program declares (a collective
       on an undeclared axis means a program silently depends on being
       run under some *other* transform's axis).
JX006  declared buffer donation honored: the lowered scan program aliases
       at least the declared number of inputs to outputs
       (``tf.aliasing_output`` in the StableHLO text).
JX007  retrace fingerprint stable across lane-value variants: variants of
       one program that differ only in traced values must produce
       bit-identical program structure — the no-recompile contract the
       whole campaign design rests on.

Violation messages carry ``file:line`` from the equation's source info, so
a firing names the offending engine line, not just the program.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Optional, Tuple

import jax

from repro.analysis import programs as programs_mod
from repro.analysis.programs import DonationUnit, TracedProgram, TracedUnit
from repro.analysis.report import Violation

#: dtypes JX001 bans from every traced program (x64 should never be on).
_WIDE_DTYPES = frozenset({"float64", "int64", "uint64", "complex128"})

#: primitive names that are host escapes (JX003).  Matched exactly plus a
#: ``callback`` substring net — jax has renamed these across versions.
_CALLBACK_PRIMS = frozenset({"debug_print", "infeed", "outfeed",
                             "outside_call"})

#: the marker XLA puts on a donated-and-honored input in StableHLO.
_ALIAS_MARKER = "tf.aliasing_output"


def iter_eqns(jaxpr) -> Iterator:
    """Every equation of ``jaxpr``, recursing into sub-jaxprs carried in
    equation params (pjit/scan/while/cond/custom_*/pallas_call)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: dict) -> Iterator:
    for v in params.values():
        for sub in _as_jaxprs(v):
            yield sub


def _as_jaxprs(v) -> Iterator:
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _as_jaxprs(x)


def _src(eqn) -> str:
    """``file:line`` of the user frame that produced this equation."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def _aval_dtype(aval) -> str:
    try:
        return str(aval.dtype)
    except Exception:        # abstract tokens / key arrays without .dtype
        return ""


# ---------------------------------------------------------------------------
# fingerprint (JX007)
# ---------------------------------------------------------------------------
def fingerprint(closed: jax.core.ClosedJaxpr) -> str:
    """Structural digest of a traced program: input/output avals, const
    avals, and the recursive (primitive, output-aval) sequence.  Equation
    *params* are deliberately excluded — they embed device-dependent
    objects (shardings, compiler options) that vary without retracing —
    but every sub-jaxpr's shapes and primitives are in, which is what a
    retrace would actually change."""
    h = hashlib.sha256()
    for aval in closed.in_avals:
        h.update(str(aval).encode())
    for aval in closed.out_avals:
        h.update(str(aval).encode())
    for c in closed.consts:
        h.update(f"{getattr(c, 'shape', ())}/{getattr(c, 'dtype', '?')}"
                 .encode())
    for eqn in iter_eqns(closed.jaxpr):
        h.update(eqn.primitive.name.encode())
        for v in eqn.outvars:
            h.update(str(v.aval).encode())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# per-unit rules
# ---------------------------------------------------------------------------
def _audit_unit(prog: str, unit: TracedUnit) -> List[Violation]:
    where = f"{prog}::{unit.label}"
    out: List[Violation] = []
    closed = unit.closed

    # JX002b: weak program outputs escape to callers, poisoning downstream
    # dtype promotion with context-dependent types
    weak_out = [str(a) for a in closed.out_avals
                if getattr(a, "weak_type", False)]
    if weak_out:
        out.append(Violation(
            "JX002", where,
            f"{len(weak_out)} weak-typed program output(s): "
            f"{', '.join(weak_out[:4])}"))

    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        # JX001 — 64-bit values
        for v in eqn.outvars:
            if _aval_dtype(v.aval) in _WIDE_DTYPES:
                out.append(Violation(
                    "JX001", where,
                    f"{name} produces {v.aval} at {_src(eqn)}"))
        # JX002a — weak constant materialized into a buffer: a python
        # scalar broadcast to rank>=1 keeps its weak type on the buffer
        if name == "broadcast_in_dim":
            for v in eqn.outvars:
                if (getattr(v.aval, "weak_type", False)
                        and getattr(v.aval, "ndim", 0) >= 1):
                    out.append(Violation(
                        "JX002", where,
                        f"weak python constant broadcast into {v.aval} "
                        f"at {_src(eqn)} — wrap the literal in "
                        f"jnp.<dtype>(...) so the buffer dtype is explicit"))
        # JX003 — host callbacks / debug prints
        if name in _CALLBACK_PRIMS or "callback" in name:
            out.append(Violation(
                "JX003", where,
                f"host-callback primitive '{name}' compiled into the "
                f"program at {_src(eqn)}"))
        # JX004 — dynamic shapes (every dim must be a concrete python int)
        for v in eqn.outvars:
            dims = getattr(v.aval, "shape", ())
            if not all(isinstance(d, int) for d in dims):
                out.append(Violation(
                    "JX004", where,
                    f"{name} output has non-static shape {dims} "
                    f"at {_src(eqn)}"))
        # JX005 — collectives only on declared mesh axes.  Axis names bound
        # by vmap are fresh non-str objects; only str names survive to the
        # compiled program and must come from the declared mesh.
        for key in ("axes", "axis_name"):
            if key not in eqn.params:
                continue
            names = eqn.params[key]
            if not isinstance(names, (tuple, list)):
                names = (names,)
            for ax in names:
                if isinstance(ax, str) and ax not in unit.declared_axes:
                    out.append(Violation(
                        "JX005", where,
                        f"collective '{name}' on undeclared axis "
                        f"{ax!r} at {_src(eqn)} (declared: "
                        f"{sorted(unit.declared_axes) or 'none'})"))
    return out


def _audit_donation(prog: str, don: DonationUnit) -> List[Violation]:
    n = don.lowered_text.count(_ALIAS_MARKER)
    if n >= don.min_aliases:
        return []
    return [Violation(
        "JX006", f"{prog}::{don.label}",
        f"lowered program aliases {n} buffer(s), expected >= "
        f"{don.min_aliases} (opt-state + slashed + contrib must be "
        f"donated — a dead copy of the optimizer state would live for "
        f"the whole campaign)")]


def _audit_fingerprints(prog: TracedProgram) -> List[Violation]:
    groups: Dict[str, List[Tuple[str, str]]] = {}
    for unit in prog.units:
        if unit.group is not None:
            groups.setdefault(unit.group, []).append(
                (unit.label, fingerprint(unit.closed)))
    out = []
    for group, pairs in groups.items():
        digests = {d for _, d in pairs}
        if len(digests) > 1:
            detail = ", ".join(f"{label}={d}" for label, d in pairs)
            out.append(Violation(
                "JX007", f"{prog.name}::{group}",
                f"variants that must share one compiled program trace to "
                f"different structures: {detail}"))
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def audit_program(prog: TracedProgram) -> List[Violation]:
    out: List[Violation] = []
    for unit in prog.units:
        out.extend(_audit_unit(prog.name, unit))
    for don in prog.donations:
        out.extend(_audit_donation(prog.name, don))
    out.extend(_audit_fingerprints(prog))
    return out


def audit_all(progs: Optional[List[TracedProgram]] = None,
              ) -> Tuple[List[Violation], Dict[str, int]]:
    """Audit every registered engine program.  Returns ``(violations,
    {program name: unit count})``."""
    if progs is None:
        progs = programs_mod.build_all()
    violations: List[Violation] = []
    summary: Dict[str, int] = {}
    for prog in progs:
        violations.extend(audit_program(prog))
        summary[prog.name] = len(prog.units)
    return violations, summary
