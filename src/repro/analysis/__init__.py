"""protolint — static analysis over the engine's *programs*, not its runs.

The paper's protocol setting (§4 verification, §5.5 no-off) needs program
properties that a participant can audit without trusting the operator.  The
test suite proves those properties dynamically; this package proves the
static half before anything runs, with three analyzers over three artifact
layers:

- :mod:`repro.analysis.jaxpr_audit` — walks the ClosedJaxprs of the real
  engine entry points (:mod:`repro.analysis.programs`) enforcing JX001…:
  no f64 on the hot path, no weak-typed constants materialized into
  buffers, no host callbacks, no dynamic shapes, declared donation
  actually aliased, collectives only on declared mesh axes, and a
  retrace fingerprint stable across churn/load lane variants — the
  no-recompile contract as a static property.
- :mod:`repro.analysis.pallas_check` — symbolically evaluates every
  kernel's BlockSpec index maps over its full grid (PK001…): tiles cover
  the output, never exceed the padded bounds, the VMEM tile footprint
  stays under budget (cross-checked against ``launch/roofline.py``), and
  tiled feature dims honor the lane-multiple padding contract.
- :mod:`repro.analysis.tracer_lint` — an AST lint over ``src/`` (PL001…)
  for the tracer hazards jaxprs can't show: python control flow on traced
  values, host escapes, ``np.`` calls, unordered dict iteration in
  pytree-order-sensitive code, ``lru_cache`` holding live arrays.

CLI: ``python -m repro.analysis --json`` (see :mod:`repro.analysis.__main__`);
rule catalog and suppression policy in ``docs/analysis.md``.
"""
from repro.analysis.report import RULES, Report, Violation  # noqa: F401
