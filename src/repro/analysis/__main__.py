"""protolint CLI — ``python -m repro.analysis [--json [PATH]] [--baseline P]``.

Runs all three analyzers:

1. ``jaxpr_audit`` over the five engine programs (round fused/unfused,
   campaign, sweep, serve scan) — rules JX001-JX007,
2. ``pallas_check`` over every registered kernel probe — rules PK001-PK004,
3. ``tracer_lint`` over ``src/`` — rules PL001-PL005,

applies the checked-in baseline (``baseline.json`` next to this package;
stale entries fire PL000), prints a human summary, and exits non-zero if
any non-baselined violation remains.  ``--json`` writes the full machine
report (violations, suppressions, baseline hits, per-analyzer summary) to
stdout or to the given path — the artifact the CI gate uploads.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.analysis import jaxpr_audit, pallas_check, tracer_lint
from repro.analysis.report import RULES, Report, load_baseline


def build_report(src_root=None) -> Report:
    report = Report()
    t0 = time.time()

    violations, programs = jaxpr_audit.audit_all()
    report.extend(violations)
    report.summary["programs"] = programs
    t1 = time.time()

    violations, kernels = pallas_check.check_all()
    report.extend(violations)
    report.summary["kernels"] = kernels
    t2 = time.time()

    root = (Path(src_root) if src_root is not None
            else Path(__file__).resolve().parents[1])
    violations, suppressed, n_files = tracer_lint.lint_tree(root)
    report.extend(violations)
    report.suppressed.extend(suppressed)
    report.summary["linted_files"] = n_files
    report.summary["seconds"] = {
        "jaxpr_audit": round(t1 - t0, 2),
        "pallas_check": round(t2 - t1, 2),
        "tracer_lint": round(time.time() - t2, 2),
    }
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis gate: jaxpr audit + Pallas kernel "
                    "check + tracer lint")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="write the JSON report to PATH ('-' or no value "
                         "= stdout)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="baseline file (default: the checked-in "
                         "baseline.json)")
    ap.add_argument("--src", default=None, metavar="DIR",
                    help="source root for tracer_lint (default: the "
                         "installed repro package)")
    args = ap.parse_args(argv)

    report = build_report(src_root=args.src)
    report.apply_baseline(load_baseline(args.baseline))

    if args.json is not None:
        text = report.to_json()
        if args.json == "-":
            print(text)
        else:
            Path(args.json).write_text(text + "\n")

    out = sys.stderr if args.json == "-" else sys.stdout
    s = report.summary
    print(f"protolint: audited {len(s.get('programs', {}))} engine "
          f"programs ({sum(s.get('programs', {}).values())} traced "
          f"variants), {len(s.get('kernels', {}))} kernels "
          f"({sum(s.get('kernels', {}).values())} pallas_call sites), "
          f"{s.get('linted_files', 0)} source files", file=out)
    for v in report.violations:
        print(f"  FAIL {v.key}: {v.message}", file=out)
        print(f"       rule: {RULES.get(v.code, '?')}", file=out)
    for v in report.baselined:
        print(f"  baselined {v.key}", file=out)
    if report.suppressed:
        print(f"  ({len(report.suppressed)} noqa-suppressed lint "
              f"findings)", file=out)
    print(("OK — no violations" if report.ok
           else f"{len(report.violations)} violation(s)"), file=out)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
