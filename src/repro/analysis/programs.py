"""The audited engine programs — traced, never run.

This module builds the *real* entry-point programs of the engine (the same
builders ``run_campaign`` / ``derailment.sweep`` / ``ServingEngine`` execute
— not reimplementations that could drift) against tiny probe problems, and
hands ``jaxpr_audit`` their :class:`jax.core.ClosedJaxpr`.  Seven programs:

``round_unfused`` / ``round_fused``
    ``swarm.make_round_fn`` in both hot-path modes, plus the scanned-run
    donation unit (``make_scan_program`` lowered text for JX006).
``round_async``
    the bounded-staleness round (``staleness_bound=K``): delay-schedule
    variants share one fingerprint, the K+1-snapshot ring is donated
    through the scan, and the staleness-axis *campaign* (two
    ``build_sweep_lanes`` value-variant grids) fingerprints stably.
``campaign``
    ``swarm.make_campaign_program`` — the jit(vmap(scan)) phase-diagram
    program, with value-variants (base / churn / attack) that must share a
    retrace fingerprint, and a :class:`~repro.core.placement.MeshPlan`
    variant (its own fingerprint group: ``spmd_axis_name`` and placement
    legitimately change the jaxpr) that declares its mesh axes for JX005.
``sweep``
    ``derailment.build_sweep_lanes`` feeding ``make_campaign_program`` —
    the multi-aggregator fused phase-diagram program, with two grids
    differing only in seed/scale values (one fingerprint group).
``economy``
    the incentive phase diagram: ``build_sweep_lanes`` over economy axes
    (identity cost / fee / reward schedule / fixed-vs-adaptive) feeding
    ``make_campaign_program`` — knob-value-variant grids share one
    fingerprint, and the scanned economy run donates the ``EconState``
    carry next to opt_state.
``serve_step``
    ``ServingEngine.program`` — the custody-gated continuous-batching
    scan, vmapped over a stacked lane campaign, with load / churn lane
    variants (one fingerprint group).

Everything here is shape-tiny so tracing stays sub-second; the invariants
audited (dtypes, primitives, donation, axis names, fingerprint stability)
do not depend on problem size.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import derailment, economy, serving, swarm
from repro.core.economy import EconomyConfig
from repro.core.placement import MeshPlan
from repro.core.scenarios import Regime, SweepGrid
from repro.core.swarm import NodeSpec, SwarmConfig
from repro.core.unextractable import assign_matrix
from repro.core.verification import VerificationConfig
from repro.optim.optimizer import SGD


@dataclass(frozen=True)
class TracedUnit:
    """One traced variant of a program: a ClosedJaxpr plus audit context.

    ``group`` names the retrace-fingerprint group: every unit sharing a
    group must produce an identical fingerprint (JX007) — they are the
    lane-value variants one compiled program is contractually required to
    serve without retracing.  ``declared_axes`` are the mesh axis names
    collectives may legally use (JX005); empty = no collectives allowed.
    """
    label: str
    closed: jax.core.ClosedJaxpr
    group: Optional[str] = None
    declared_axes: FrozenSet[str] = frozenset()


@dataclass(frozen=True)
class DonationUnit:
    """A lowered program whose declared buffer donation JX006 verifies:
    ``lowered_text`` must contain at least ``min_aliases`` occurrences of
    ``tf.aliasing_output`` (one per donated input buffer)."""
    label: str
    lowered_text: str
    min_aliases: int


@dataclass
class TracedProgram:
    name: str
    units: List[TracedUnit]
    donations: List[DonationUnit] = field(default_factory=list)


# ---------------------------------------------------------------------------
# tiny probe problems
# ---------------------------------------------------------------------------
def _tiny_problem(d: int = 8):
    """A d-dim linear regression — the smallest loss with a real gradient
    path, shared by the round/campaign/sweep probes."""
    params = {"w": jnp.zeros((d,), jnp.float32)}
    w_true = jnp.arange(d, dtype=jnp.float32) / d

    def data_fn(node_idx, rnd):
        k = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(17), node_idx), rnd)
        x = jax.random.normal(k, (4, d))
        return {"x": x, "y": x @ w_true}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def eval_fn(p):
        x = jax.random.normal(jax.random.PRNGKey(3), (16, d))
        return jnp.mean((x @ p["w"] - x @ w_true) ** 2)

    return params, loss_fn, data_fn, eval_fn


def _roster(n: int, *, churn: bool = False, attack: bool = False):
    nodes = [NodeSpec(node_id=f"n{i}") for i in range(n)]
    if churn:
        nodes[1] = NodeSpec(node_id="n1", join_round=1)
        nodes[2] = NodeSpec(node_id="n2", leave_round=2)
    if attack:
        nodes[-1] = NodeSpec(node_id=f"n{n - 1}", byzantine="sign_flip",
                             byzantine_scale=5.0)
    return nodes


def _batch_fn(data_fn, n):
    def batch_fn(rnd):
        return jax.vmap(lambda i: data_fn(i, rnd))(jnp.arange(n))
    return batch_fn


# ---------------------------------------------------------------------------
# round programs (unfused / fused) + donation units
# ---------------------------------------------------------------------------
def _round_program(name: str, *, fused: bool) -> TracedProgram:
    n, d = 4, (128 if fused else 8)   # fused wire is bucketed per lane-width
    params, loss_fn, data_fn, _ = _tiny_problem(d)
    opt = SGD(lr=0.05)
    kind, ckw = (("qsgd", {"levels": 64}) if fused else (None, None))
    round_fn = swarm.make_round_fn(
        loss_fn, opt, params, n, aggregator="centered_clip",
        compression_kind=kind, compression_kwargs=ckw, verify=True,
        fused=fused)
    batch_fn = _batch_fn(data_fn, n)
    state0 = swarm.init_state(params, opt, n)
    cfg = SwarmConfig(verification=VerificationConfig(p_check=0.5))

    units = []
    for label, roster in (("base", _roster(n)),
                          ("churn", _roster(n, churn=True)),
                          ("attack", _roster(n, attack=True))):
        lane = swarm.lane_for_nodes(roster, cfg)
        closed = jax.make_jaxpr(round_fn)(
            lane, state0, jnp.asarray(0, jnp.int32), batch_fn(0))
        units.append(TracedUnit(label, closed, group=name))

    # the scanned-run program donates opt_state + slashed + contrib — one
    # aliased output per donated leaf (SGDState: step + per-param momentum)
    lane = swarm.lane_for_nodes(_roster(n), cfg)
    scan_fn = swarm.make_scan_program(round_fn, batch_fn, rounds=3)
    lowered = scan_fn.lower(lane, state0.params, state0.opt_state,
                            state0.slashed, state0.contrib).as_text()
    min_aliases = len(jax.tree.leaves(state0.opt_state)) + 2
    return TracedProgram(name, units,
                         donations=[DonationUnit("scan", lowered, min_aliases)])


def build_round_unfused() -> TracedProgram:
    return _round_program("round_unfused", fused=False)


def build_round_fused() -> TracedProgram:
    return _round_program("round_fused", fused=True)


# ---------------------------------------------------------------------------
# campaign program (value variants + mesh variant)
# ---------------------------------------------------------------------------
def _campaign_lanes(cfg: SwarmConfig, n: int, variant: str):
    rosters = {
        "base": [_roster(n), _roster(n), _roster(n)],
        "churn": [_roster(n), _roster(n, churn=True), _roster(n, churn=True)],
        "attack": [_roster(n, attack=True), _roster(n), _roster(n, attack=True)],
    }[variant]
    return swarm.stack_lanes([swarm.lane_for_nodes(r, cfg) for r in rosters])


def build_campaign() -> TracedProgram:
    n = 4
    params, loss_fn, data_fn, eval_fn = _tiny_problem()
    opt = SGD(lr=0.05)
    cfg = SwarmConfig()
    lanes = _campaign_lanes(cfg, n, "base")
    fn = swarm.make_campaign_program(
        loss_fn, params, opt, data_fn, lanes, rounds=2,
        aggregator="centered_clip", eval_fn=eval_fn)

    units = []
    for variant in ("base", "churn", "attack"):
        closed = jax.make_jaxpr(fn)(_campaign_lanes(cfg, n, variant))
        units.append(TracedUnit(variant, closed, group="campaign"))

    # mesh variant: same campaign under an explicit MeshPlan — placement and
    # spmd_axis_name legitimately change the jaxpr, so it gets its OWN
    # fingerprint group, and declares the axes its collectives may use
    plan = MeshPlan.for_lanes(3)
    placed = plan.place_lanes(_campaign_lanes(cfg, n, "base"))
    mesh_fn = swarm.make_campaign_program(
        loss_fn, plan.place_params(params), opt, data_fn, placed, rounds=2,
        aggregator="centered_clip", eval_fn=eval_fn, plan=plan)
    with plan.mesh:
        closed = jax.make_jaxpr(mesh_fn)(placed)
    units.append(TracedUnit(
        "mesh", closed, group="campaign_mesh",
        declared_axes=frozenset(
            {plan.lanes_axis, plan.data_axis, plan.model_axis})))
    return TracedProgram("campaign", units)


# ---------------------------------------------------------------------------
# sweep program (derailment phase diagram)
# ---------------------------------------------------------------------------
def _sweep_grid(seed: int, scale: float) -> SweepGrid:
    return SweepGrid(
        name=f"audit_probe_{seed}",
        description="tiny two-regime probe grid for the static audit",
        regimes=(Regime("mean", "mean"),
                 Regime("cc+audit", "centered_clip",
                        verification=VerificationConfig(p_check=0.5))),
        n_honest=3, attacker_counts=(1,), seeds=(seed,), scales=(scale,),
        rounds=2)


def build_sweep() -> TracedProgram:
    params, loss_fn, data_fn, eval_fn = _tiny_problem()
    opt = SGD(lr=0.05)
    spec0 = derailment.build_sweep_lanes(_sweep_grid(0, 10.0), rounds=2)
    fn = swarm.make_campaign_program(
        loss_fn, params, opt, data_fn, swarm.stack_lanes(spec0.lanes),
        rounds=2, aggregator=spec0.aggregator, agg_kwargs=spec0.agg_kwargs,
        verify=spec0.verify, eval_fn=eval_fn)

    units = []
    for label, (seed, scale) in (("base", (0, 10.0)), ("shifted", (1, 50.0))):
        spec = derailment.build_sweep_lanes(_sweep_grid(seed, scale), rounds=2)
        closed = jax.make_jaxpr(fn)(swarm.stack_lanes(spec.lanes))
        units.append(TracedUnit(label, closed, group="sweep"))
    return TracedProgram("sweep", units)


# ---------------------------------------------------------------------------
# async round program (bounded-staleness ring)
# ---------------------------------------------------------------------------
def _async_grid(seed: int, scale: float) -> SweepGrid:
    return SweepGrid(
        name=f"audit_async_{seed}",
        description="tiny staleness-axis probe grid for the static audit",
        regimes=(Regime("cc", "centered_clip"),),
        n_honest=3, attacker_counts=(1,), seeds=(seed,), scales=(scale,),
        staleness_bounds=(0, 2), rounds=2)


def build_round_async() -> TracedProgram:
    """The bounded-staleness async round (``swarm.make_round_fn`` with
    ``staleness_bound=K``): the K+1-snapshot ring must keep static shapes
    (JX001-004), be donated through the scanned run next to opt_state
    (JX006), and hold one retrace fingerprint across delay-schedule
    variants (JX007) — plus the async *campaign* (the staleness-axis sweep
    via ``derailment.build_sweep_lanes``), whose two value-variant grids
    share a fingerprint the same way the sync sweep's do."""
    n, K = 4, 2
    params, loss_fn, data_fn, eval_fn = _tiny_problem()
    opt = SGD(lr=0.05)
    round_fn = swarm.make_round_fn(
        loss_fn, opt, params, n, aggregator="centered_clip", verify=True,
        staleness_bound=K)
    batch_fn = _batch_fn(data_fn, n)
    state0 = swarm.init_state(params, opt, n, staleness_bound=K)
    cfg = SwarmConfig(verification=VerificationConfig(p_check=0.5),
                      staleness_bound=K)

    def stale(nodes, jitter: int = 0):
        return [replace(nd, delay=(i + jitter) % (K + 1))
                for i, nd in enumerate(nodes)]

    units = []
    for label, roster in (("base", stale(_roster(n))),
                          ("churn", stale(_roster(n, churn=True))),
                          ("attack", stale(_roster(n, attack=True))),
                          ("jitter", stale(_roster(n), jitter=1))):
        lane = swarm.lane_for_nodes(roster, cfg)
        closed = jax.make_jaxpr(round_fn)(
            lane, state0, jnp.asarray(0, jnp.int32), batch_fn(0))
        units.append(TracedUnit(label, closed, group="round_async"))

    # the async campaign: both probe grids carry staleness_bounds=(0, 2),
    # so the compiled ring has the same K and the jaxprs must coincide
    fn = None
    for label, (seed, scale) in (("sweep_base", (0, 10.0)),
                                 ("sweep_shifted", (1, 50.0))):
        spec = derailment.build_sweep_lanes(_async_grid(seed, scale), rounds=2)
        if fn is None:
            fn = swarm.make_campaign_program(
                loss_fn, params, opt, data_fn, swarm.stack_lanes(spec.lanes),
                rounds=2, aggregator=spec.aggregator,
                agg_kwargs=spec.agg_kwargs, verify=spec.verify,
                eval_fn=eval_fn)
        closed = jax.make_jaxpr(fn)(swarm.stack_lanes(spec.lanes))
        units.append(TracedUnit(label, closed, group="campaign_async"))

    # the scanned async run donates the ring buffer next to opt_state +
    # slashed + contrib — one aliased output per donated leaf
    lane = swarm.lane_for_nodes(stale(_roster(n)), cfg)
    scan_fn = swarm.make_scan_program(round_fn, batch_fn, rounds=3)
    lowered = scan_fn.lower(lane, state0.params, state0.opt_state,
                            state0.slashed, state0.contrib,
                            state0.ring).as_text()
    min_aliases = (len(jax.tree.leaves(state0.opt_state)) + 2
                   + len(jax.tree.leaves(state0.ring)))
    return TracedProgram("round_async", units,
                         donations=[DonationUnit("scan", lowered, min_aliases)])


# ---------------------------------------------------------------------------
# economy program (incentive phase diagram)
# ---------------------------------------------------------------------------
def _econ_grid(seed: int, icost: float, fee: float) -> SweepGrid:
    return SweepGrid(
        name=f"audit_econ_{seed}",
        description="tiny incentive-axis probe grid for the static audit",
        regimes=(Regime("mean+audit", "mean",
                        verification=VerificationConfig(p_check=0.5)),),
        n_honest=3, attacker_counts=(1,), seeds=(seed,), scales=(2.0,),
        rounds=2, identity_costs=(icost,), fees=(fee,),
        reward_schedules=((0.1, 5.0),), adaptive=(False, True))


def build_economy() -> TracedProgram:
    """The economy campaign (incentive axes as traced lane data): every
    knob — identity cost, fee income, reward schedule, jackpot, and the
    fixed-vs-adaptive switch — rides in ``EconParams``, so probe grids that
    differ only in knob *values* must share one retrace fingerprint
    (JX007), and the scanned economy run donates the ``EconState`` carry
    (stakes, balances, escrow, pool/income counters) next to opt_state
    through the scan (JX006)."""
    n = 4
    params, loss_fn, data_fn, eval_fn = _tiny_problem()
    opt = SGD(lr=0.05)

    units = []
    fn = None
    for label, (seed, icost, fee) in (("base", (0, 1.0, 1.0)),
                                      ("shifted", (1, 8.0, 0.25))):
        spec = derailment.build_sweep_lanes(_econ_grid(seed, icost, fee),
                                            rounds=2)
        if fn is None:
            fn = swarm.make_campaign_program(
                loss_fn, params, opt, data_fn, swarm.stack_lanes(spec.lanes),
                rounds=2, aggregator=spec.aggregator,
                agg_kwargs=spec.agg_kwargs, verify=spec.verify,
                eval_fn=eval_fn)
        closed = jax.make_jaxpr(fn)(swarm.stack_lanes(spec.lanes))
        units.append(TracedUnit(label, closed, group="campaign_economy"))

    # the scanned economy run donates the EconState carry next to
    # opt_state + slashed + contrib — one aliased output per donated leaf
    cfg = SwarmConfig(verification=VerificationConfig(p_check=0.5),
                      economy=EconomyConfig(adaptive=True))
    lane = swarm.lane_for_nodes(_roster(n, attack=True), cfg)
    round_fn = swarm.make_round_fn(loss_fn, opt, params, n,
                                   aggregator="mean", verify=True)
    batch_fn = _batch_fn(data_fn, n)
    state0 = swarm.init_state(params, opt, n,
                              econ=economy.init_econ_state(lane.econ, n))
    scan_fn = swarm.make_scan_program(round_fn, batch_fn, rounds=3)
    lowered = scan_fn.lower(lane, state0.params, state0.opt_state,
                            state0.slashed, state0.contrib, state0.ring,
                            state0.econ).as_text()
    min_aliases = (len(jax.tree.leaves(state0.opt_state)) + 2
                   + len(jax.tree.leaves(state0.econ)))
    return TracedProgram("economy", units,
                         donations=[DonationUnit("scan", lowered, min_aliases)])


# ---------------------------------------------------------------------------
# serving program (custody-gated continuous batching)
# ---------------------------------------------------------------------------
def _serve_lane(custody: np.ndarray, steps: int, variant: str):
    kw = {"load": 1.0} if variant == "load" else {
        "load": 2.0, "churn_rate": 0.5, "coalition_fraction": 0.25,
        "defect_step": steps // 2}
    return serving.build_lane(
        n_requests=6, prompt_lens=[6, 4, 5, 6, 3, 4], max_new=4,
        steps=steps, n_nodes=4, balances=[8.0, 8.0, 1.0], fee=1.0,
        custody=custody, **kw)


def build_serve_step() -> TracedProgram:
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config("protocol-125m").reduced(
        num_layers=1, d_model=32, num_heads=2, head_dim=16, d_ff=64,
        vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (6, 6), 0,
                                 cfg.vocab_size)
    scfg = serving.ServingConfig(slots=3, max_new=4, steps=20)
    engine = serving.ServingEngine(model, scfg, prompts)
    fn = engine.program(has_custody=True, vmapped=True)
    custody = assign_matrix(4, 8, 2, 0, 0.5)

    units = []
    for variant in ("load", "churn"):
        lanes = serving.stack_serve_lanes(
            [_serve_lane(custody, scfg.steps, variant),
             _serve_lane(custody, scfg.steps, variant)])
        closed = jax.make_jaxpr(fn)(params, prompts, lanes)
        units.append(TracedUnit(variant, closed, group="serve"))
    return TracedProgram("serve_step", units)


#: name -> builder, in audit order.  ``build_all`` is what the CLI and the
#: integration test iterate; each builder is independent so golden tests
#: can trace one program without paying for the rest.
PROGRAM_BUILDERS: Dict[str, Callable[[], TracedProgram]] = {
    "round_unfused": build_round_unfused,
    "round_fused": build_round_fused,
    "round_async": build_round_async,
    "campaign": build_campaign,
    "sweep": build_sweep,
    "economy": build_economy,
    "serve_step": build_serve_step,
}


def build_all() -> List[TracedProgram]:
    return [build() for build in PROGRAM_BUILDERS.values()]
