"""PK-rules: symbolic evaluation of every kernel's BlockSpec index maps.

A Pallas kernel's correctness story starts before its body runs: the
BlockSpec index maps decide which tile each grid point touches, and a map
that skips a tile, runs past the padded bounds, or asks for more VMEM than
a core has fails only on real hardware — CPU ``interpret=True`` tests
cannot see it.  This analyzer makes those properties static: it intercepts
``pl.pallas_call`` (recording grid, specs, arg shapes — the kernel body
never executes), drives each kernel's public ``*_fwd`` wrapper at
representative shapes, and evaluates every index map over the *full* grid
with python ints.

- **PK001** every output tile must be visited: the union of visited block
  indices must cover ``ceil(dim/block)`` per dimension (inputs may
  legitimately be read partially; outputs may legitimately be revisited —
  accumulator kernels do).
- **PK002** no tile may extend past the (padded) array bounds in any
  dimension, for inputs and outputs both.
- **PK003** the per-grid-point VMEM tile footprint — every in/out block
  double-buffered, plus scratch — must fit the per-kernel budget,
  default :data:`repro.launch.roofline.VMEM_BYTES` (the same constant the
  roofline model uses, so the two can never drift apart).
- **PK004** a *tiled* trailing (feature) dim must stay lane-multiple: if a
  block tiles the last axis of an array whose trailing dim is >= one lane
  (128), the block's trailing extent must be a multiple of 128 — the
  padding contract ``masked_agg._pad_lanes`` exists to guarantee.
  Sub-lane arrays (e.g. per-bucket norms) are out of scope by
  construction, not exemption.
"""
from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Violation
from repro.launch.roofline import VMEM_BYTES

LANE = 128
_GRID_POINT_CAP = 65536          # probes are tiny; a blowup is a probe bug


@dataclass
class CapturedCall:
    """One intercepted ``pl.pallas_call``: everything the checks need."""
    kernel: str                    # registry name
    index: int                     # nth pallas_call of this probe
    grid: Tuple[int, ...]
    in_specs: List[object]
    out_specs: List[object]
    in_shapes: List[Tuple[Tuple[int, ...], int]]    # (shape, itemsize)
    out_shapes: List[Tuple[Tuple[int, ...], int]]
    scratch_bytes: int
    num_scalar_prefetch: int

    def label(self, kind: str, i: int) -> str:
        return f"{self.kernel}[{self.index}].{kind}{i}"


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    return tuple(x) if isinstance(x, (tuple, list)) else (x,)


def _scratch_bytes(shapes) -> int:
    total = 0
    for s in _as_tuple(shapes):
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        if shape is not None and dtype is not None:
            total += int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    return total


@contextlib.contextmanager
def capture_pallas_calls(records: List[CapturedCall], kernel: str):
    """Swap ``pl.pallas_call`` for a recorder that returns zeros of
    ``out_shape`` — kernel wrappers run their real pre/post reshapes while
    the device call itself is captured, not executed."""
    from jax.experimental import pallas as pl

    real = pl.pallas_call
    counter = itertools.count()

    def fake(kern, *pargs, out_shape=None, grid_spec=None, grid=None,
             in_specs=None, out_specs=None, scratch_shapes=(), **kw):
        if out_shape is None and pargs:
            out_shape, pargs = pargs[0], pargs[1:]
        nsp = 0
        if grid_spec is not None:
            grid = grid_spec.grid
            in_specs = grid_spec.in_specs
            out_specs = grid_spec.out_specs
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0))
            scratch_shapes = getattr(grid_spec, "scratch_shapes",
                                     scratch_shapes)
        outs = _as_tuple(out_shape)
        idx = next(counter)

        def runner(*args):
            blocks = args[nsp:]           # scalar-prefetch args have no spec
            records.append(CapturedCall(
                kernel=kernel, index=idx,
                grid=tuple(int(g) for g in _as_tuple(grid)),
                in_specs=list(_as_tuple(in_specs)),
                out_specs=list(_as_tuple(out_specs)),
                in_shapes=[(tuple(a.shape), jnp.dtype(a.dtype).itemsize)
                           for a in blocks],
                out_shapes=[(tuple(o.shape), jnp.dtype(o.dtype).itemsize)
                            for o in outs],
                scratch_bytes=_scratch_bytes(scratch_shapes),
                num_scalar_prefetch=nsp))
            zeros = [jnp.zeros(o.shape, o.dtype) for o in outs]
            if isinstance(out_shape, (tuple, list)):
                return type(out_shape)(zeros)
            return zeros[0]

        return runner

    pl.pallas_call = fake
    try:
        yield
    finally:
        pl.pallas_call = real


# ------------------------------- the checks -----------------------------------
def _eval_map(spec, point: Sequence[int], nsp: int) -> Optional[Tuple[int, ...]]:
    """Index map at one grid point, python ints in — ints out.  Scalar
    prefetch refs get inert placeholders (this repo's maps never read
    them for indexing)."""
    args = tuple(point) + (object(),) * nsp
    try:
        idx = spec.index_map(*args)
    except TypeError:
        idx = spec.index_map(*point)
    return tuple(int(i) for i in _as_tuple(idx))


def _check_call(call: CapturedCall,
                budget: int = VMEM_BYTES) -> List[Violation]:
    out: List[Violation] = []
    vmem = _vmem_bytes(call)
    if vmem > budget:
        out.append(Violation(
            "PK003", f"{call.kernel}[{call.index}]",
            f"tile set needs {vmem} B of VMEM (double-buffered blocks "
            f"+ scratch) > budget {budget} B"))
    if not call.grid:
        return out
    npoints = int(np.prod(call.grid))
    if npoints > _GRID_POINT_CAP:
        out.append(Violation("PK001", call.label("grid", 0),
                             f"probe grid {call.grid} too large to "
                             "enumerate — shrink the probe"))
        return out
    points = list(itertools.product(*(range(g) for g in call.grid)))

    units = (
        [("in", i, spec, shp) for i, (spec, shp)
         in enumerate(zip(call.in_specs, call.in_shapes))]
        + [("out", i, spec, shp) for i, (spec, shp)
           in enumerate(zip(call.out_specs, call.out_shapes))])

    for kind, i, spec, (shape, itemsize) in units:
        where = call.label(kind, i)
        block = tuple(int(b) for b in spec.block_shape)
        if len(block) != len(shape):
            out.append(Violation(
                "PK002", where,
                f"block rank {len(block)} != array rank {len(shape)} "
                f"(block {block}, array {shape})"))
            continue
        visited = set()
        oob = None
        for p in points:
            idx = _eval_map(spec, p, call.num_scalar_prefetch)
            visited.add(idx)
            for d, (bi, bd, ad) in enumerate(zip(idx, block, shape)):
                if bi < 0 or (bi * bd + bd) > ad:
                    oob = (p, idx, d)
            if oob:
                break
        if oob:
            p, idx, d = oob
            out.append(Violation(
                "PK002", where,
                f"grid point {p} maps block index {idx}: dim {d} spans "
                f"[{idx[d] * block[d]}, {idx[d] * block[d] + block[d]}) "
                f"outside array extent {shape[d]} (block {block}, "
                f"array {shape})"))
            continue
        if kind == "out":
            required = set(itertools.product(
                *(range(-(-ad // bd)) for ad, bd in zip(shape, block))))
            missing = required - visited
            if missing:
                out.append(Violation(
                    "PK001", where,
                    f"{len(missing)}/{len(required)} output tiles never "
                    f"visited, e.g. {sorted(missing)[0]} (grid "
                    f"{call.grid}, block {block}, array {shape})"))
        # PK004 — lane contract on tiled feature dims
        bt, at = block[-1], shape[-1]
        if bt < at and at >= LANE and bt % LANE:
            out.append(Violation(
                "PK004", where,
                f"trailing dim tiled {bt}/{at}: tile is not a multiple "
                f"of the {LANE}-wide lane (pad the array — see "
                "masked_agg._pad_lanes)"))
    return out


def _vmem_bytes(call: CapturedCall) -> int:
    total = call.scratch_bytes
    for spec, (_, itemsize) in (
            list(zip(call.in_specs, call.in_shapes))
            + list(zip(call.out_specs, call.out_shapes))):
        total += 2 * int(np.prod(spec.block_shape)) * itemsize   # dbl-buffered
    return total


# ------------------------------- kernel probes --------------------------------
def _probe_qsgd():
    from repro.kernels.qsgd.kernel import qsgd_encode_fwd
    x = jnp.ones((512, 128), jnp.float32)
    qsgd_encode_fwd(x, x, jnp.float32(1.0), levels=64, block_rows=256)


def _probe_qsgd_decode():
    from repro.kernels.qsgd_decode.kernel import qsgd_decode_accumulate_fwd
    n, l, bucket = 8, 8192, 128
    codes = jnp.zeros((n, l), jnp.int8)
    norms = jnp.ones((n, l // bucket), jnp.float32)
    qsgd_decode_accumulate_fwd(codes, norms, jnp.ones((n,), jnp.float32),
                               levels=64, bucket_size=bucket, block_d=4096)


def _probe_masked_agg():
    from repro.kernels.masked_agg import kernel as k
    upd = jnp.ones((8, 4000), jnp.float32)        # exercises _pad_lanes
    mask = jnp.ones((8,), jnp.float32)
    k.masked_median_fwd(upd, mask, block_d=2048)
    k.masked_cc_iter_fwd(upd, jnp.zeros((4000,), jnp.float32), mask,
                         block_d=2048)
    k.masked_krum_d2_fwd(upd, block_d=2048)


def _probe_centered_clip():
    from repro.kernels.centered_clip.kernel import centered_clip_iter_fwd
    centered_clip_iter_fwd(jnp.ones((8, 4096), jnp.float32),
                           jnp.zeros((4096,), jnp.float32), block_d=2048)


def _probe_swa_attention():
    from repro.kernels.swa_attention.kernel import swa_attention_fwd
    q = jnp.ones((1, 2, 512, 128), jnp.float32)
    swa_attention_fwd(q, q, q, window=256, block_q=128)


def _probe_mamba2_scan():
    from repro.kernels.mamba2_scan.kernel import ssd_scan_fwd
    b, s, h, p, n = 1, 512, 2, 64, 128
    ssd_scan_fwd(jnp.ones((b, s, h, p), jnp.float32),
                 jnp.zeros((b, s, h), jnp.float32),
                 jnp.ones((b, s, n), jnp.float32),
                 jnp.ones((b, s, n), jnp.float32),
                 jnp.zeros((b, h, p, n), jnp.float32), chunk=128)


def _probe_rwkv6_wkv():
    from repro.kernels.rwkv6_wkv.kernel import wkv_scan_fwd
    b, s, h, dk = 1, 256, 2, 64
    r = jnp.ones((b, s, h, dk), jnp.float32)
    wkv_scan_fwd(r, r, r, r, jnp.ones((h, dk), jnp.float32),
                 jnp.zeros((b, h, dk, dk), jnp.float32), chunk=64)


#: name -> (probe, VMEM budget in bytes).  Budgets are the full-core
#: default; a kernel wanting a tighter promise overrides here.
KERNEL_PROBES: Dict[str, Tuple[Callable[[], None], int]] = {
    "qsgd": (_probe_qsgd, VMEM_BYTES),
    "qsgd_decode": (_probe_qsgd_decode, VMEM_BYTES),
    "masked_agg": (_probe_masked_agg, VMEM_BYTES),
    "centered_clip": (_probe_centered_clip, VMEM_BYTES),
    "swa_attention": (_probe_swa_attention, VMEM_BYTES),
    "mamba2_scan": (_probe_mamba2_scan, VMEM_BYTES),
    "rwkv6_wkv": (_probe_rwkv6_wkv, VMEM_BYTES),
}


def check_kernel(name: str) -> Tuple[List[Violation], List[CapturedCall]]:
    probe, budget = KERNEL_PROBES[name]
    records: List[CapturedCall] = []
    with capture_pallas_calls(records, name):
        probe()
    out: List[Violation] = []
    for call in records:
        out.extend(_check_call(call, budget))
    return out, records


def check_all() -> Tuple[List[Violation], Dict[str, int]]:
    """All registered kernels.  Returns (violations, {kernel: #pallas_calls})."""
    violations: List[Violation] = []
    counts: Dict[str, int] = {}
    for name in sorted(KERNEL_PROBES):
        v, records = check_kernel(name)
        violations.extend(v)
        counts[name] = len(records)
    return violations, counts
