"""Violation/report plumbing shared by the three analyzers.

A :class:`Violation` is one rule firing at one stable location.  Its
``key`` (``CODE::where``) deliberately excludes line numbers — ``where`` is
a ``file::qualname`` or ``program::variant`` anchor — so a checked-in
baseline survives unrelated edits to the same file.  The human-facing
``message`` carries the precise line.

Baseline policy (docs/analysis.md): the baseline file maps keys to a
one-line justification.  A baselined violation is reported but does not
fail the gate; an *unused* baseline entry does — stale debt records are
themselves a violation (PL000), so the file can only shrink honestly.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List

#: code -> one-line rule description.  Single registry so the CLI, docs
#: test, and golden tests agree on the catalog.
RULES: Dict[str, str] = {
    # -- jaxpr_audit ----------------------------------------------------------
    "JX001": "64-bit (f64/i64) value on the hot path",
    "JX002": "weak-type hazard: weak constant materialized into a buffer, "
             "weak program output/scan carry, or mixed-dtype promotion",
    "JX003": "host callback / debug print inside a traced program",
    "JX004": "dynamic or data-dependent shape in a traced program",
    "JX005": "collective on an axis the program's mesh does not declare",
    "JX006": "declared donation not honored: params/opt-state buffers "
             "not aliased in the lowered program",
    "JX007": "retrace fingerprint unstable across lane-value variants "
             "(the no-recompile contract would break)",
    # -- pallas_check ---------------------------------------------------------
    "PK001": "kernel output tiles do not cover the output array",
    "PK002": "kernel tile reads/writes past the padded array bounds",
    "PK003": "kernel VMEM tile footprint exceeds its budget",
    "PK004": "tiled feature dim violates the lane-multiple padding contract",
    # -- tracer_lint ----------------------------------------------------------
    "PL000": "stale baseline entry (key no longer fires)",
    "PL001": "python if/while on a traced expression inside a traced fn",
    "PL002": "host escape (.item()/float()/int()/bool()) inside a traced fn",
    "PL003": "numpy call inside a traced fn (silent constant-fold or crash)",
    "PL004": "unordered dict iteration in pytree-order-sensitive code",
    "PL005": "lru_cache on an array-taking function (pins live buffers, "
             "retraces per concrete array identity)",
}


@dataclass(frozen=True)
class Violation:
    code: str      # rule code from RULES
    where: str     # stable anchor: "file::qualname" or "program::variant"
    message: str   # human detail (line numbers, shapes, values)

    @property
    def key(self) -> str:
        return f"{self.code}::{self.where}"

    def to_dict(self) -> dict:
        return {"code": self.code, "where": self.where,
                "message": self.message, "rule": RULES.get(self.code, "?")}


@dataclass
class Report:
    """Merged result of one ``python -m repro.analysis`` run."""
    violations: List[Violation] = field(default_factory=list)
    suppressed: List[Violation] = field(default_factory=list)  # noqa'd
    baselined: List[Violation] = field(default_factory=list)
    summary: Dict[str, object] = field(default_factory=dict)

    def extend(self, violations: List[Violation]) -> None:
        self.violations.extend(violations)

    def apply_baseline(self, baseline: Dict[str, str]) -> None:
        """Move baselined violations aside; turn stale entries into PL000."""
        live, shelved = [], []
        hit_keys = set()
        for v in self.violations:
            if v.key in baseline:
                hit_keys.add(v.key)
                shelved.append(v)
            else:
                live.append(v)
        for key, why in sorted(baseline.items()):
            if key not in hit_keys:
                live.append(Violation(
                    "PL000", key,
                    f"baseline entry no longer fires (was: {why}) — "
                    "delete it from the baseline file"))
        self.violations, self.baselined = live, shelved

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "rules": dict(RULES),
            "violations": [v.to_dict() for v in self.violations],
            "suppressed": [v.to_dict() for v in self.suppressed],
            "baselined": [v.to_dict() for v in self.baselined],
            "summary": self.summary,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)


def default_baseline_path() -> Path:
    return Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path=None) -> Dict[str, str]:
    """``{violation key: one-line justification}`` from the checked-in
    baseline file (empty at HEAD — kept so debt, if ever taken on, is
    visible in review rather than silent)."""
    p = Path(path) if path is not None else default_baseline_path()
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    return dict(data.get("keys", {}))
