"""Fused QSGD dequantize-and-accumulate — Pallas TPU kernel (paper §3.1+§3.3).

The unfused round decodes every node's QSGD payload into a full fp32
(N, D) stack before aggregation touches it — 4 bytes/element of HBM
traffic for data that lived on the wire at ~0.56 bytes/element (int8
sign+magnitude codes plus one fp32 norm per bucket).  This kernel
consumes the wire payloads directly: each grid step loads an
(N, block_d) tile of int8 codes and the matching (N, block_d/bucket)
norm columns, dequantizes in VMEM, and accumulates the weighted
per-node sum straight into the aggregation accumulator.  The decoded
stack never exists in HBM.

The weight vector folds in whatever the aggregator needs — the masked
mean uses ``mask / k``; CenteredClip-style iterations can pass
per-node clip scales.  Columns are independent, so the grid is a plain
(n_d_blocks,) sweep with no cross-tile state.

``block_d`` must cover whole buckets (the norm layout is per-bucket);
the ops wrapper enforces ``bucket_size % 128 == 0`` and pads D to a
bucket multiple exactly like the wire codec does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_acc_kernel(c_ref, n_ref, w_ref, o_ref, *, bucket: int,
                       levels: int):
    nb_tile = c_ref.shape[1] // bucket
    n = c_ref.shape[0]
    codes = c_ref[...].astype(jnp.float32) / levels    # (N, bd)
    dec = (codes.reshape(n, nb_tile, bucket)
           * n_ref[...][:, :, None]).reshape(n, nb_tile * bucket)
    o_ref[...] = jnp.sum(dec * w_ref[...], axis=0, keepdims=True)


def qsgd_decode_accumulate_fwd(codes, norms, weights, *, levels: int,
                               bucket_size: int, block_d: int = 4096,
                               interpret: bool = False):
    """weights ⋅ dequantize(codes, norms): (N, L) int8 codes, (N, L/bucket)
    norms, (N,) weights -> (L,) f32 accumulator, one streamed pass."""
    n, l = codes.shape
    if bucket_size % 128 or l % bucket_size:
        raise ValueError(
            f"decode_accumulate needs lane-aligned whole buckets: "
            f"bucket_size={bucket_size}, L={l}")
    block_d = max(bucket_size, min(block_d, l))
    while l % block_d or block_d % bucket_size:
        block_d -= bucket_size
    kern = functools.partial(_decode_acc_kernel, bucket=bucket_size,
                             levels=levels)
    out = pl.pallas_call(
        kern,
        grid=(l // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda j: (0, j)),
            pl.BlockSpec((n, block_d // bucket_size), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, l), jnp.float32),
        interpret=interpret,
    )(codes, norms, weights.reshape(n, 1).astype(jnp.float32))
    return out.reshape(l)
