"""Wire-format QSGD payloads and the fused decode-accumulate entry points.

``wire_encode`` is bit-compatible with ``core.compression.qsgd_compress``
— same bucketing, same norms, same stochastic-rounding draws from the
same key — but stores the code as one **signed int8** per element
(sign folded into the magnitude) instead of the reference's int32 + bool
pair, so the payload a fused round keeps live between compress and
aggregate is ~4.5 bytes/element smaller.  ``wire_decode(wire_encode(k, x))``
equals ``compression.roundtrip("qsgd", k, x)`` except that true-sign zero
codes decode to +0.0 rather than −0.0 (numerically equal; every
arithmetic consumer is unaffected).

``QsgdPayload`` is a registered pytree with static (levels, size,
bucket_size) aux data, so ``jax.vmap(wire_encode)`` batches the per-node
payloads into a stack the fused aggregators consume directly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.qsgd_decode.kernel import qsgd_decode_accumulate_fwd

Array = jax.Array


@jax.tree_util.register_pytree_node_class
class QsgdPayload:
    """codes (…, nb, B) int8 signed magnitudes, norms (…, nb, 1) f32 bucket
    L2 norms; levels/size/bucket_size are static aux (vmap-/jit-safe)."""

    def __init__(self, codes: Array, norms: Array, *, levels: int,
                 size: int, bucket_size: int):
        self.codes = codes
        self.norms = norms
        self.levels = levels
        self.size = size
        self.bucket_size = bucket_size

    def tree_flatten(self):
        return (self.codes, self.norms), (self.levels, self.size,
                                          self.bucket_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        levels, size, bucket_size = aux
        codes, norms = children
        return cls(codes, norms, levels=levels, size=size,
                   bucket_size=bucket_size)

    def wire_bits(self) -> int:
        """Same accounting as ``compression.qsgd_compress``."""
        import math
        bits_per_el = math.ceil(math.log2(self.levels + 1)) + 1
        nb = -(-self.size // self.bucket_size)
        return 32 * nb + self.size * bits_per_el


def wire_encode(key, x: Array, *, levels: int = 16,
                bucket_size: int = 1024) -> QsgdPayload:
    """QSGD-quantize ``x`` (any shape) into a signed-int8 wire payload.

    Every intermediate up to the code integers matches
    ``compression.qsgd_compress`` expression-for-expression, so the
    stochastic rounding consumes identical uniform draws and the decoded
    values agree bitwise (modulo signed zeros).  ``levels`` must fit a
    signed byte.
    """
    if levels > 127:
        raise ValueError(f"int8 wire codes need levels <= 127, got {levels}")
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % bucket_size
    padded = jnp.pad(flat, (0, pad)).reshape(-1, bucket_size)
    norms = jnp.linalg.norm(padded, axis=1, keepdims=True)
    scaled = jnp.abs(padded) / jnp.maximum(norms, 1e-30) * levels
    lower = jnp.floor(scaled)
    p = scaled - lower
    rnd = jax.random.uniform(key, padded.shape)
    q = (lower + (rnd < p)).astype(jnp.int32)
    sign = jnp.signbit(padded)
    codes = jnp.where(sign, -q, q).astype(jnp.int8)
    return QsgdPayload(codes, norms, levels=levels, size=flat.size,
                       bucket_size=bucket_size)


def wire_decode(payload: QsgdPayload) -> Array:
    """Dequantize a (possibly vmapped) payload back to flat f32 updates."""
    # associate exactly like compression.qsgd_decompress — (q/levels)·norm —
    # so the reconstruction is bit-equal, not merely within an ulp
    dec = (payload.codes.astype(jnp.float32)
           / payload.levels * payload.norms)
    lead = payload.codes.shape[:-2]
    return dec.reshape(lead + (-1,))[..., :payload.size]


def wire_roundtrip(key, x: Array, *, levels: int = 16,
                   bucket_size: int = 1024) -> Array:
    """decode(encode(x)) — the fused twin of
    ``compression.roundtrip("qsgd", ...)``, equal modulo signed zeros."""
    out = wire_decode(wire_encode(key, x, levels=levels,
                                  bucket_size=bucket_size))
    return out.reshape(x.shape)


def decode_accumulate(payload: QsgdPayload, weights: Array, *,
                      use_kernel: bool = False, block_d: int = 4096,
                      interpret: bool = False) -> Array:
    """Σᵢ wᵢ · decode(payloadᵢ) without a materialized decoded stack.

    ``payload`` is a node-batched QsgdPayload (codes (N, nb, B)); returns
    the (size,) f32 accumulator.  The jnp path writes the dequantize as an
    elementwise expression feeding the node-sum so XLA fuses it into one
    pass; ``use_kernel=True`` runs the Pallas tile kernel instead.
    """
    n, nb, b = payload.codes.shape
    if use_kernel:
        acc = qsgd_decode_accumulate_fwd(
            payload.codes.reshape(n, nb * b),
            payload.norms.reshape(n, nb),
            weights, levels=payload.levels, bucket_size=b,
            block_d=block_d, interpret=interpret)
    else:
        dec = (payload.codes.astype(jnp.float32)
               / payload.levels * payload.norms)            # (N, nb, B)
        w = weights.astype(jnp.float32)[:, None, None]
        acc = jnp.sum(dec * w, axis=0).reshape(-1)
    return acc[:payload.size]
