"""Pure-jnp oracle: decode through the wire codec, then masked-mean.

The reference path is exactly what the unfused round does — reconstruct
the full (N, D) stack via ``compression.qsgd_decompress`` semantics, then
apply the weights — so the conformance suite pins the fused
decode-accumulate against the engine's own arithmetic.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_stack_ref(payload):
    """QsgdPayload batch (N, nb, B) -> decoded (N, size) f32 stack, using
    the reference sign/magnitude decode (signed zeros and all)."""
    q = jnp.abs(payload.codes).astype(jnp.float32)
    sign = payload.codes < 0
    mag = q / payload.levels * payload.norms
    dec = jnp.where(sign, -mag, mag)
    n = dec.shape[0]
    return dec.reshape(n, -1)[:, :payload.size]


def decode_accumulate_ref(payload, weights):
    dec = decode_stack_ref(payload)
    return jnp.sum(dec * weights[:, None].astype(jnp.float32), axis=0)
