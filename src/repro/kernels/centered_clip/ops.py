"""Jit'd CenteredClip wrapper: full iterated aggregation over (N, D)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centered_clip.kernel import centered_clip_iter_fwd


@functools.partial(jax.jit,
                   static_argnames=("clip_tau", "iters", "block_d", "interpret"))
def centered_clip(updates, *, clip_tau: float = 1.0, iters: int = 3,
                  v0=None, block_d: int = 2048, interpret: bool = False):
    """updates: (N, D) -> (D,) robust aggregate (kernel twin of
    repro.core.aggregation.centered_clip with an explicit static τ — the
    adaptive-τ variant computes τ outside and passes it here).

    Warm start matches the reference: coordinate median unless v0 given.
    """
    upd = updates.astype(jnp.float32)
    v = jnp.median(upd, axis=0) if v0 is None else v0.astype(jnp.float32)
    for _ in range(iters):
        v = centered_clip_iter_fwd(upd, v, clip_tau=clip_tau,
                                   block_d=block_d, interpret=interpret)
    return v
