"""Pure-jnp oracle — the model-level aggregator IS the reference."""
from __future__ import annotations

from repro.core.aggregation import centered_clip as centered_clip_ref  # noqa: F401
