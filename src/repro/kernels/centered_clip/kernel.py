"""CenteredClip byzantine-robust aggregation — Pallas TPU kernel ([40], §3.3).

One CenteredClip iteration:  v ← v + mean_i clip(x_i − v, τ), where the clip
is by each node's FULL-vector L2 norm ‖x_i − v‖ over all D coordinates.

TPU adaptation (DESIGN.md §2): D is huge (the flattened gradient) and N is
small (the node count), so the kernel streams (N, block_d) VMEM tiles twice
along a two-phase grid — phase 0 accumulates per-node squared norms into a
persistent (N, 1) VMEM scratch (cross-tile reduction), phase 1 re-streams
the tiles and applies the clipped mean.  The updates matrix is read twice
from HBM; nothing of size D is ever resident.

Grid: (2, n_d_blocks)   (phase outermost, tiles innermost/sequential)
Blocks: x (N, bd) · v (1, bd) -> v_new (1, bd);  scratch sq (N, 1) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, v_ref, o_ref, sq_ref, *, tau: float):
    ph = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = x_ref[...].astype(jnp.float32) - v_ref[...].astype(jnp.float32)

    @pl.when(ph == 0)
    def _accumulate():
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)
        o_ref[...] = v_ref[...]                       # placeholder write

    @pl.when(ph == 1)
    def _apply():
        norm = jnp.sqrt(sq_ref[...])                  # (N, 1)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        o_ref[...] = v_ref[...] + jnp.mean(diff * scale, axis=0, keepdims=True)


def centered_clip_iter_fwd(updates, v, *, clip_tau: float = 1.0,
                           block_d: int = 2048, interpret: bool = False):
    """One CC iteration.  updates: (N, D) fp32; v: (D,) fp32 -> (D,)."""
    n, d = updates.shape
    block_d = min(block_d, d)
    while d % block_d:
        block_d //= 2
    grid = (2, d // block_d)

    kern = functools.partial(_kernel, tau=clip_tau)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_d), lambda ph, j: (0, j)),
            pl.BlockSpec((1, block_d), lambda ph, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda ph, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, 1), jnp.float32)],
        interpret=interpret,
    )(updates, v.reshape(1, d))
    return out.reshape(d)
