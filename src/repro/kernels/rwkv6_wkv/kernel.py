"""RWKV6 WKV recurrence (data-dependent per-channel decay) — Pallas TPU
kernel ([arXiv:2404.05892], the attention-free core of rwkv6-1.6b).

Per head (K = V = head dim), with w_t ∈ (0,1)^K data-dependent:

  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
  y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

Chunked form (same algebra as models.rwkv6.wkv_chunked): within a chunk the
strictly-causal part is a (c×c) banded matmul with cumulative log-decay,
the diagonal carries the u bonus, and the (K×V) state is carried across
chunks.  TPU adaptation: the state lives in VMEM scratch across the
sequential chunk grid dim; every matmul maps to the MXU with c, K multiples
of (8, 128) at production sizes (c=128, K=64..128).

Grid: (B·H, n_chunks)   (chunks innermost — state carry)
Blocks (inputs pre-reshaped to (B, nc, c, H, K)):
  r/k/v/logw (1, 1, c, 1, K);  u (1, K);  s0 (1, 1, K, K)
Outputs: y (1, 1, c, 1, K);  s_final (1, 1, K, K)
Scratch: S (K, K) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref, s_ref, *,
            nchunks: int):
    kidx = pl.program_id(1)

    @pl.when(kidx == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0, :, 0].astype(jnp.float32)          # (c, K)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)
    logw = w_ref[0, 0, :, 0].astype(jnp.float32)       # ≤ 0
    u = u_ref[0].astype(jnp.float32)                   # (K,)
    c = r.shape[0]

    cs = jnp.cumsum(logw, axis=0)                      # (c, K) inclusive
    excl = cs - logw                                   # exclusive
    rd = r * jnp.exp(excl)
    kd = k * jnp.exp(-cs)
    att = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())))   # (c, c)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))      # strict
    att = jnp.where(tri, att, 0.0)
    y = jax.lax.dot(att, v)                                        # (c, K)
    # diagonal with u bonus
    y += jnp.sum(r * u[None] * k, axis=-1, keepdims=True) * v
    # inter-chunk
    y += jax.lax.dot(rd, s_ref[...])                               # (c,K)·(K,V)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update
    end = cs[-1]                                                   # (K,)
    s_new = s_ref[...] * jnp.exp(end)[:, None] + jax.lax.dot_general(
        k * jnp.exp(end[None] - cs), v, (((0,), (0,)), ((), ())))  # (K, V)
    s_ref[...] = s_new

    @pl.when(kidx == nchunks - 1)
    def _final():
        sf_ref[0, 0] = s_new.astype(sf_ref.dtype)


def wkv_scan_fwd(r, k, v, logw, u, s0, *, chunk: int = 64,
                 interpret: bool = False):
    """r, k, v, logw: (B, S, H, K); u: (H, K); s0: (B, H, K, K) fp32.
    Returns y (B, S, H, K) fp32 and s_final (B, H, K, K) fp32."""
    bsz, s, h, dk = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    resh = lambda t: t.reshape(bsz, nc, chunk, h, dk)
    grid = (bsz * h, nc)
    kern = functools.partial(_kernel, nchunks=nc)

    io_spec = pl.BlockSpec((1, 1, chunk, 1, dk),
                           lambda bh, kk: (bh // h, kk, 0, bh % h, 0))
    st_spec = pl.BlockSpec((1, 1, dk, dk),
                           lambda bh, kk: (bh // h, bh % h, 0, 0))

    y, sf = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, dk), lambda bh, kk: (bh % h, 0)),
                  st_spec],
        out_specs=[io_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, chunk, h, dk), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, dk, dk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(resh(r), resh(k), resh(v), resh(logw), u, s0.astype(jnp.float32))
    return y.reshape(bsz, s, h, dk), sf
