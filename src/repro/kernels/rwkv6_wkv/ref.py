"""Pure-jnp oracle: the token-by-token WKV recurrence."""
from __future__ import annotations

from repro.models.rwkv6 import wkv_reference  # noqa: F401
