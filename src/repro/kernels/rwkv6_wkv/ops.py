"""Jit'd WKV wrapper with the same surface as models.rwkv6.wkv_chunked."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked_pallas(r, k, v, w, u, *, chunk: int = 64, s0=None,
                       interpret: bool = False):
    """r, k, v, w: (B, S, H, K) with w the per-step decay in (0, 1);
    u: (H, K).  Returns (y, s_final) — matches wkv_chunked."""
    bsz, s, h, dk = r.shape
    if s0 is None:
        s0 = jnp.zeros((bsz, h, dk, dk), jnp.float32)
    logw = jnp.log(w.astype(jnp.float32))
    y, sf = wkv_scan_fwd(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), logw, u.astype(jnp.float32),
                         s0, chunk=chunk, interpret=interpret)
    return y.astype(r.dtype), sf
