"""Jit'd QSGD wrappers: arbitrary-shape tensors in, (codes, norm) out."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qsgd.kernel import LANE, qsgd_encode_fwd

Array = jax.Array


def _to_lanes(x: Array):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % LANE
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANE), pad


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def qsgd_encode(key, x: Array, *, levels: int = 64, interpret: bool = False):
    """Returns (codes int8 (R,128), norm fp32 scalar, pad).  Unbiased."""
    x2d, pad = _to_lanes(x)
    norm = jnp.linalg.norm(x2d)
    rnd = jax.random.uniform(key, x2d.shape, jnp.float32)
    q = qsgd_encode_fwd(x2d, rnd, norm, levels=levels, interpret=interpret)
    return q, norm


@functools.partial(jax.jit, static_argnames=("levels", "shape"))
def qsgd_decode(q: Array, norm: Array, *, levels: int, shape: tuple):
    size = 1
    for d in shape:
        size *= d
    mag = q.astype(jnp.float32) / levels * norm
    return mag.reshape(-1)[:size].reshape(shape)


def qsgd_roundtrip(key, x: Array, *, levels: int = 64, interpret: bool = False):
    q, norm = qsgd_encode(key, x, levels=levels, interpret=interpret)
    return qsgd_decode(q, norm, levels=levels, shape=tuple(x.shape))


def wire_bits(x: Array) -> int:
    """int8 code per element + fp32 norm."""
    return x.size * 8 + 32


def single_bucket_regime(size: int, *, bucket_size: int = 1024) -> bool:
    """True iff this kernel (one global norm, LANE-padded draws) and the
    bucketed wire codec ``compression.qsgd_compress`` quantize identically.

    Two facts make the regimes coincide:
    (1) threefry uniform draws depend only on the *total* padded element
        count, so ``uniform(key, (r, LANE))`` equals
        ``uniform(key, (1, r*LANE))`` reshaped, bit for bit;
    (2) zero padding never changes a bucket's L2 norm.

    Hence the codecs agree exactly when the wire codec produces a single
    bucket whose padded width matches the kernel's LANE padding:
    ``size <= bucket_size`` and ``ceil(size/LANE)*LANE == bucket_size``.
    Outside this regime the per-bucket norms genuinely differ from the
    global norm and divergence is bounded by the QSGD error bound
    (√d/levels · ‖x‖) instead — tests/test_kernels.py pins both regimes
    explicitly against this predicate.
    """
    rows = -(-size // LANE)
    return size <= bucket_size and rows * LANE == bucket_size
