"""Pure-jnp oracle for the QSGD kernel — same codes, bit for bit."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qsgd.kernel import LANE


def qsgd_encode_ref(x2d, rnd2d, norm, *, levels: int = 64):
    """Mirror of kernel._kernel on a full (R, 128) array."""
    x = x2d.astype(jnp.float32)
    scaled = jnp.abs(x) / jnp.maximum(norm, 1e-30) * levels
    lower = jnp.floor(scaled)
    p = scaled - lower
    q = lower + (rnd2d < p).astype(jnp.float32)
    q = jnp.where(jnp.signbit(x), -q, q)
    return q.astype(jnp.int8)


def qsgd_roundtrip_ref(key, x, *, levels: int = 64):
    """Encode+decode via the oracle (matches ops.qsgd_roundtrip)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % LANE
    x2d = jnp.pad(flat, (0, pad)).reshape(-1, LANE)
    norm = jnp.linalg.norm(x2d)
    rnd = jax.random.uniform(key, x2d.shape, jnp.float32)
    q = qsgd_encode_ref(x2d, rnd, norm, levels=levels)
    mag = q.astype(jnp.float32) / levels * norm
    return mag.reshape(-1)[:flat.size].reshape(x.shape)
